"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

The chunked SSD form is the TPU-native adaptation: intra-chunk work is dense
matmuls (MXU-friendly), inter-chunk work is a short `lax.scan` over chunk
states — exactly the structure the Pallas kernel in ``repro.kernels.ssd``
tiles into VMEM. ``ssd_reference`` is the O(S) sequential recurrence oracle
used by tests.

Recurrence (per head h, state dim N, head channels P):

    H_t = exp(A·dt_t) · H_{t-1} + dt_t · B_t ⊗ x_t        H: (P, N)
    y_t = C_t · H_t + D · x_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.transformer.common import normal_init, rms_norm


# ------------------------------------------------------------------- SSD --


def ssd_reference(x, dt, A, B, C, *, h0=None):
    """Sequential oracle. x: (b,s,h,p), dt: (b,s,h), A: (h,), B/C: (b,s,n).
    Returns (y (b,s,h,p), h_final (b,h,p,n))."""
    from repro.core.vma import match_vma

    b, s, h, p = x.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h0 = match_vma(h0, x, dt, A, B, C)

    def step(hs, xs):
        x_t, dt_t, b_t, c_t = xs
        a_t = jnp.exp(A[None, :] * dt_t)  # (b,h)
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        hs = a_t[..., None, None] * hs + upd
        y_t = jnp.einsum("bhpn,bn->bhp", hs, c_t)
        return hs, y_t

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
    )
    h_final, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (b,s,h,p)
    return y.astype(x.dtype), h_final


def ssd_chunked(x, dt, A, B, C, *, chunk: int, h0=None):
    """Chunked SSD (the mamba2 paper's matmul formulation).
    Shapes as ``ssd_reference``. Sequences are padded to a chunk multiple
    internally (dt=0 pads are state-neutral: decay=1, update=0)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    nc, q = s_pad // chunk, chunk

    from repro.core.vma import match_vma

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, q, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, q, n)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h0 = match_vma(h0, x, dt, A, B, C)

    loga = A[None, None, :] * dtf.reshape(b * nc, q, h).reshape(b, nc, q, h)  # (b,nc,q,h)
    la = jnp.cumsum(loga, axis=2)  # cumulative within chunk

    def chunk_body(h_prev, xs):
        x_c, dt_c, b_c, c_c, la_c = xs  # (b,q,h,p),(b,q,h),(b,q,n),(b,q,n),(b,q,h)
        xd = x_c * dt_c[..., None]  # (b,q,h,p)
        # intra-chunk: Y[i] += Σ_{j<=i} (C_i·B_j) exp(la_i - la_j) xd[j]
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)
        decay = jnp.exp(la_c[:, :, None, :] - la_c[:, None, :, :])  # (b,i,j,h)
        causal = jnp.tril(jnp.ones((q, q), bool))
        g = cb[..., None] * jnp.where(causal[None, :, :, None], decay, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", g, xd)
        # inter-chunk: Y[i] += exp(la_i) C_i · h_prev
        y_inter = jnp.einsum("bin,bhpn->bihp", c_c, h_prev) * jnp.exp(la_c)[..., None]
        # state update: h = exp(la_Q) h_prev + Σ_j exp(la_Q - la_j) B_j ⊗ xd_j
        last = la_c[:, -1:, :]  # (b,1,h)
        dstate = jnp.exp(last - la_c)  # (b,q,h)
        h_new = jnp.exp(last[:, 0])[..., None, None] * h_prev + jnp.einsum(
            "bjn,bjhp->bhpn", b_c, xd * dstate[..., None]
        )
        return h_new, y_intra + y_inter

    xs = tuple(
        jnp.moveaxis(a, 1, 0)
        for a in (xf, dtf, Bf, Cf, la)
    )
    h_final, ys = lax.scan(chunk_body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, h, p)[:, :s]
    return y.astype(x.dtype), h_final


def ssd_decode_step(h_state, x, dt, A, B, C):
    """One-token state update. h_state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    B/C: (b,n). Returns (y (b,h,p), h_new)."""
    a_t = jnp.exp(A[None, :] * dt.astype(jnp.float32))
    upd = jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32) * dt[..., None], B.astype(jnp.float32))
    h_new = a_t[..., None, None] * h_state + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C.astype(jnp.float32))
    return y.astype(x.dtype), h_new


# ----------------------------------------------------------- mamba2 block --


def mamba2_init(key: jax.Array, d: int, *, expand: int, head_dim: int, n_state: int, conv_width: int, dtype=jnp.bfloat16) -> dict:
    d_in = expand * d
    h = d_in // head_dim
    conv_dim = d_in + 2 * n_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": normal_init(ks[0], (d, 2 * d_in + 2 * n_state + h), dtype=dtype),
        "conv_w": normal_init(ks[1], (conv_width, conv_dim), scale=0.2, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "dt_bias": jnp.full((h,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "D": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.zeros((d_in,), dtype),
        "out_proj": normal_init(ks[2], (d_in, d), dtype=dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array, *, state: jax.Array | None = None):
    """Depthwise causal conv. xbc: (bt, s, c); w: (width, c).
    ``state``: (bt, width-1, c) left context for decode; returns new state."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[-1]), xbc.dtype)
    full = jnp.concatenate([state, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):
        out = out + full[:, i : i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = full[:, full.shape[1] - (width - 1) :]
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def mamba2_apply(
    p: dict,
    x: jax.Array,  # (bt, s, d)
    *,
    expand: int,
    head_dim: int,
    n_state: int,
    chunk: int,
    ssm_state: jax.Array | None = None,  # (bt, h, p, n) decode carry
    conv_state: jax.Array | None = None,  # (bt, width-1, conv_dim)
    decode: bool = False,
):
    """Mamba2 block body (no outer residual/norm — the block wrapper owns
    those). Returns (y, (ssm_state, conv_state)) — states updated when
    decoding, None-safe otherwise."""
    bt, s, d = x.shape
    d_in = expand * d
    h = d_in // head_dim

    proj = x @ p["in_proj"]  # (bt, s, 2*d_in + 2n + h)
    z, xbc, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * n_state], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state=conv_state)
    x_ssm, B, C = jnp.split(xbc, [d_in, d_in + n_state], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (bt,s,h)
    A = -jnp.exp(p["A_log"])

    xh = x_ssm.reshape(bt, s, h, head_dim)
    if decode:
        assert s == 1
        y1, new_ssm = ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0], A, B[:, 0], C[:, 0]
        )
        y = y1[:, None]
    else:
        y, new_ssm = ssd_chunked(xh, dt, A, B, C, h0=ssm_state, chunk=chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh.astype(y.dtype)
    y = y.reshape(bt, s, d_in)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["out_proj"]
    return out, (new_ssm, new_conv)
