"""Mixture-of-Experts with expert parallelism over a mesh axis.

Routing is sort-based capacity dispatch (no O(T·E·C) one-hot matmuls — those
would pollute the compute roofline with fake FLOPs):

  token→expert assignments are argsorted by expert id, each expert keeps its
  first ``capacity`` tokens, a (E_local, capacity) gather table dispatches,
  and a scatter-add combines weighted expert outputs.

Expert parallelism ("gathered" mode — the paper-era baseline recorded in
EXPERIMENTS.md, with all_to_all dispatch as the hillclimb variant): expert
weights live sharded over ``ep_axis`` (leading E dim); tokens are
all-gathered over that axis, every device runs its local experts, and a
``psum_scatter`` returns each device its own tokens' combined outputs. The
alternative ``a2a`` mode moves only routed tokens with two all_to_alls.

Supports: softmax top-k (standard), sigmoid+bias selection (deepseek-v3
aux-free), shared experts, and arctic's parallel dense residual.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.transformer.common import normal_init
from repro.models.transformer.ffn import ffn_apply, ffn_init


def moe_init(
    key: jax.Array,
    d: int,
    ff: int,
    *,
    num_experts: int,
    num_shared: int = 0,
    dense_residual: bool = False,
    router_kind: str = "softmax",
    mlp_kind: str = "swiglu",
    dtype=jnp.bfloat16,
) -> dict:
    ks = jax.random.split(key, 8)
    p = {
        "router": normal_init(ks[0], (d, num_experts), scale=0.006, dtype=jnp.float32),
        "we_gate": normal_init(ks[1], (num_experts, d, ff), dtype=dtype),
        "we_up": normal_init(ks[2], (num_experts, d, ff), dtype=dtype),
        "we_down": normal_init(ks[3], (num_experts, ff, d), dtype=dtype),
    }
    if router_kind == "sigmoid":
        p["router_bias"] = jnp.zeros((num_experts,), jnp.float32)
    if num_shared:
        p["shared"] = ffn_init(ks[4], d, ff * num_shared, kind=mlp_kind, dtype=dtype)
    if dense_residual:
        p["dense"] = ffn_init(ks[5], d, ff, kind=mlp_kind, dtype=dtype)
    return p


def _route(p: dict, x: jax.Array, *, k: int, router_kind: str):
    """-> (topk_idx (T,k) int32, topk_w (T,k) f32, aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ p["router"]  # (T, E)
    e = logits.shape[-1]
    if router_kind == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"][None, :]
        _, idx = lax.top_k(sel, k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = lax.top_k(probs, k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * Σ_e f_e · P_e
    f = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / idx.size
    pbar = probs.mean(axis=0)
    aux = e * jnp.sum(f * pbar)
    return idx.astype(jnp.int32), w, aux


def _dispatch_tables(idx: jax.Array, w: jax.Array, *, num_experts: int, e0, e_local: int, capacity: int):
    """Sort-based dispatch. Returns (token_table (E_local, C) int32,
    weight_table (E_local, C) f32) — token_table rows index into the gathered
    token array; empty slots point at token 0 with weight 0."""
    t, k = idx.shape
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = w.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=num_experts)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)

    local = (se >= e0) & (se < e0 + e_local) & (pos < capacity)
    slot = jnp.where(local, (se - e0) * capacity + pos, e_local * capacity)

    tok_table = jnp.zeros((e_local * capacity + 1,), jnp.int32).at[slot].set(st, mode="drop")
    w_table = jnp.zeros((e_local * capacity + 1,), jnp.float32).at[slot].set(sw, mode="drop")
    return (
        tok_table[:-1].reshape(e_local, capacity),
        w_table[:-1].reshape(e_local, capacity),
    )


def _expert_ffn(p: dict, xin: jax.Array, *, mlp_kind: str) -> jax.Array:
    """xin: (E_local, C, d) with per-expert weights (E_local, d, ff)."""
    if mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", xin, p["we_gate"])) * jnp.einsum(
            "ecd,edf->ecf", xin, p["we_up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xin, p["we_up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["we_down"])


def moe_apply(
    p: dict,
    x: jax.Array,  # (T, d) local tokens
    *,
    num_experts: int,
    k: int,
    router_kind: str = "softmax",
    mlp_kind: str = "swiglu",
    capacity_factor: float = 1.25,
    ep_axis: str | None = None,
    ep_size: int = 1,
    mode: str = "gathered",  # "gathered" | "a2a" | "replicated"
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (T, d), aux_loss). Expert leaves in ``p`` are LOCAL
    shards (E_local = num_experts / ep_size) when ep_axis is set.

    ``replicated`` mode: tokens are identical on every ep_axis device (e.g.
    batch=1 long-context decode); each device runs its local experts and the
    combined output is psum'd — no token gather/scatter at all."""
    t, d = x.shape
    idx, w, aux = _route(p, x, k=k, router_kind=router_kind)

    if ep_axis is not None and mode == "gathered":
        xg = lax.all_gather(x, ep_axis, axis=0, tiled=True)  # (T_all, d)
        idx = lax.all_gather(idx, ep_axis, axis=0, tiled=True)
        w = lax.all_gather(w, ep_axis, axis=0, tiled=True)
    else:
        xg = x
    t_all = xg.shape[0]

    e_local = num_experts // ep_size
    e0 = (lax.axis_index(ep_axis) * e_local) if ep_axis is not None else 0
    capacity = max(8, math.ceil(t_all * k / num_experts * capacity_factor))

    if ep_axis is not None and mode == "a2a":
        out = _moe_a2a(
            p, x, idx, w,
            num_experts=num_experts, e_local=e_local, e0=e0,
            capacity=max(8, math.ceil(t * k / num_experts * capacity_factor)),
            ep_axis=ep_axis, ep_size=ep_size, mlp_kind=mlp_kind,
        )
    else:
        tok_table, w_table = _dispatch_tables(
            idx, w, num_experts=num_experts, e0=e0, e_local=e_local, capacity=capacity
        )
        xin = xg[tok_table]  # (E_local, C, d)
        yout = _expert_ffn(p, xin, mlp_kind=mlp_kind)
        contrib = (yout * w_table[..., None]).astype(jnp.float32)
        out_g = jnp.zeros((t_all, d), jnp.float32).at[tok_table.reshape(-1)].add(
            contrib.reshape(-1, d)
        )
        if ep_axis is not None and mode == "gathered":
            out = lax.psum_scatter(out_g, ep_axis, scatter_dimension=0, tiled=True)
        elif ep_axis is not None and mode == "replicated":
            out = lax.psum(out_g, ep_axis)
        else:
            out = out_g
    out = out.astype(x.dtype)

    if "shared" in p:
        out = out + ffn_apply(p["shared"], x, kind=mlp_kind)
    if "dense" in p:
        out = out + ffn_apply(p["dense"], x, kind=mlp_kind)
    return out, aux


def _moe_a2a(
    p, x, idx, w, *, num_experts, e_local, e0, capacity, ep_axis, ep_size, mlp_kind
):
    """all_to_all expert parallelism (beyond-paper §Perf variant): each device
    packs per-destination-device expert buffers from its LOCAL tokens only,
    all_to_alls them, runs local experts, and all_to_alls results back.
    Moves ~k/E·T·ep_size× less data than the gathered baseline."""
    t, d = x.shape
    # local dispatch tables for EVERY destination device: (ep, E_local, C)
    tok_tabs = []
    w_tabs = []
    for dev in range(ep_size):
        tt, wt = _dispatch_tables(
            idx, w, num_experts=num_experts, e0=dev * e_local, e_local=e_local, capacity=capacity
        )
        tok_tabs.append(tt)
        w_tabs.append(wt)
    tok_tab = jnp.stack(tok_tabs)  # (ep, E_local, C)
    w_tab = jnp.stack(w_tabs)
    send = x[tok_tab]  # (ep, E_local, C, d) — buffers for each dest device
    # exchange: device i sends slice j to device j
    recv = lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0, tiled=True)
    recv = recv.reshape(ep_size, e_local, capacity, d)  # by source device
    # run experts: the same local expert weights serve every source device
    yout = _expert_ffn_by_source(p, recv, mlp_kind=mlp_kind)
    back = lax.all_to_all(
        yout.reshape(ep_size, e_local, capacity, d), ep_axis, split_axis=0, concat_axis=0, tiled=True
    ).reshape(ep_size, e_local, capacity, d)
    out = jnp.zeros((t, d), jnp.float32)
    flat_tok = tok_tab.reshape(-1)
    contrib = (back.reshape(ep_size, e_local, capacity, d) * w_tab[..., None]).astype(jnp.float32)
    out = out.at[flat_tok].add(contrib.reshape(-1, d))
    return out


def _expert_ffn_by_source(p: dict, recv: jax.Array, *, mlp_kind: str) -> jax.Array:
    """recv: (ep_src, E_local, C, d) -> same shape; expert dim shared."""
    ep, e_local, c, d = recv.shape
    xin = recv.transpose(1, 0, 2, 3).reshape(e_local, ep * c, d)
    y = _expert_ffn(p, xin, mlp_kind=mlp_kind)
    return y.reshape(e_local, ep, c, d).transpose(1, 0, 2, 3)
