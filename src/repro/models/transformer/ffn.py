"""Feed-forward blocks: SwiGLU / GeGLU / GELU-MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ffn_apply(p: dict, x: jax.Array, *, kind: str) -> jax.Array:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "gelu":
        return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
    raise KeyError(f"unknown mlp kind {kind!r}")


def ffn_init(key: jax.Array, d: int, ff: int, *, kind: str, dtype=jnp.bfloat16) -> dict:
    from repro.models.transformer.common import normal_init

    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": normal_init(ks[0], (d, ff), dtype=dtype),
            "w_up": normal_init(ks[1], (d, ff), dtype=dtype),
            "w_down": normal_init(ks[2], (ff, d), dtype=dtype),
        }
    return {
        "w_up": normal_init(ks[1], (d, ff), dtype=dtype),
        "w_down": normal_init(ks[2], (ff, d), dtype=dtype),
    }
