"""Per-architecture transformer blocks (one layer slot) — init + train/decode.

A block is the unit the pipeline scans. All slots of an arch share one
homogeneous params pytree; heterogeneity rides in ``extras``:

    active : f32  — 0 on pipeline-padding slots (block becomes identity)
    window : i32  — sliding-window size for this layer (0 = global)

Hybrid (zamba2) is assembled at the *stage* level in model.py (5 scanned
mamba slots + 1 weight-shared attention slot) so its KV cache exists only
where attention does.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.transformer.attention import blocked_attention, decode_attention
from repro.models.transformer.common import apply_mrope, apply_rope, normal_init, rms_norm
from repro.models.transformer.ffn import ffn_apply, ffn_init
from repro.models.transformer.moe import moe_apply, moe_init
from repro.models.transformer.ssm import mamba2_apply, mamba2_init


# ------------------------------------------------------------------ init --


def init_attn_params(cfg: ArchConfig, key: jax.Array, *, dtype=jnp.bfloat16) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 10)
    if cfg.attn_kind == "mla":
        qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        p = {
            "w_dq": normal_init(ks[0], (d, cfg.q_lora_rank), dtype=dtype),
            "ln_q": jnp.zeros((cfg.q_lora_rank,), dtype),
            "w_uq": normal_init(ks[1], (cfg.q_lora_rank, h * qk), dtype=dtype),
            "w_dkv": normal_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype=dtype),
            "ln_kv": jnp.zeros((cfg.kv_lora_rank,), dtype),
            "w_uk": normal_init(ks[3], (cfg.kv_lora_rank, h * cfg.qk_nope_head_dim), dtype=dtype),
            "w_uv": normal_init(ks[4], (cfg.kv_lora_rank, h * cfg.v_head_dim), dtype=dtype),
            "w_o": normal_init(ks[5], (h * cfg.v_head_dim, d), dtype=dtype),
        }
        return p
    p = {
        "w_q": normal_init(ks[0], (d, h * hd), dtype=dtype),
        "w_k": normal_init(ks[1], (d, kv * hd), dtype=dtype),
        "w_v": normal_init(ks[2], (d, kv * hd), dtype=dtype),
        "w_o": normal_init(ks[3], (h * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h * hd,), dtype)
        p["b_k"] = jnp.zeros((kv * hd,), dtype)
        p["b_v"] = jnp.zeros((kv * hd,), dtype)
    return p


def init_block(cfg: ArchConfig, key: jax.Array, *, dtype=jnp.bfloat16) -> dict:
    """One attention(+FFN/MoE) layer slot."""
    d = cfg.d_model
    k_attn, k_ffn, k_norm = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "attn": init_attn_params(cfg, k_attn, dtype=dtype),
    }
    if cfg.sandwich_norms:
        p["ln1_post"] = jnp.zeros((d,), dtype)
        p["ln2_post"] = jnp.zeros((d,), dtype)
    if cfg.num_experts:
        p["moe"] = moe_init(
            k_ffn,
            d,
            cfg.d_ff,
            num_experts=cfg.num_experts,
            num_shared=cfg.num_shared_experts,
            dense_residual=cfg.moe_dense_residual,
            router_kind=cfg.router_kind,
            mlp_kind=cfg.mlp_kind,
            dtype=dtype,
        )
    else:
        p["ffn"] = ffn_init(k_ffn, d, cfg.d_ff, kind=cfg.mlp_kind, dtype=dtype)
    return p


def init_mamba_block(cfg: ArchConfig, key: jax.Array, *, dtype=jnp.bfloat16) -> dict:
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "mamba": mamba2_init(
            key,
            cfg.d_model,
            expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim,
            n_state=cfg.ssm_state,
            conv_width=cfg.ssm_conv_width,
            dtype=dtype,
        ),
    }


# ------------------------------------------------------------ attention --


def _project_qkv(cfg: ArchConfig, p: dict, h_in: jax.Array, positions: jax.Array):
    """-> (q (B,S,H,hd'), k (B,S,KV,hd'), v (B,S,KV,vd), cache_entry).
    ``cache_entry`` is what prefill persists: {'k','v'} post-rope for GQA,
    the compressed {'ckv'} (= ckv ‖ k_rope) for MLA."""
    b, s, _ = h_in.shape
    if cfg.attn_kind == "mla":
        qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        cq = rms_norm(h_in @ p["w_dq"], p["ln_q"], eps=cfg.norm_eps)
        q = (cq @ p["w_uq"]).reshape(b, s, cfg.num_heads, qk)
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
        q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

        dkv = h_in @ p["w_dkv"]
        ckv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
        ckv = rms_norm(ckv, p["ln_kv"], eps=cfg.norm_eps)
        k_rope = apply_rope(k_rope[:, :, None, :], positions, theta=cfg.rope_theta)
        k_nope = (ckv @ p["w_uk"]).reshape(b, s, cfg.num_heads, cfg.qk_nope_head_dim)
        v = (ckv @ p["w_uv"]).reshape(b, s, cfg.num_heads, cfg.v_head_dim)

        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:-1], cfg.qk_rope_head_dim))],
            axis=-1,
        )
        entry = {"ckv": jnp.concatenate([ckv, k_rope[:, :, 0]], axis=-1)}
        return q, k, v, entry

    hd = cfg.head_dim
    q = h_in @ p["w_q"]
    k = h_in @ p["w_k"]
    v = h_in @ p["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.rope_kind == "mrope":
        q = apply_mrope(q, positions, theta=cfg.rope_theta)
        k = apply_mrope(k, positions, theta=cfg.rope_theta)
    elif cfg.rope_kind == "rope":
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
    return q, k, v, {"k": k, "v": v}


def attn_apply(
    cfg: ArchConfig,
    p: dict,
    h_in: jax.Array,
    *,
    positions: jax.Array,
    window,
    kv_block: int = 512,
    return_cache: bool = False,
    backend: str = "blocked",  # "blocked" (pure jnp) | "flash" (Pallas)
):
    b, s, _ = h_in.shape
    q, k, v, entry = _project_qkv(cfg, p, h_in, positions)
    lin = positions[0] if cfg.rope_kind == "mrope" else positions  # causal order
    if backend == "flash" and s % 128 == 0 and isinstance(window, int):
        from repro.kernels.flash.ops import flash_attention

        out = flash_attention(q, k, v, window, cfg.attn_softcap, 128, 128)
    else:
        out = blocked_attention(
            q, k, v,
            q_pos=lin, kv_pos=lin,
            window=window,
            attn_softcap=cfg.attn_softcap,
            kv_block=kv_block,
        )
    out = out.reshape(b, s, -1) @ p["w_o"]
    if return_cache:
        return out, entry
    return out


def ring_positions(cur_pos: jax.Array, w_local: int, *, seq_axis: str | None = None, w_total: int | None = None) -> jax.Array:
    """Global positions held by ring-buffer slots, derived (not stored):
    slot i holds p_i = cur_pos - ((cur_pos - i) mod W); p_i < 0 ⇒ empty.
    Valid because serving fills positions contiguously 0..cur_pos."""
    w_total = w_total or w_local
    idx = jnp.arange(w_local, dtype=jnp.int32)
    if seq_axis is not None:
        idx = idx + lax.axis_index(seq_axis).astype(jnp.int32) * w_local
    return cur_pos - ((cur_pos - idx) % w_total)


def attn_decode_apply(
    cfg: ArchConfig,
    p: dict,
    h_in: jax.Array,  # (B, 1, d)
    cache: dict,  # {'k','v'} or {'ckv'} (mla), ring-buffer on dim 1
    *,
    cur_pos: jax.Array,
    window,
    seq_axis: str | None = None,
    seq_shards: int = 1,
) -> tuple[jax.Array, dict]:
    b = h_in.shape[0]
    pos_vec = (
        jnp.full((3, 1), cur_pos, jnp.int32) if cfg.rope_kind == "mrope" else jnp.full((1,), cur_pos, jnp.int32)
    )
    q, k_new, v_new, entry_new = _project_qkv(cfg, p, h_in, pos_vec)
    q = q[:, 0]  # (B, H, hd)

    w_local = (cache["ckv"] if cfg.attn_kind == "mla" else cache["k"]).shape[1]
    w_total = w_local * seq_shards
    slot = cur_pos % w_total
    if seq_axis is not None:
        owner = slot // w_local
        local_slot = slot - owner * w_local
        mine = lax.axis_index(seq_axis) == owner
    else:
        local_slot = slot
        mine = jnp.asarray(True)

    def wr(buf, new):
        upd = lax.dynamic_update_index_in_dim(buf, new, local_slot, axis=1)
        return jnp.where(mine, upd, buf)

    if cfg.attn_kind == "mla":
        # compressed cache: ckv (B, W, r + rope_dim)
        cache = dict(cache, ckv=wr(cache["ckv"], entry_new["ckv"][:, 0]))
        # expand cached ckv -> k, v (recompute form)
        ckv_all, kr_all = jnp.split(cache["ckv"], [cfg.kv_lora_rank], axis=-1)
        k_nope = (ckv_all @ p["w_uk"]).reshape(b, w_local, cfg.num_heads, cfg.qk_nope_head_dim)
        v_all = (ckv_all @ p["w_uv"]).reshape(b, w_local, cfg.num_heads, cfg.v_head_dim)
        k_all = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :], (*k_nope.shape[:-1], cfg.qk_rope_head_dim))],
            axis=-1,
        )
    else:
        cache = dict(cache, k=wr(cache["k"], k_new[:, 0]), v=wr(cache["v"], v_new[:, 0]))
        k_all, v_all = cache["k"], cache["v"]

    kv_pos = ring_positions(cur_pos, w_local, seq_axis=seq_axis, w_total=w_total)
    out = decode_attention(
        q, k_all, v_all, kv_pos, cur_pos,
        window=window, attn_softcap=cfg.attn_softcap, axis=seq_axis,
    )
    return out.reshape(b, 1, -1) @ p["w_o"], cache


def init_attn_cache(cfg: ArchConfig, mb: int, w_local: int, *, dtype=jnp.bfloat16) -> dict:
    """One layer's decode cache (local shard of width w_local). Positions are
    implicit (ring_positions)."""
    if cfg.attn_kind == "mla":
        return {
            "ckv": jnp.zeros((mb, w_local, cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((mb, w_local, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((mb, w_local, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


# ---------------------------------------------------------------- blocks --


def _ffn_or_moe(cfg: ArchConfig, p: dict, x: jax.Array, *, ep_axis, ep_size, moe_mode) -> jax.Array:
    if cfg.num_experts:
        b, s, d = x.shape
        out, _aux = moe_apply(
            p["moe"],
            x.reshape(b * s, d),
            num_experts=cfg.num_experts,
            k=cfg.experts_per_token,
            router_kind=cfg.router_kind,
            mlp_kind=cfg.mlp_kind,
            ep_axis=ep_axis,
            ep_size=ep_size,
            mode=moe_mode,
        )
        return out.reshape(b, s, d)
    return ffn_apply(p["ffn"], x, kind=cfg.mlp_kind)


def block_train(
    cfg: ArchConfig,
    lp: dict,
    ex: dict,
    h: jax.Array,
    *,
    positions: jax.Array,
    ep_axis: str | None = None,
    ep_size: int = 1,
    moe_mode: str = "gathered",
    kv_block: int = 512,
    attn_backend: str = "blocked",
) -> jax.Array:
    """One attention(+FFN) layer, full-sequence (train/prefill)."""

    def run(h):
        a = attn_apply(
            cfg, lp["attn"], rms_norm(h, lp["ln1"], eps=cfg.norm_eps),
            positions=positions, window=ex["window"], kv_block=kv_block,
            backend=attn_backend,
        )
        if cfg.sandwich_norms:
            a = rms_norm(a, lp["ln1_post"], eps=cfg.norm_eps)
        h = h + a
        f = _ffn_or_moe(
            cfg, lp, rms_norm(h, lp["ln2"], eps=cfg.norm_eps),
            ep_axis=ep_axis, ep_size=ep_size, moe_mode=moe_mode,
        )
        if cfg.sandwich_norms:
            f = rms_norm(f, lp["ln2_post"], eps=cfg.norm_eps)
        return h + f

    return jnp.where(ex["active"] > 0, run(h), h)


def block_decode(
    cfg: ArchConfig,
    lp: dict,
    ex: dict,
    h: jax.Array,
    cache: dict,
    *,
    cur_pos: jax.Array,
    ep_axis: str | None = None,
    ep_size: int = 1,
    moe_mode: str = "gathered",
    seq_axis: str | None = None,
    seq_shards: int = 1,
) -> tuple[jax.Array, dict]:
    def run(h, cache):
        a, cache = attn_decode_apply(
            cfg, lp["attn"], rms_norm(h, lp["ln1"], eps=cfg.norm_eps), cache,
            cur_pos=cur_pos, window=ex["window"], seq_axis=seq_axis, seq_shards=seq_shards,
        )
        if cfg.sandwich_norms:
            a = rms_norm(a, lp["ln1_post"], eps=cfg.norm_eps)
        h = h + a
        f = _ffn_or_moe(
            cfg, lp, rms_norm(h, lp["ln2"], eps=cfg.norm_eps),
            ep_axis=ep_axis, ep_size=ep_size, moe_mode=moe_mode,
        )
        if cfg.sandwich_norms:
            f = rms_norm(f, lp["ln2_post"], eps=cfg.norm_eps)
        return h + f, cache

    h_new, cache_new = run(h, cache)
    active = ex["active"] > 0
    h_out = jnp.where(active, h_new, h)
    cache_out = jax.tree_util.tree_map(
        lambda new, old: jnp.where(active, new, old), cache_new, cache
    )
    return h_out, cache_out


def mamba_block_train(cfg: ArchConfig, lp: dict, ex: dict, h: jax.Array) -> jax.Array:
    def run(h):
        y, _ = mamba2_apply(
            lp["mamba"], rms_norm(h, lp["ln1"], eps=cfg.norm_eps),
            expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            n_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
        )
        return h + y

    return jnp.where(ex["active"] > 0, run(h), h)


def mamba_block_decode(
    cfg: ArchConfig, lp: dict, ex: dict, h: jax.Array, cache: dict
) -> tuple[jax.Array, dict]:
    y, (ssm, conv) = mamba2_apply(
        lp["mamba"], rms_norm(h, lp["ln1"], eps=cfg.norm_eps),
        expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
        n_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
        ssm_state=cache["ssm"], conv_state=cache["conv"], decode=True,
    )
    active = ex["active"] > 0
    h_out = jnp.where(active, h + y, h)
    cache_out = {
        "ssm": jnp.where(active, ssm, cache["ssm"]),
        "conv": jnp.where(active, conv, cache["conv"]),
    }
    return h_out, cache_out


def block_prefill(
    cfg: ArchConfig,
    lp: dict,
    ex: dict,
    h: jax.Array,
    cache: dict,
    *,
    positions: jax.Array,
    ep_axis: str | None = None,
    ep_size: int = 1,
    moe_mode: str = "gathered",
    kv_block: int = 512,
) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also emits this layer's KV cache (the real
    serving prefill). Cache entry shapes match ``init_attn_cache`` with
    w_local == seq_len (positions arrive already valid)."""
    a, entry = attn_apply(
        cfg, lp["attn"], rms_norm(h, lp["ln1"], eps=cfg.norm_eps),
        positions=positions, window=ex["window"], kv_block=kv_block, return_cache=True,
    )
    if cfg.sandwich_norms:
        a = rms_norm(a, lp["ln1_post"], eps=cfg.norm_eps)
    h_new = h + a
    f = _ffn_or_moe(
        cfg, lp, rms_norm(h_new, lp["ln2"], eps=cfg.norm_eps),
        ep_axis=ep_axis, ep_size=ep_size, moe_mode=moe_mode,
    )
    if cfg.sandwich_norms:
        f = rms_norm(f, lp["ln2_post"], eps=cfg.norm_eps)
    h_new = h_new + f

    active = ex["active"] > 0
    new_cache = {
        k_: jnp.where(active, entry[k_].astype(cache[k_].dtype), cache[k_]) for k_ in entry
    }
    return jnp.where(active, h_new, h), new_cache


def mamba_block_prefill(
    cfg: ArchConfig, lp: dict, ex: dict, h: jax.Array, cache: dict
) -> tuple[jax.Array, dict]:
    """Full-sequence mamba forward emitting the final recurrent state."""
    y, (ssm, conv) = mamba2_apply(
        lp["mamba"], rms_norm(h, lp["ln1"], eps=cfg.norm_eps),
        expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
        n_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
        ssm_state=cache["ssm"] * 0.0, conv_state=None, decode=False,
    )
    active = ex["active"] > 0
    return (
        jnp.where(active, h + y, h),
        {
            "ssm": jnp.where(active, ssm, cache["ssm"]),
            "conv": jnp.where(active, conv.astype(cache["conv"].dtype), cache["conv"]),
        },
    )


def init_mamba_cache(cfg: ArchConfig, mb: int, *, dtype=jnp.bfloat16) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((mb, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((mb, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }
