"""Shared transformer primitives: norms, rope/m-rope, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


def normal_init(key: jax.Array, shape, *, scale: float = 0.02, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ rope --


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: broadcastable to
    (..., S) int32. Rotates the full head_dim (half-split convention)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, *, theta: float, sections=(2, 1, 1)
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): positions (3, ..., S) for (t, h, w); the
    head_dim/2 frequency slots are split across the three components in
    ``sections`` proportion."""
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    sizes = [half * s // total for s in sections]
    sizes[-1] = half - sum(sizes[:-1])
    inv = rope_freqs(hd, theta)  # (half,)
    # build a per-slot position by selecting the component for its section
    comp = jnp.repeat(
        jnp.arange(3), jnp.asarray(sizes), total_repeat_length=half
    )  # (half,) in {0,1,2}
    pos = positions[comp]  # (half, ..., S) — gather over leading axis
    pos = jnp.moveaxis(pos, 0, -1)  # (..., S, half)
    ang = pos.astype(jnp.float32) * inv
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
