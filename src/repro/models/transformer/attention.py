"""Attention: blocked online-softmax (flash-style, pure JAX) + decode paths.

``blocked_attention`` scans KV blocks with a running (max, sum, acc) — the
memory-bounded formulation that makes prefill_32k lowerable (scores for the
full (S, S) square are never materialized). Handles GQA head grouping,
sliding windows (gemma2 / long-context fallback), attention softcap, and
arbitrary query/key positions.

``decode_attention`` is the single-new-token path against a KV cache. With
``axis`` set it combines per-shard partial softmax statistics with ``psum``
over a mesh axis — flash-decoding over a sequence-sharded cache, used by
long_500k where batch=1 leaves the data axis otherwise idle (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.transformer.common import softcap as _softcap

_NEG = -2.0e38  # large negative for f32 masking (avoid inf-inf NaNs)


def _mask_bias(q_pos, kv_pos, window: jax.Array | int):
    """(Sq, Skv) additive mask: causal + optional sliding window.

    ``window`` may be a traced scalar (per-layer extras); 0 disables."""
    causal = kv_pos[None, :] <= q_pos[:, None]
    dist_ok = (q_pos[:, None] - kv_pos[None, :]) < jnp.maximum(window, 1)
    use_window = window > 0
    ok = causal & jnp.where(use_window, dist_ok, True)
    return jnp.where(ok, 0.0, _NEG)


def blocked_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,  # (B, Skv, KV, hd)
    *,
    q_pos: jax.Array,  # (Sq,)
    kv_pos: jax.Array,  # (Skv,)
    window: jax.Array | int = 0,
    attn_softcap: float = 0.0,
    kv_block: int = 512,
) -> jax.Array:
    """Causal attention, O(Sq * kv_block) live memory. Returns (B,Sq,H,hd_v).
    K and V head dims may differ (MLA)."""
    b, sq, h, hd = q.shape
    skv, kv_heads = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    g = h // kv_heads
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qg = q.reshape(b, sq, kv_heads, g, hd).astype(jnp.float32) * scale

    nblk = max(1, (skv + kv_block - 1) // kv_block)
    pad = nblk * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(b, nblk, kv_block, kv_heads, hd)
    vb = v.reshape(b, nblk, kv_block, kv_heads, hd_v)
    pb = kv_pos.reshape(nblk, kv_block)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs  # (B, kv_block, KV, hd), ..., (kv_block,)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_i.astype(jnp.float32))
        if attn_softcap > 0:
            s = _softcap(s, attn_softcap)
        bias = _mask_bias(q_pos, p_i, window)  # (Sq, kv_block)
        s = s + bias[None, None, None]
        valid = bias > _NEG / 2
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * valid[None, None, None]
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    from repro.core.vma import match_vma

    m0 = jnp.full((b, kv_heads, g, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kv_heads, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv_heads, g, sq, hd_v), jnp.float32)
    (m0, l0, a0) = match_vma((m0, l0, a0), qg, kb, vb, pb)
    (m, l, acc), _ = lax.scan(
        body,
        (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, Sq, hd_v)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd_v)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, H, hd) — one new token
    k_cache: jax.Array,  # (B, Skv_local, KV, hd)
    v_cache: jax.Array,  # (B, Skv_local, KV, hd)
    kv_pos: jax.Array,  # (Skv_local,) global positions; < 0 marks empty slots
    cur_pos: jax.Array,  # scalar — position of the new token
    *,
    window: jax.Array | int = 0,
    attn_softcap: float = 0.0,
    axis: str | None = None,  # psum partial-softmax over this mesh axis
) -> jax.Array:
    """Single-token attention against a (possibly axis-sharded) cache."""
    b, h, hd = q.shape
    kv_heads = k_cache.shape[2]
    g = h // kv_heads
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = q.reshape(b, kv_heads, g, hd).astype(jnp.float32) * scale

    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache.astype(jnp.float32))
    if attn_softcap > 0:
        s = _softcap(s, attn_softcap)
    ok = (kv_pos >= 0) & (kv_pos <= cur_pos)
    if not isinstance(window, int) or window != 0:
        dist_ok = (cur_pos - kv_pos) < jnp.maximum(window, 1)
        ok = ok & jnp.where(window > 0, dist_ok, True)
    s = jnp.where(ok[None, None, None], s, _NEG)

    m = jnp.max(s, axis=-1)
    if axis is not None:
        m = lax.pmax(m, axis)
    p = jnp.exp(s - m[..., None]) * ok[None, None, None]
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    if axis is not None:
        l = lax.psum(l, axis)
        acc = lax.psum(acc, axis)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)
