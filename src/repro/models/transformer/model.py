"""Full model assembly: embed → SPMD-pipelined block stack → head.

``Topology`` captures the mesh contract of DESIGN.md §5:

  * ``stage_axis`` ("model") — pipeline stages (the paper's technique);
  * ``fsdp_axis`` ("data")   — data parallel + ZeRO-3 param sharding +
    expert parallelism for MoE;
  * ``pod_axis`` ("pod")     — cross-pod data parallelism (optional);
  * ``num_micro``            — GPipe chunks per step.

Parameter layout: block leaves are stacked (num_stages, layers_per_stage,
*dims); ``param_layout`` assigns each leaf fsdp / expert / replicated
placement, used both for pjit in_shardings and for the in-pipeline ZeRO-3
gather. Embedding/head live outside the pipeline (DESIGN.md §5); the head's
vocab dim is sharded over the stage axis and the loss runs in a scan over
batch chunks so full-vocab logits are never materialized at full batch.

Step builders return ``StepArtifacts`` — fn + shardings + abstract inputs —
consumed identically by the training driver, the multi-pod dry-run, and
tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.configs.base import ArchConfig, ShapeConfig, pipeline_padding
from repro.core.spmd_pipe import (
    make_gather_fn,
    make_interleaved_stage,
    make_scanned_stage,
    make_scanned_stage_stateful,
    spmd_pipeline,
    spmd_pipeline_interleaved,
)
from repro.models.transformer import blocks as B
from repro.models.transformer.common import normal_init, rms_norm, softcap
from repro.train import optimizer as opt_lib
from repro.train.losses import softmax_xent


@dataclasses.dataclass(frozen=True)
class Topology:
    num_stages: int  # TOTAL pipeline stages (virtual stages when interleaved)
    stage_axis: str = "model"
    fsdp_axis: str = "data"
    pod_axis: str | None = None
    fsdp_size: int = 1
    num_micro: int = 1
    moe_mode: str = "gathered"  # "gathered" | "a2a"
    zero3: bool = True  # False: blocks replicated over fsdp (ZeRO-1 only)
    attn_backend: str = "blocked"  # "blocked" (jnp) | "flash" (Pallas kernel)
    remat: bool = True
    seq_shard_decode: bool = False  # long_500k: shard KV seq over fsdp axis
    kv_block: int = 512
    loss_chunks: int = 8
    schedule: str = "fill_drain"  # "fill_drain" | "interleaved"
    num_virtual: int = 1  # interleaved: V virtual stages per physical device

    @property
    def data_axes(self):
        return (self.pod_axis, self.fsdp_axis) if self.pod_axis else (self.fsdp_axis,)

    @property
    def ep_enabled(self) -> bool:
        return self.fsdp_size > 1

    @property
    def pipe_devices(self) -> int:
        """Physical devices on the stage axis: num_stages for fill-drain,
        num_stages / num_virtual for the interleaved (circular) schedule."""
        if self.schedule != "interleaved":
            return self.num_stages
        if self.num_virtual < 1 or self.num_stages % self.num_virtual:
            raise ValueError(
                f"num_virtual ({self.num_virtual}) must divide num_stages ({self.num_stages})"
            )
        return self.num_stages // self.num_virtual


# ------------------------------------------------------------- stacking --


def _hybrid_layout(cfg: ArchConfig, num_stages: int) -> tuple[int, int]:
    """(mamba_slots_per_stage, total_slots_per_stage): the attention slot is
    the last of each ``hybrid_attn_every`` group."""
    every = cfg.hybrid_attn_every
    per, _ = pipeline_padding(cfg.num_layers, num_stages)
    per = math.ceil(per / every) * every
    return per - per // every, per


def stacked_shape_plan(cfg: ArchConfig, num_stages: int) -> dict:
    if cfg.arch_type == "hybrid":
        m_per, per = _hybrid_layout(cfg, num_stages)
        return {
            "per_stage": per,
            "mamba_per_stage": m_per,
            "attn_per_stage": per // cfg.hybrid_attn_every,
        }
    per, pad = pipeline_padding(cfg.num_layers, num_stages)
    return {"per_stage": per, "pad": pad}


def init_params(cfg: ArchConfig, key: jax.Array, *, num_stages: int, dtype=jnp.bfloat16) -> dict:
    plan = stacked_shape_plan(cfg, num_stages)
    k_embed, k_head, k_blocks, k_shared, k_mtp = jax.random.split(key, 5)

    params: dict[str, Any] = {
        "embed": normal_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype=dtype),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = normal_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dtype)
    if cfg.mtp:
        params["mtp_proj"] = normal_init(k_mtp, (cfg.d_model, cfg.d_model), dtype=dtype)

    if cfg.arch_type == "hybrid":
        lead = plan["mamba_per_stage"]
        init_one = lambda k: B.init_mamba_block(cfg, k, dtype=dtype)
        params["shared_attn"] = B.init_block(cfg, k_shared, dtype=dtype)
    elif cfg.arch_type == "ssm":
        lead = plan["per_stage"]
        init_one = lambda k: B.init_mamba_block(cfg, k, dtype=dtype)
    else:
        lead = plan["per_stage"]
        init_one = lambda k: B.init_block(cfg, k, dtype=dtype)
    stack = jax.vmap(init_one)(jax.random.split(k_blocks, num_stages * lead))
    params["blocks"] = jax.tree_util.tree_map(
        lambda a: a.reshape(num_stages, lead, *a.shape[1:]), stack
    )
    return params


def make_extras(cfg: ArchConfig, num_stages: int, *, long_context: bool = False) -> dict:
    """Per-layer-slot metadata, stacked (num_stages, slots)."""
    plan = stacked_shape_plan(cfg, num_stages)
    per = plan["per_stage"]
    wins_src = cfg.layer_windows(long_context=long_context)
    if cfg.arch_type == "hybrid":
        every = cfg.hybrid_attn_every
        m_per, a_per = plan["mamba_per_stage"], plan["attn_per_stage"]
        active_m = np.zeros((num_stages, m_per), np.float32)
        active_a = np.zeros((num_stages, a_per), np.float32)
        win_a = np.zeros((num_stages, a_per), np.int32)
        for s in range(num_stages):
            mi = ai = 0
            for i in range(per):
                g = s * per + i
                if (i % every) == (every - 1):
                    active_a[s, ai] = float(g < cfg.num_layers)
                    win_a[s, ai] = wins_src[min(g, cfg.num_layers - 1)]
                    ai += 1
                else:
                    active_m[s, mi] = float(g < cfg.num_layers)
                    mi += 1
        return {
            "mamba": {"active": jnp.asarray(active_m)},
            "attn": {"active": jnp.asarray(active_a), "window": jnp.asarray(win_a)},
        }
    total = num_stages * per
    active = (np.arange(total) < cfg.num_layers).astype(np.float32).reshape(num_stages, per)
    wins = np.asarray(wins_src + [0] * (total - len(wins_src)), np.int32).reshape(num_stages, per)
    return {"active": jnp.asarray(active), "window": jnp.asarray(wins)}


def extras_specs(cfg: ArchConfig, topo: Topology):
    def sp(a):
        return P(topo.stage_axis, None)

    return jax.tree_util.tree_map(sp, make_extras(cfg, topo.num_stages))


# ------------------------------------------------------- sharding layout --


def _path_names(path) -> list[str]:
    return [str(getattr(p_, "key", getattr(p_, "name", p_))) for p_ in path]


def param_layout(cfg: ArchConfig, params_shapes: Any, topo: Topology) -> tuple[Any, Any]:
    """-> (PartitionSpec pytree, ZeRO-3 gather-mask pytree of bool)."""
    fsdp, stage = topo.fsdp_axis, topo.stage_axis
    use_ep = topo.fsdp_size > 1
    use_fsdp = use_ep and topo.zero3

    def spec_for(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        top = names[0]
        if top == "embed":
            # replicated: a vocab-sharded table turns every lookup into a
            # (B,S,D)-sized all-reduce (measured 1 GiB/step on codeqwen);
            # ZeRO-1 shards its optimizer moments instead (moment_specs)
            return P(None, None)
        if top == "head":
            # replicated: the pipeline reduce-scatters its output over the
            # stage axis along seq, so the head matmul is already distributed
            return P(None, None)
        if top in ("final_ln", "mtp_proj"):
            return P(*([None] * len(shape)))
        if top == "shared_attn":
            if use_fsdp and len(shape) >= 2 and shape[0] % topo.fsdp_size == 0:
                return P(fsdp, *([None] * (len(shape) - 1)))
            return P(*([None] * len(shape)))
        # blocks: (S, per, *dims)
        dims = shape[2:]
        if any(n.startswith("we_") for n in names):
            # expert parallelism is orthogonal to ZeRO: stays sharded
            ax = fsdp if use_ep else None
            return P(stage, None, ax, *([None] * (len(dims) - 1)))
        if use_fsdp and len(dims) >= 2 and dims[0] % topo.fsdp_size == 0:
            return P(stage, None, fsdp, *([None] * (len(dims) - 1)))
        return P(stage, None, *([None] * len(dims)))

    def gather_for(path, leaf):
        if not use_fsdp:
            return False
        names = _path_names(path)
        top = names[0]
        if top == "shared_attn":
            return len(leaf.shape) >= 2 and leaf.shape[0] % topo.fsdp_size == 0
        if top != "blocks":
            return False
        if any(n.startswith("we_") for n in names):
            return False  # expert-parallel: stays local
        dims = leaf.shape[2:]
        return len(dims) >= 2 and dims[0] % topo.fsdp_size == 0

    specs = jax.tree_util.tree_map_with_path(spec_for, params_shapes)
    gather = jax.tree_util.tree_map_with_path(gather_for, params_shapes)
    return specs, gather


def moment_specs(cfg: ArchConfig, params_shapes: Any, topo: Topology) -> Any:
    """Optimizer-moment shardings: like param specs, but replicated embed /
    head moments are ZeRO-1 sharded over the fsdp axis (f32 moments are 4×
    the bf16 params — sharding them is the bulk of ZeRO-1's win)."""
    specs, _ = param_layout(cfg, params_shapes, topo)
    if topo.fsdp_size <= 1:
        return specs
    out = dict(specs)
    vocab, d = cfg.vocab_size, cfg.d_model
    if "embed" in out and vocab % topo.fsdp_size == 0:
        out["embed"] = P(topo.fsdp_axis, None)
    elif "embed" in out and d % topo.fsdp_size == 0:
        out["embed"] = P(None, topo.fsdp_axis)
    if "head" in out and vocab % topo.fsdp_size == 0:
        out["head"] = P(None, topo.fsdp_axis)
    return out


# ------------------------------------------------------------ embeddings --


def embed_inputs(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    x = params["embed"][batch["tokens"]]  # (B, S_text, d)
    if cfg.frontend != "none":
        x = jnp.concatenate([batch["frontend_embeds"].astype(x.dtype), x], axis=1)
    return x


def make_positions(cfg: ArchConfig, seq: int) -> jax.Array:
    """(S,) rope positions, or (3, S) for m-rope (image grid then text).
    Concrete (numpy-backed) so pipeline bodies may close over it."""
    if cfg.rope_kind != "mrope":
        return jnp.arange(seq, dtype=jnp.int32)
    s_front = int(seq * cfg.frontend_frac) if cfg.frontend != "none" else 0
    side = max(1, int(math.sqrt(max(s_front, 1))))
    idx = np.arange(seq)
    t = np.where(idx < s_front, 0, idx - s_front + 1)
    hh = np.where(idx < s_front, (idx // side) % side, idx - s_front + 1)
    ww = np.where(idx < s_front, idx % side, idx - s_front + 1)
    return jnp.asarray(np.stack([t, hh, ww]), jnp.int32)


def lm_head_logits(cfg: ArchConfig, params: dict, y: jax.Array) -> jax.Array:
    y = rms_norm(y, params["final_ln"], eps=cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (y @ head).astype(jnp.float32)
    return softcap(logits, cfg.logit_softcap)


# --------------------------------------------------------- stage builders --


def _train_block_fn(cfg, topo, positions):
    """Homogeneous per-layer train body ``block_fn(lp, ex, h) -> h`` shared by
    the fill-drain (``make_scanned_stage``) and interleaved
    (``make_interleaved_stage``) stage builders. Hybrid stacks are
    heterogeneous and keep their dedicated ``_hybrid_stage``."""
    if cfg.arch_type == "hybrid":
        raise NotImplementedError("hybrid stacks have no homogeneous block fn")
    if cfg.arch_type == "ssm":
        return lambda lp, ex, h: B.mamba_block_train(cfg, lp, ex, h)
    ep = bool(cfg.num_experts) and topo.ep_enabled
    return lambda lp, ex, h: B.block_train(
        cfg, lp, ex, h, positions=positions,
        ep_axis=topo.fsdp_axis if ep else None, ep_size=topo.fsdp_size if ep else 1,
        moe_mode=topo.moe_mode, kv_block=topo.kv_block,
        attn_backend=topo.attn_backend,
    )


def _stage_fn_train(cfg, topo, blocks_local, shared, extras_local, gather_mask, positions):
    gfn = make_gather_fn(gather_mask["blocks"], topo.fsdp_axis) if topo.fsdp_size > 1 else None
    if cfg.arch_type == "hybrid":
        return _hybrid_stage(
            cfg, topo, blocks_local, shared, extras_local, gather_mask, positions,
            mode="train",
        )
    block = _train_block_fn(cfg, topo, positions)
    return make_scanned_stage(block, blocks_local, extras_local, gather_fn=gfn)


def _stage_fn_train_interleaved(cfg, topo, blocks_local, extras_local, gather_mask, positions):
    """Interleaved twin of ``_stage_fn_train``: ``blocks_local`` leaves are
    (num_virtual, layers_per_stage, ...) — this device's circularly-placed
    virtual-stage slices."""
    if cfg.arch_type == "hybrid":
        raise NotImplementedError(
            "interleaved schedule requires a homogeneous block stack; "
            "zamba2-style hybrid stages run fill_drain"
        )
    gfn = make_gather_fn(gather_mask["blocks"], topo.fsdp_axis) if topo.fsdp_size > 1 else None
    block = _train_block_fn(cfg, topo, positions)
    return make_interleaved_stage(block, blocks_local, extras_local, gather_fn=gfn)


def _stage_fn_prefill(cfg, topo, blocks_local, shared, extras_local, gather_mask, positions):
    gfn = make_gather_fn(gather_mask["blocks"], topo.fsdp_axis) if topo.fsdp_size > 1 else None
    if cfg.arch_type == "ssm":
        return make_scanned_stage_stateful(
            lambda lp, ex, h, c: B.mamba_block_prefill(cfg, lp, ex, h, c),
            blocks_local, extras_local, gather_fn=gfn,
        )
    if cfg.arch_type == "hybrid":
        return _hybrid_stage(
            cfg, topo, blocks_local, shared, extras_local, gather_mask, positions,
            mode="prefill",
        )
    ep = bool(cfg.num_experts) and topo.ep_enabled
    block = lambda lp, ex, h, c: B.block_prefill(
        cfg, lp, ex, h, c, positions=positions,
        ep_axis=topo.fsdp_axis if ep else None, ep_size=topo.fsdp_size if ep else 1,
        moe_mode=topo.moe_mode, kv_block=topo.kv_block,
    )
    return make_scanned_stage_stateful(block, blocks_local, extras_local, gather_fn=gfn)


def _stage_fn_decode(cfg, topo, blocks_local, shared, extras_local, gather_mask, cur_pos):
    gfn = make_gather_fn(gather_mask["blocks"], topo.fsdp_axis) if topo.fsdp_size > 1 else None
    seq_axis = topo.fsdp_axis if topo.seq_shard_decode else None
    seq_shards = topo.fsdp_size if topo.seq_shard_decode else 1
    if cfg.arch_type == "ssm":
        return make_scanned_stage_stateful(
            lambda lp, ex, h, c: B.mamba_block_decode(cfg, lp, ex, h, c),
            blocks_local, extras_local, gather_fn=gfn,
        )
    if cfg.arch_type == "hybrid":
        return _hybrid_stage(
            cfg, topo, blocks_local, shared, extras_local, gather_mask, cur_pos,
            mode="decode",
        )
    # batch-replicated decode (long_500k) runs EP with replicated tokens
    ep = bool(cfg.num_experts) and topo.ep_enabled
    block = lambda lp, ex, h, c: B.block_decode(
        cfg, lp, ex, h, c, cur_pos=cur_pos,
        ep_axis=topo.fsdp_axis if ep else None, ep_size=topo.fsdp_size if ep else 1,
        moe_mode="replicated" if (ep and topo.seq_shard_decode) else topo.moe_mode,
        seq_axis=seq_axis, seq_shards=seq_shards,
    )
    return make_scanned_stage_stateful(block, blocks_local, extras_local, gather_fn=gfn)


def _hybrid_stage(cfg, topo, m_params, shared, extras_local, gather_mask, pos_or_cur, *, mode):
    """zamba2 stage: groups of mamba slots, each followed by one application
    of the weight-shared attention block. State (prefill/decode):
    {'mamba': leaves (m_per, ...), 'attn': leaves (a_per, ...)}."""
    m_ex = extras_local["mamba"]
    a_ex = extras_local["attn"]
    n_attn = a_ex["active"].shape[0]
    m_total = jax.tree_util.tree_leaves(m_params)[0].shape[0]
    m_grp = m_total // max(n_attn, 1)
    gfn = make_gather_fn(gather_mask["blocks"], topo.fsdp_axis) if topo.fsdp_size > 1 else None
    sgfn = (
        make_gather_fn(gather_mask["shared_attn"], topo.fsdp_axis)
        if topo.fsdp_size > 1
        else None
    )
    seq_axis = topo.fsdp_axis if topo.seq_shard_decode else None
    seq_shards = topo.fsdp_size if topo.seq_shard_decode else 1

    def slice_group(tree, g):
        return jax.tree_util.tree_map(lambda a: a[g * m_grp : (g + 1) * m_grp], tree)

    def stage_fn(h, state_mb):
        new_state = {"mamba": [], "attn": []} if mode != "train" else None

        def one_mamba(c, xs):
            if mode == "train":
                lp, ex = xs
                if gfn is not None:
                    lp = gfn(lp)
                return B.mamba_block_train(cfg, lp, ex, c), None
            lp, ex, cache_i = xs
            if gfn is not None:
                lp = gfn(lp)
            fn = B.mamba_block_prefill if mode == "prefill" else B.mamba_block_decode
            return fn(cfg, lp, ex, c, cache_i)

        for g in range(max(n_attn, 1)):
            grp, grp_ex = slice_group(m_params, g), slice_group(m_ex, g)
            if mode == "train":
                h, _ = lax.scan(one_mamba, h, (grp, grp_ex))
            else:
                grp_cache = slice_group(state_mb["mamba"], g)
                h, cache_out = lax.scan(one_mamba, h, (grp, grp_ex, grp_cache))
                new_state["mamba"].append(cache_out)
            if n_attn:
                sp = sgfn(shared) if sgfn is not None else shared
                ex_g = jax.tree_util.tree_map(lambda a: a[g], a_ex)
                if mode == "train":
                    h = B.block_train(cfg, sp, ex_g, h, positions=pos_or_cur, kv_block=topo.kv_block)
                elif mode == "prefill":
                    a_cache = jax.tree_util.tree_map(lambda a: a[g], state_mb["attn"])
                    h, a_out = B.block_prefill(
                        cfg, sp, ex_g, h, a_cache, positions=pos_or_cur, kv_block=topo.kv_block
                    )
                    new_state["attn"].append(a_out)
                else:
                    a_cache = jax.tree_util.tree_map(lambda a: a[g], state_mb["attn"])
                    h, a_out = B.block_decode(
                        cfg, sp, ex_g, h, a_cache, cur_pos=pos_or_cur,
                        seq_axis=seq_axis, seq_shards=seq_shards,
                    )
                    new_state["attn"].append(a_out)
        if mode == "train":
            return h, state_mb
        stacked = {
            "mamba": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_state["mamba"]
            ),
            "attn": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *new_state["attn"]),
        }
        return h, stacked

    return stage_fn


# ------------------------------------------------------------ step fns --


@dataclasses.dataclass
class StepArtifacts:
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: tuple  # ShapeDtypeStructs matching fn's signature
    meta: dict


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def _abstract_params(cfg: ArchConfig, topo: Topology, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: init_params(cfg, k, num_stages=topo.num_stages, dtype=dtype),
        jax.random.PRNGKey(0),
    )


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, topo: Topology) -> tuple[dict, dict]:
    """(abstract batch, PartitionSpec tree) for one step's input batch."""
    bsz, seq = shape.global_batch, shape.seq_len
    data = topo.data_axes if bsz > 1 else (None,)
    d_axes = data[0] if len(data) == 1 else data
    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((bsz,), jnp.int32),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        specs = {"tokens": P(d_axes) if bsz > 1 else P(None), "pos": P()}
        return batch, specs
    s_front = int(seq * cfg.frontend_frac) if cfg.frontend != "none" else 0
    s_text = seq - s_front + (1 if shape.kind == "train" else 0)  # train carries labels
    batch = {"tokens": jax.ShapeDtypeStruct((bsz, s_text), jnp.int32)}
    specs = {"tokens": P(d_axes, None)}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.ShapeDtypeStruct((bsz, s_front, cfg.d_model), jnp.bfloat16)
        specs["frontend_embeds"] = P(d_axes, None, None)
    return batch, specs


def _labels_from_batch(cfg: ArchConfig, batch: dict, seq: int) -> tuple[jax.Array, jax.Array]:
    """(labels (B, S), mask (B, S)) aligned with the concatenated sequence."""
    toks = batch["tokens"]
    bsz = toks.shape[0]
    s_front = seq - (toks.shape[1] - 1)
    labels_text = toks[:, 1:]
    if s_front > 0:
        pad = jnp.full((bsz, s_front), -1, jnp.int32)
        labels = jnp.concatenate([pad, labels_text], axis=1)
    else:
        labels = labels_text
    return labels, (labels >= 0).astype(jnp.float32)


def make_train_step(
    cfg: ArchConfig,
    topo: Topology,
    shape: ShapeConfig,
    mesh,
    *,
    lr: float = 1e-4,
    dtype=jnp.bfloat16,
) -> StepArtifacts:
    seq = shape.seq_len
    positions = make_positions(cfg, seq)
    extras = make_extras(cfg, topo.num_stages)
    aparams = _abstract_params(cfg, topo, dtype)
    specs, gather_mask = param_layout(cfg, aparams, topo)
    optimizer = opt_lib.adam(lr)
    aopt = jax.eval_shape(optimizer.init, aparams)
    m_specs = moment_specs(cfg, aparams, topo)
    opt_specs = opt_lib.AdamState(step=P(), mu=m_specs, nu=m_specs)
    abatch, bspecs = batch_specs(cfg, shape, topo)
    ex_specs = jax.tree_util.tree_map(lambda a: P(topo.stage_axis, None), extras)
    xspec = P(topo.data_axes, None, None)

    if topo.schedule not in ("fill_drain", "interleaved"):
        raise ValueError(
            f"Topology.schedule must be 'fill_drain' or 'interleaved', got {topo.schedule!r}"
        )
    interleaved = topo.schedule == "interleaved" and topo.num_stages > 1
    if interleaved:
        D, V = topo.pipe_devices, topo.num_virtual
        if topo.num_micro < D:
            raise ValueError(
                f"interleaved schedule needs num_micro ({topo.num_micro}) >= "
                f"physical stage devices ({D})"
            )
        # circular placement: device d hosts virtual stages {v·D + d}; the
        # stacked (S, per, ...) leaves are row-permuted so the contiguous
        # V-row shard each device receives under P(stage_axis, ...) is
        # exactly its virtual-stage slices
        circ = np.array([v * D + d for d in range(D) for v in range(V)])
        extras = jax.tree_util.tree_map(lambda a: a[circ], extras)

    def loss_fn(params, batch):
        inputs = dict(batch, tokens=batch["tokens"][:, :-1])
        x = embed_inputs(cfg, params, inputs).astype(dtype)
        x = lax.with_sharding_constraint(x, NamedSharding(mesh, xspec))

        def pipe_body(blocks, shared, ex, x_local):
            b_local = x_local.shape[0]
            x_mb = x_local.reshape(topo.num_micro, b_local // topo.num_micro, seq, -1)
            if interleaved:
                # blocks/ex arrive as this device's (V, per, ...) shard
                stage_fn = _stage_fn_train_interleaved(
                    cfg, topo, blocks, ex, gather_mask, positions
                )
                out = spmd_pipeline_interleaved(
                    stage_fn, x_mb, stage_axis=topo.stage_axis,
                    num_devices=D, num_virtual=V, remat=topo.remat,
                    vma_refs=(blocks, shared),
                )
                return out.reshape(b_local, seq, -1)
            blocks_local = jax.tree_util.tree_map(lambda a: a[0], blocks)
            ex_local = jax.tree_util.tree_map(lambda a: a[0], ex)
            stage_fn = _stage_fn_train(
                cfg, topo, blocks_local, shared, ex_local, gather_mask, positions
            )
            # reduce-scatter output along seq over the stage axis: the LM
            # head + loss then run stage-sharded instead of 16×-replicated
            out, _ = spmd_pipeline(
                stage_fn, x_mb, stage_axis=topo.stage_axis,
                num_stages=topo.num_stages, remat=topo.remat, scatter_dim=2,
                vma_refs=(blocks_local, shared),
            )
            return out.reshape(b_local, seq // topo.num_stages, -1)

        shared = params.get("shared_attn", ())
        shared_spec = specs.get("shared_attn", ())
        blocks_in = params["blocks"]
        if interleaved:
            blocks_in = jax.tree_util.tree_map(lambda a: a[circ], blocks_in)
            # outputs are psum-broadcast (not seq-scattered) on the ring
            yspec = P(topo.data_axes, None, None)
        else:
            yspec = P(topo.data_axes, topo.stage_axis, None)
        y = compat.shard_map(
            pipe_body,
            mesh=mesh,
            in_specs=(specs["blocks"], shared_spec, ex_specs, xspec),
            out_specs=yspec,
        )(blocks_in, shared, extras, x)

        labels, mask = _labels_from_batch(cfg, batch, seq)
        bsz = y.shape[0]
        chunks = min(topo.loss_chunks, bsz)
        # chunk along the MINOR batch dim so each device keeps its own rows
        # (a major-dim chunking would all-to-all the whole activation)
        chunk_spec = NamedSharding(mesh, P(None, topo.data_axes, topo.stage_axis, None))
        yc = lax.with_sharding_constraint(
            jnp.swapaxes(y.reshape(bsz // chunks, chunks, seq, -1), 0, 1), chunk_spec
        )
        lc = jnp.swapaxes(labels.reshape(bsz // chunks, chunks, seq), 0, 1)
        mc = jnp.swapaxes(mask.reshape(bsz // chunks, chunks, seq), 0, 1)
        logit_spec = NamedSharding(mesh, P(topo.data_axes, topo.stage_axis, None))

        @jax.checkpoint
        def chunk_loss(carry, xs):
            yi, li, mi = xs
            logits = lax.with_sharding_constraint(lm_head_logits(cfg, params, yi), logit_spec)
            # masked mean accumulated as (sum, count)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
            s, c = carry
            s = s + ((lse - ll) * mi).sum()
            c = c + mi.sum()
            if cfg.mtp:
                # multi-token prediction aux head (deepseek-v3): predict t+2
                y2 = (yi @ params["mtp_proj"]).astype(yi.dtype)
                logits2 = lax.with_sharding_constraint(
                    lm_head_logits(cfg, params, y2), logit_spec
                )[:, :-1]
                li2 = jnp.maximum(li[:, 1:], 0)
                mi2 = mi[:, 1:] * mi[:, :-1]
                lse2 = jax.nn.logsumexp(logits2, axis=-1)
                ll2 = jnp.take_along_axis(logits2, li2[..., None], axis=-1)[..., 0]
                s = s + 0.3 * ((lse2 - ll2) * mi2).sum()
            return (s, c), None

        (s, c), _ = lax.scan(chunk_loss, (jnp.zeros(()), jnp.zeros(())), (yc, lc, mc))
        return s / jnp.maximum(c, 1.0)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    in_sh = (_named(mesh, specs), _named(mesh, opt_specs), _named(mesh, bspecs))
    out_sh = (_named(mesh, specs), _named(mesh, opt_specs), {"loss": NamedSharding(mesh, P())})
    return StepArtifacts(
        fn=train_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_inputs=(aparams, aopt, abatch),
        meta={"positions": positions, "extras": extras, "optimizer": optimizer,
              "specs": specs, "gather_mask": gather_mask},
    )


# --------------------------------------------------------------- caches --


def cache_plan(cfg: ArchConfig, topo: Topology, shape: ShapeConfig) -> dict:
    """Static cache geometry for decode/prefill shapes."""
    bsz = shape.global_batch
    nm = topo.num_micro
    b_mb = max(bsz // nm, 1)
    if shape.kind == "decode":
        if topo.seq_shard_decode:
            w_total = cfg.long_context_window if not cfg.is_subquadratic() else cfg.long_context_window
            # windows already reflected in layer_windows(long_context=True);
            # cache width = max window, sharded over fsdp
            w_total = max(w for w in cfg.layer_windows(long_context=True)) if cfg.arch_type not in ("ssm",) else 0
            w_local = w_total // topo.fsdp_size if w_total else 0
        else:
            w_total = shape.seq_len + 16
            w_local = w_total
    else:
        w_total = w_local = shape.seq_len
    return {"b_mb": b_mb, "w_total": w_total, "w_local": w_local, "nm": nm}


def abstract_cache(cfg: ArchConfig, topo: Topology, shape: ShapeConfig, *, dtype=jnp.bfloat16):
    """(abstract cache pytree, PartitionSpec tree). Global leaves are
    (num_stages, num_micro, slots, b_mb, ...)."""
    plan = cache_plan(cfg, topo, shape)
    sp = stacked_shape_plan(cfg, topo.num_stages)
    nm, b_mb, w_local = plan["nm"], plan["b_mb"], plan["w_local"]
    S = topo.num_stages
    stage, fsdp = topo.stage_axis, topo.fsdp_axis
    batch_axes = topo.data_axes if shape.global_batch > 1 else None
    seq_ax = fsdp if topo.seq_shard_decode else None

    def attn_leaf(inner_shape, *, has_batch=True, seq_dim=None, dt=dtype):
        shp = (S, nm, slots, b_mb, *inner_shape) if has_batch else (S, nm, slots, *inner_shape)
        ax = [stage, None, None]
        if has_batch:
            ax.append(batch_axes)
        for i in range(len(inner_shape)):
            ax.append(seq_ax if (seq_dim is not None and i == seq_dim) else None)
        return jax.ShapeDtypeStruct(shp, dt), P(*ax)

    def build_attn(slots_):
        nonlocal slots
        slots = slots_
        if cfg.attn_kind == "mla":
            c, cs = attn_leaf((w_local, cfg.kv_lora_rank + cfg.qk_rope_head_dim), seq_dim=0)
            return {"ckv": c}, {"ckv": cs}
        k, ks = attn_leaf((w_local, cfg.num_kv_heads, cfg.head_dim), seq_dim=0)
        v, vs = attn_leaf((w_local, cfg.num_kv_heads, cfg.head_dim), seq_dim=0)
        return {"k": k, "v": v}, {"k": ks, "v": vs}

    def build_mamba(slots_):
        nonlocal slots
        slots = slots_
        d_in = cfg.ssm_expand * cfg.d_model
        h = d_in // cfg.ssm_head_dim
        conv_dim = d_in + 2 * cfg.ssm_state
        s1, sp1 = attn_leaf((h, cfg.ssm_head_dim, cfg.ssm_state), dt=jnp.float32)
        c1, cp1 = attn_leaf((cfg.ssm_conv_width - 1, conv_dim))
        return {"ssm": s1, "conv": c1}, {"ssm": sp1, "conv": cp1}

    slots = 0
    if cfg.arch_type == "ssm":
        cache, cspec = build_mamba(sp["per_stage"])
    elif cfg.arch_type == "hybrid":
        m_cache, m_spec = build_mamba(sp["mamba_per_stage"])
        a_cache, a_spec = build_attn(sp["attn_per_stage"])
        cache = {"mamba": m_cache, "attn": a_cache}
        cspec = {"mamba": m_spec, "attn": a_spec}
    else:
        cache, cspec = build_attn(sp["per_stage"])
    return cache, cspec


def make_serve_step(
    cfg: ArchConfig,
    topo: Topology,
    shape: ShapeConfig,
    mesh,
    *,
    dtype=jnp.bfloat16,
) -> StepArtifacts:
    """One decode step: next-token logits + cache update, pipelined."""
    extras = make_extras(cfg, topo.num_stages, long_context=topo.seq_shard_decode)
    aparams = _abstract_params(cfg, topo, dtype)
    specs, gather_mask = param_layout(cfg, aparams, topo)
    abatch, bspecs = batch_specs(cfg, shape, topo)
    acache, cache_specs = abstract_cache(cfg, topo, shape, dtype=dtype)
    ex_specs = jax.tree_util.tree_map(lambda a: P(topo.stage_axis, None), extras)
    bsz = shape.global_batch
    data = topo.data_axes if bsz > 1 else None
    xspec = P(data, None, None)

    def serve_step(params, cache, batch):
        x = params["embed"][batch["tokens"]][:, None, :].astype(dtype)  # (B,1,d)
        x = lax.with_sharding_constraint(x, NamedSharding(mesh, xspec))
        cur_pos = batch["pos"]

        def pipe_body(blocks, shared, ex, cache_in, x_local, pos_scalar):
            blocks_local = jax.tree_util.tree_map(lambda a: a[0], blocks)
            ex_local = jax.tree_util.tree_map(lambda a: a[0], ex)
            cache_local = jax.tree_util.tree_map(lambda a: a[0], cache_in)
            stage_fn = _stage_fn_decode(
                cfg, topo, blocks_local, shared, ex_local, gather_mask, pos_scalar
            )
            b_local = x_local.shape[0]
            mb = b_local // topo.num_micro
            x_mb = x_local.reshape(topo.num_micro, mb, 1, -1)
            out, new_cache = spmd_pipeline(
                stage_fn, x_mb, stage_axis=topo.stage_axis,
                num_stages=topo.num_stages, state=cache_local, remat=False,
                vma_refs=(blocks_local, shared),
            )
            new_cache = jax.tree_util.tree_map(lambda a: a[None], new_cache)
            return out.reshape(b_local, 1, -1), new_cache

        shared = params.get("shared_attn", ())
        shared_spec = specs.get("shared_attn", ())
        # batch-replicated decode (long_500k): the cache is genuinely
        # invariant over idle mesh axes but shard_map cannot infer it
        # through the gathered-param dataflow — skip the static check.
        y, cache = compat.shard_map(
            pipe_body,
            mesh=mesh,
            in_specs=(specs["blocks"], shared_spec, ex_specs, cache_specs, xspec, P()),
            out_specs=(xspec, cache_specs),
            check_vma=False,
        )(params["blocks"], shared, extras, cache, x, cur_pos)

        logits = lm_head_logits(cfg, params, y[:, 0])  # (B, V)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    in_sh = (_named(mesh, specs), _named(mesh, cache_specs), _named(mesh, bspecs))
    out_sh = (NamedSharding(mesh, P(data) if bsz > 1 else P(None)), _named(mesh, cache_specs))
    return StepArtifacts(
        fn=serve_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_inputs=(aparams, acache, abatch),
        meta={"extras": extras, "specs": specs, "cache_specs": cache_specs},
    )


def make_prefill_step(
    cfg: ArchConfig,
    topo: Topology,
    shape: ShapeConfig,
    mesh,
    *,
    dtype=jnp.bfloat16,
) -> StepArtifacts:
    """Full-sequence prefill: last-token logits + populated KV cache."""
    seq = shape.seq_len
    positions = make_positions(cfg, seq)
    extras = make_extras(cfg, topo.num_stages)
    aparams = _abstract_params(cfg, topo, dtype)
    specs, gather_mask = param_layout(cfg, aparams, topo)
    abatch, bspecs = batch_specs(cfg, shape, topo)
    acache, cache_specs = abstract_cache(cfg, topo, shape, dtype=dtype)
    ex_specs = jax.tree_util.tree_map(lambda a: P(topo.stage_axis, None), extras)
    xspec = P(topo.data_axes, None, None)

    def prefill_step(params, cache, batch):
        x = embed_inputs(cfg, params, batch).astype(dtype)
        x = lax.with_sharding_constraint(x, NamedSharding(mesh, xspec))

        def pipe_body(blocks, shared, ex, cache_in, x_local):
            blocks_local = jax.tree_util.tree_map(lambda a: a[0], blocks)
            ex_local = jax.tree_util.tree_map(lambda a: a[0], ex)
            cache_local = jax.tree_util.tree_map(lambda a: a[0], cache_in)
            stage_fn = _stage_fn_prefill(
                cfg, topo, blocks_local, shared, ex_local, gather_mask, positions
            )
            b_local = x_local.shape[0]
            mb = b_local // topo.num_micro
            x_mb = x_local.reshape(topo.num_micro, mb, seq, -1)
            out, new_cache = spmd_pipeline(
                stage_fn, x_mb, stage_axis=topo.stage_axis,
                num_stages=topo.num_stages, state=cache_local, remat=topo.remat,
                scatter_dim=2, vma_refs=(blocks_local, shared),
            )
            new_cache = jax.tree_util.tree_map(lambda a: a[None], new_cache)
            return out.reshape(b_local, seq // topo.num_stages, -1), new_cache

        shared = params.get("shared_attn", ())
        shared_spec = specs.get("shared_attn", ())
        yspec = P(topo.data_axes, topo.stage_axis, None)
        y, cache = compat.shard_map(
            pipe_body,
            mesh=mesh,
            in_specs=(specs["blocks"], shared_spec, ex_specs, cache_specs, xspec),
            out_specs=(yspec, cache_specs),
        )(params["blocks"], shared, extras, cache, x)

        logits = lm_head_logits(cfg, params, y[:, -1])  # (B, V)
        return logits, cache

    in_sh = (_named(mesh, specs), _named(mesh, cache_specs), _named(mesh, bspecs))
    out_sh = (
        NamedSharding(mesh, P(topo.data_axes, None)),
        _named(mesh, cache_specs),
    )
    return StepArtifacts(
        fn=prefill_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_inputs=(aparams, acache, abatch),
        meta={"extras": extras, "specs": specs, "cache_specs": cache_specs},
    )
