from repro.models.gnn.layers import (
    gat_layer,
    gcn_layer,
    graph_conv_layer,
    gated_graph_conv_layer,
    init_gat,
    init_gcn,
    init_graph_conv,
    init_gated_graph_conv,
)
from repro.models.gnn.net import build_paper_gat, build_gnn, GNNModel

__all__ = [
    "gat_layer",
    "gcn_layer",
    "graph_conv_layer",
    "gated_graph_conv_layer",
    "init_gat",
    "init_gcn",
    "init_graph_conv",
    "init_gated_graph_conv",
    "build_paper_gat",
    "build_gnn",
    "GNNModel",
]
