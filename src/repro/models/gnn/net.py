"""The paper's sequential GAT network (§6), as a stage-able layer sequence.

The model is expressed as an explicit ``list[SeqLayer]`` — the same shape as
the paper's ``nn.Sequential`` — so the GPipe engine in ``repro.core`` can
partition it with a ``balance`` array exactly like torchgpipe does.

Forward structure (paper §6, fixed across all experiments):

    dropout(0.6) -> GAT(8 heads, concat, attn-dropout 0.6) -> ELU
    -> dropout(0.6) -> GAT(8 heads, average, attn-dropout 0.6) -> log_softmax
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.graphs.data import GraphBatch
from repro.models.gnn import layers as L


@dataclasses.dataclass(frozen=True)
class SeqLayer:
    """One element of a sequential model: init + pure apply.

    ``apply(params, graph, h, rng, train) -> h`` — the graph rides along the
    carry, mirroring the paper's (node-indices, features) tuple workaround,
    minus the workaround: pytrees make it first-class.
    """

    name: str
    init: Callable[[jax.Array], Any]
    apply: Callable[[Any, GraphBatch, jax.Array, jax.Array | None, bool], jax.Array]


def _dropout_layer(rate: float, name: str) -> SeqLayer:
    return SeqLayer(
        name=name,
        init=lambda key: {},
        apply=lambda p, g, h, rng, train: L.dropout(h, rate, rng, train),
    )


def _elu_layer() -> SeqLayer:
    return SeqLayer("elu", lambda key: {}, lambda p, g, h, rng, train: jax.nn.elu(h))


def _log_softmax_layer() -> SeqLayer:
    return SeqLayer(
        "log_softmax", lambda key: {}, lambda p, g, h, rng, train: jax.nn.log_softmax(h, axis=-1)
    )


def _gat_seq_layer(
    name: str,
    in_dim: int,
    out_dim: int,
    *,
    heads: int,
    concat: bool,
    attn_dropout: float,
    backend: str,
) -> SeqLayer:
    def apply(p, g, h, rng, train):
        return L.gat_layer(
            p,
            g,
            h,
            concat=concat,
            attn_dropout=attn_dropout if backend != "pallas" else 0.0,
            rng=rng,
            train=train,
            backend=backend,
        )

    return SeqLayer(name, lambda key: L.init_gat(key, in_dim, out_dim, heads=heads), apply)


def _gcn_seq_layer(name: str, in_dim: int, out_dim: int, *, backend: str) -> SeqLayer:
    return SeqLayer(
        name,
        lambda key: L.init_gcn(key, in_dim, out_dim),
        lambda p, g, h, rng, train: L.gcn_layer(p, g, h, backend=backend),
    )


@dataclasses.dataclass(frozen=True)
class GNNModel:
    layers: tuple[SeqLayer, ...]
    in_dim: int
    out_dim: int

    def init_params(self, key: jax.Array) -> list:
        keys = jax.random.split(key, len(self.layers))
        return [layer.init(k) for layer, k in zip(self.layers, keys)]

    def apply(
        self,
        params: list,
        g: GraphBatch,
        h: jax.Array | None = None,
        *,
        rng: jax.Array | None = None,
        train: bool = False,
    ) -> jax.Array:
        h = g.features if h is None else h
        rngs = (
            jax.random.split(rng, len(self.layers))
            if rng is not None
            else [None] * len(self.layers)
        )
        for layer, p, r in zip(self.layers, params, rngs):
            h = layer.apply(p, g, h, r, train)
        return h

    def num_params(self, params: list) -> int:
        return sum(x.size for x in jax.tree_util.tree_leaves(params))


def build_paper_gat(
    num_features: int,
    num_classes: int,
    *,
    hidden_per_head: int = 8,
    heads: int = 8,
    feat_dropout: float = 0.6,
    attn_dropout: float = 0.6,
    backend: str = "padded",
) -> GNNModel:
    """The exact model of paper §6 (GAT defaults of Veličković et al.)."""
    layers = (
        _dropout_layer(feat_dropout, "dropout_0"),
        _gat_seq_layer(
            "gat_0",
            num_features,
            hidden_per_head,
            heads=heads,
            concat=True,
            attn_dropout=attn_dropout,
            backend=backend,
        ),
        _elu_layer(),
        _dropout_layer(feat_dropout, "dropout_1"),
        _gat_seq_layer(
            "gat_1",
            hidden_per_head * heads,
            num_classes,
            heads=heads,
            concat=False,
            attn_dropout=attn_dropout,
            backend=backend,
        ),
        _log_softmax_layer(),
    )
    return GNNModel(layers=layers, in_dim=num_features, out_dim=num_classes)


def build_gnn(
    kind: str,
    num_features: int,
    num_classes: int,
    *,
    hidden: int = 64,
    depth: int = 2,
    backend: str = "padded",
) -> GNNModel:
    """Generic builders for the future-work §8 model zoo (GCN / GraphConv /
    GatedGraphConv), assembled in the same sequential form."""
    if kind == "gat":
        return build_paper_gat(num_features, num_classes, backend=backend)

    layers: list[SeqLayer] = []
    dims = [num_features] + [hidden] * (depth - 1) + [num_classes]
    for i in range(depth):
        din, dout = dims[i], dims[i + 1]
        if kind == "gcn":
            layers.append(_gcn_seq_layer(f"gcn_{i}", din, dout, backend=backend))
        elif kind == "graphconv":
            layers.append(
                SeqLayer(
                    f"graphconv_{i}",
                    (lambda din=din, dout=dout: (lambda key: L.init_graph_conv(key, din, dout)))(),
                    lambda p, g, h, rng, train: L.graph_conv_layer(p, g, h, backend=backend),
                )
            )
        elif kind == "gatedgraphconv":
            if din != dout:
                layers.append(_gcn_seq_layer(f"proj_{i}", din, dout, backend=backend))
            layers.append(
                SeqLayer(
                    f"ggc_{i}",
                    (lambda dout=dout: (lambda key: L.init_gated_graph_conv(key, dout)))(),
                    lambda p, g, h, rng, train: L.gated_graph_conv_layer(p, g, h, backend=backend),
                )
            )
        else:
            raise KeyError(f"unknown GNN kind {kind!r}")
        if i < depth - 1:
            layers.append(_elu_layer())
    layers.append(_log_softmax_layer())
    return GNNModel(layers=tuple(layers), in_dim=num_features, out_dim=num_classes)
