"""The paper's sequential GAT network (§6), as a stage-able layer sequence.

The model is expressed as an explicit ``list[SeqLayer]`` — the same shape as
the paper's ``nn.Sequential`` — so the GPipe engine in ``repro.core`` can
partition it with a ``balance`` array exactly like torchgpipe does.

Forward structure (paper §6, fixed across all experiments):

    dropout(0.6) -> GAT(8 heads, concat, attn-dropout 0.6) -> ELU
    -> dropout(0.6) -> GAT(8 heads, average, attn-dropout 0.6) -> log_softmax
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.graphs.data import GraphBatch
from repro.models.gnn import layers as L


@dataclasses.dataclass(frozen=True)
class SeqLayer:
    """One element of a sequential model: init + pure apply.

    ``apply(params, graph, h, rng, train) -> h`` — the graph rides along the
    carry, mirroring the paper's (node-indices, features) tuple workaround,
    minus the workaround: pytrees make it first-class.
    """

    name: str
    init: Callable[[jax.Array], Any]
    apply: Callable[[Any, GraphBatch, jax.Array, jax.Array | None, bool], jax.Array]


def _dropout_layer(rate: float, name: str) -> SeqLayer:
    return SeqLayer(
        name=name,
        init=lambda key: {},
        apply=lambda p, g, h, rng, train: L.dropout(h, rate, rng, train),
    )


def _elu_layer() -> SeqLayer:
    return SeqLayer("elu", lambda key: {}, lambda p, g, h, rng, train: jax.nn.elu(h))


def _log_softmax_layer() -> SeqLayer:
    return SeqLayer(
        "log_softmax", lambda key: {}, lambda p, g, h, rng, train: jax.nn.log_softmax(h, axis=-1)
    )


def _gat_seq_layer(
    name: str,
    in_dim: int,
    out_dim: int,
    *,
    heads: int,
    concat: bool,
    attn_dropout: float,
    backend: str,
) -> SeqLayer:
    def apply(p, g, h, rng, train):
        # attn_dropout passes through unchanged: the pallas backend validates
        # up-front in gat_layer and raises a clear error instead of this
        # wrapper silently zeroing the rate (eval / rate-0 paths are fine).
        return L.gat_layer(
            p,
            g,
            h,
            concat=concat,
            attn_dropout=attn_dropout,
            rng=rng,
            train=train,
            backend=backend,
        )

    return SeqLayer(name, lambda key: L.init_gat(key, in_dim, out_dim, heads=heads), apply)


def _gcn_seq_layer(name: str, in_dim: int, out_dim: int, *, backend: str) -> SeqLayer:
    return SeqLayer(
        name,
        lambda key: L.init_gcn(key, in_dim, out_dim),
        lambda p, g, h, rng, train: L.gcn_layer(p, g, h, backend=backend),
    )


@dataclasses.dataclass(frozen=True)
class GNNModel:
    layers: tuple[SeqLayer, ...]
    in_dim: int
    out_dim: int

    def init_params(self, key: jax.Array) -> list:
        keys = jax.random.split(key, len(self.layers))
        return [layer.init(k) for layer, k in zip(self.layers, keys)]

    def apply(
        self,
        params: list,
        g: GraphBatch,
        h: jax.Array | None = None,
        *,
        rng: jax.Array | None = None,
        train: bool = False,
    ) -> jax.Array:
        h = g.features if h is None else h
        rngs = (
            jax.random.split(rng, len(self.layers))
            if rng is not None
            else [None] * len(self.layers)
        )
        for layer, p, r in zip(self.layers, params, rngs):
            h = layer.apply(p, g, h, r, train)
        return h

    def num_params(self, params: list) -> int:
        return sum(x.size for x in jax.tree_util.tree_leaves(params))


def activation_widths(model: GNNModel, params: list, graph: GraphBatch) -> list[int]:
    """Feature width at every layer boundary: ``widths[i]`` is the input dim
    of layer ``i``, ``widths[len(layers)]`` the model output dim. Computed by
    shape-tracing each layer (no FLOPs), so it works for any SeqLayer mix."""
    g_struct = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), graph
    )
    n = graph.num_nodes
    h = jax.ShapeDtypeStruct((n, model.in_dim), jnp.float32)
    widths = [model.in_dim]
    for layer, p in zip(model.layers, params):
        h = jax.eval_shape(lambda p_, g_, h_, L=layer: L.apply(p_, g_, h_, None, False), p, g_struct, h)
        widths.append(h.shape[-1])
    return widths


def travel_width(bounds: list[tuple[int, int]], widths: list[int]) -> int:
    """Wire width of the traveling activation: the widest *stage-boundary*
    dim (every stage's output width). The model input width is excluded —
    stage 0 reads features by chunk id, they never ride the wire."""
    return max(widths[hi] for _, hi in bounds)


def make_gnn_stage(
    model: GNNModel,
    params: list,
    bounds: list[tuple[int, int]],
    widths: list[int],
    graph: GraphBatch,
    rng: jax.Array,
    *,
    stage_axis: str,
    train: bool = True,
):
    """Adapter from a sequential GNN to an SPMD pipeline stage for
    ``repro.core.spmd_pipe.spmd_pipeline``.

    The device's stage index (``lax.axis_index``) selects — via ``lax.switch``
    — the branch that closes a contiguous ``SeqLayer`` slice ``[lo, hi)`` over
    its stage params. Because inter-stage activation widths differ (features →
    hidden → classes), the traveling activation is padded to the widest stage
    boundary (``travel_width``); each branch slices its true input width and
    re-pads its output, so every branch has the uniform shape ``ppermute``
    requires.

    The travel pytree is ``{"h", "chunk"}`` — deliberately minimal. The
    stacked per-chunk subgraphs (``graph``, leaves (chunks, n_pad, ...)) are
    closed over as a replicated constant and every branch dynamic-slices its
    chunk's subgraph by the *traveling chunk id*: the graph rides the
    pipeline keyed by an int32 scalar instead of re-``ppermute``-ing the
    neighbor/mask/norm arrays (and the feature matrix) every tick. Stage 0
    reads its input activation from the sliced chunk's features the same way.

    Per-(chunk, layer) dropout keys are derived from the traveling chunk id
    exactly as the host engine derives them
    (``split(fold_in(rng, chunk), n_layers)``), keeping the two engines'
    stochastic training bitwise-comparable. The key derivation is hoisted
    out of the ``switch`` into the stage body: branches that consume
    fold_in/split asymmetrically break ``cond``'s partial-eval when the
    pipeline is linearized (jax <= 0.4.x), whereas key *use* inside a
    branch is fine.
    """
    n_layers = len(model.layers)
    d_travel = travel_width(bounds, widths)

    def branch(s: int):
        lo, hi = bounds[s]

        def apply_slice(operand):
            travel, rngs = operand
            c = travel["chunk"]
            g = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, c, 0, keepdims=False), graph
            )
            h = g.features if lo == 0 else travel["h"][:, : widths[lo]]
            for i in range(lo, hi):
                h = model.layers[i].apply(params[i], g, h, rngs[i], train)
            return jnp.pad(h, ((0, 0), (0, d_travel - h.shape[-1])))

        return apply_slice

    branches = [branch(s) for s in range(len(bounds))]

    def stage_fn(travel, state_mb):
        s = jax.lax.axis_index(stage_axis)
        rngs = jax.random.split(jax.random.fold_in(rng, travel["chunk"]), n_layers)
        h_out = jax.lax.switch(s, branches, (travel, rngs))
        return dict(travel, h=h_out), state_mb

    return stage_fn


def make_gnn_stage_slices(
    model: GNNModel,
    bounds: list[tuple[int, int]],
    widths: list[int],
    graph: GraphBatch,
    rng: jax.Array,
    *,
    train: bool = True,
    chunk_offset=0,
):
    """Params-EXPLICIT per-stage slice functions for the scheduled executor
    (``spmd_pipeline_scheduled``), which differentiates stages explicitly
    via ``jax.vjp`` instead of AD-ing through the whole pipeline program.

    Returns ``slices[s](params, chunk, h_in) -> h_out``: apply the
    contiguous ``SeqLayer`` slice ``[lo, hi)`` of stage ``s`` to chunk
    ``chunk`` (a traced int32 — the stacked subgraphs are closed over and
    dynamic-sliced by it, exactly like ``make_gnn_stage``). ``params`` is
    the FULL layer-params list so ``jax.vjp(f, params, h_in)`` yields a
    full-params gradient pytree with zeros outside the stage's layers — the
    uniform structure ``lax.switch`` and the cross-stage psum reduction
    need. ``h_in``/``h_out`` are padded to the uniform wire width
    (``travel_width``); stage 0 ignores ``h_in`` and reads the chunk's
    features, so its input cotangent comes out zero automatically.

    Per-(chunk, layer) dropout keys are derived exactly as the host engine
    derives them (``split(fold_in(rng, chunk), n_layers)``), keeping every
    schedule×engine combination bitwise-comparable. Under data parallelism
    the chunk id traveling the pipeline is LOCAL to the replica while the
    host engine folds the GLOBAL chunk id; ``chunk_offset`` (a traced scalar
    — each replica passes ``axis_index("data") * chunks_per_replica``) is
    added before the fold so the keys stay bitwise identical. It offsets
    ONLY the rng derivation: graph slicing keeps the local id, because each
    replica's stacked graph shard is indexed locally.
    """
    n_layers = len(model.layers)
    d_travel = travel_width(bounds, widths)

    def make(s: int):
        lo, hi = bounds[s]

        def apply_slice(params, chunk, h_in):
            g = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, chunk, 0, keepdims=False),
                graph,
            )
            rngs = jax.random.split(jax.random.fold_in(rng, chunk + chunk_offset), n_layers)
            h = g.features if lo == 0 else h_in[:, : widths[lo]]
            for i in range(lo, hi):
                h = model.layers[i].apply(params[i], g, h, rngs[i], train)
            return jnp.pad(h, ((0, 0), (0, d_travel - h.shape[-1])))

        return apply_slice

    return [make(s) for s in range(len(bounds))]


def make_gnn_stage_slices_bw(
    model: GNNModel,
    bounds: list[tuple[int, int]],
    widths: list[int],
    graph: GraphBatch,
    rng: jax.Array,
    *,
    train: bool = True,
    loss_ct=None,
    chunk_offset=0,
):
    """Split-backward (zero-bubble) halves of ``make_gnn_stage_slices``: the
    stage backward is cut along the vjp's two cotangent outputs so the
    scheduled executor can run them in separate ticks.

    Returns ``(b_fns, w_fns)``:

      * ``b_fns[s](params, chunk, h_in, ct) -> (d_h, residual, loss_sum,
        count)`` — the **B** (input-grad) half: differentiate the stage wrt
        its *input only* (``jax.vjp`` of ``h -> slice(params, chunk, h)``,
        so XLA dead-code-eliminates the weight-grad work) and return the
        upstream cotangent immediately — the only product on the pipeline's
        critical path — plus the residual the deferred W half needs: the
        ``(h_in, ct_applied)`` pair, two uniform wire-shaped buffers (kept
        as a tuple, not stacked — the executor stashes the halves
        separately so no concat/slice materializes per tick).
        At the LAST stage ``loss_ct(y, chunk) -> (ct, loss_sum, count)``
        derives the applied cotangent from the stage's own output (the
        pipeline's loss head); other stages consume the wire ``ct`` and
        report zeros.
      * ``w_fns[s](params, chunk, residual) -> d_params`` — the **W**
        (weight-grad) half: re-materialize the stage forward from the
        residual's banked input (GPipe's recompute discipline) and
        differentiate wrt the FULL params list, yielding the same
        zero-outside-the-stage gradient pytree the fused backward produces
        — float-identical, since both halves replay the identical primal
        and cotangent chains.

    Stage 0 ignores ``h_in`` (features are read by chunk id), so its B half
    is almost entirely dead code — mirroring zb-h1's accounting, where the
    first stage's critical-path backward is free.
    """
    slices = make_gnn_stage_slices(
        model, bounds, widths, graph, rng, train=train, chunk_offset=chunk_offset
    )
    zero = jnp.zeros((), jnp.float32)

    def make(s: int):
        fwd = slices[s]
        last = s == len(bounds) - 1 and loss_ct is not None

        def b_fn(params, chunk, h_in, ct):
            y, vjp = jax.vjp(lambda h: fwd(params, chunk, h), h_in)
            if last:
                ct, loss_sum, count = loss_ct(y, chunk)
            else:
                loss_sum = count = zero
            (d_h,) = vjp(ct)
            return d_h, (h_in, ct), loss_sum, count

        def w_fn(params, chunk, residual):
            h_in, ct = residual
            _, vjp = jax.vjp(lambda p: fwd(p, chunk, h_in), params)
            (d_params,) = vjp(ct)
            return d_params

        return b_fn, w_fn

    pairs = [make(s) for s in range(len(bounds))]
    return [b for b, _ in pairs], [w for _, w in pairs]


def build_paper_gat(
    num_features: int,
    num_classes: int,
    *,
    hidden_per_head: int = 8,
    heads: int = 8,
    feat_dropout: float = 0.6,
    attn_dropout: float = 0.6,
    backend: str = "padded",
) -> GNNModel:
    """The exact model of paper §6 (GAT defaults of Veličković et al.)."""
    layers = (
        _dropout_layer(feat_dropout, "dropout_0"),
        _gat_seq_layer(
            "gat_0",
            num_features,
            hidden_per_head,
            heads=heads,
            concat=True,
            attn_dropout=attn_dropout,
            backend=backend,
        ),
        _elu_layer(),
        _dropout_layer(feat_dropout, "dropout_1"),
        _gat_seq_layer(
            "gat_1",
            hidden_per_head * heads,
            num_classes,
            heads=heads,
            concat=False,
            attn_dropout=attn_dropout,
            backend=backend,
        ),
        _log_softmax_layer(),
    )
    return GNNModel(layers=layers, in_dim=num_features, out_dim=num_classes)


def build_imbalanced_gcn(
    num_features: int,
    num_classes: int,
    *,
    hidden: tuple[int, ...] = (256, 256, 32, 32, 32, 32),
    backend: str = "padded",
) -> GNNModel:
    """A deliberately cost-IMBALANCED GCN stack — the partitioner's benchmark
    and test fixture. The leading layers are an order of magnitude wider than
    the tail, so a layer-count-uniform ``balance`` packs the heavy layers
    into one stage (which then sets every pipeline tick) while the profiled
    partitioner isolates them: with the default widths and 4 stages,
    ``uniform_balance`` groups the two 256-wide convs together and the
    cost-aware split pulls them apart."""
    dims = [num_features, *hidden, num_classes]
    layers = tuple(
        _gcn_seq_layer(f"gcn_{i}", dims[i], dims[i + 1], backend=backend)
        for i in range(len(dims) - 1)
    ) + (_log_softmax_layer(),)
    return GNNModel(layers=layers, in_dim=num_features, out_dim=num_classes)


def build_gnn(
    kind: str,
    num_features: int,
    num_classes: int,
    *,
    hidden: int = 64,
    depth: int = 2,
    backend: str = "padded",
) -> GNNModel:
    """Generic builders for the future-work §8 model zoo (GCN / GraphConv /
    GatedGraphConv), assembled in the same sequential form."""
    if kind == "gat":
        return build_paper_gat(num_features, num_classes, backend=backend)

    layers: list[SeqLayer] = []
    dims = [num_features] + [hidden] * (depth - 1) + [num_classes]
    for i in range(depth):
        din, dout = dims[i], dims[i + 1]
        if kind == "gcn":
            layers.append(_gcn_seq_layer(f"gcn_{i}", din, dout, backend=backend))
        elif kind == "graphconv":
            layers.append(
                SeqLayer(
                    f"graphconv_{i}",
                    (lambda din=din, dout=dout: (lambda key: L.init_graph_conv(key, din, dout)))(),
                    lambda p, g, h, rng, train: L.graph_conv_layer(p, g, h, backend=backend),
                )
            )
        elif kind == "gatedgraphconv":
            if din != dout:
                layers.append(_gcn_seq_layer(f"proj_{i}", din, dout, backend=backend))
            layers.append(
                SeqLayer(
                    f"ggc_{i}",
                    (lambda dout=dout: (lambda key: L.init_gated_graph_conv(key, dout)))(),
                    lambda p, g, h, rng, train: L.gated_graph_conv_layer(p, g, h, backend=backend),
                )
            )
        else:
            raise KeyError(f"unknown GNN kind {kind!r}")
        if i < depth - 1:
            layers.append(_elu_layer())
    layers.append(_log_softmax_layer())
    return GNNModel(layers=tuple(layers), in_dim=num_features, out_dim=num_classes)
