"""GNN layers in JAX over the padded-neighbor layout.

Every layer comes as an ``init_*`` (params pytree) plus a pure ``*_layer``
apply function. Three aggregation backends exist:

  * ``padded`` — gather neighbors along the (n, max_deg) layout; the
    TPU-native default.
  * ``dense``  — materialize a masked (n, n) adjacency and matmul; only for
    small graphs, used by benchmarks as the "second framework" analogue of
    the paper's DGL-vs-PyG comparison.
  * ``pallas`` — the fused Pallas kernels in repro.kernels (GAT + GCN).

The GAT layer follows the paper §2.1 / Veličković et al. exactly:
``alpha_ij ∝ exp(LeakyReLU(a^T [Wh_i || Wh_j]))`` with multi-head concat or
average, attention dropout, masked softmax over the neighborhood.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graphs.data import BucketedGraphBatch, GraphBatch

_NEG_INF = -1e9


def _bucket_fields(g: BucketedGraphBatch):
    return (
        tuple(b.neighbors for b in g.buckets),
        tuple(b.norm for b in g.buckets),
        tuple(b.mask for b in g.buckets),
        tuple(b.row_node for b in g.buckets),
    )


def glorot(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    fan_in, fan_out = shape[-2], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def dropout(x: jax.Array, rate: float, rng: jax.Array | None, train: bool) -> jax.Array:
    if not train or rate <= 0.0 or rng is None:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def _dense_adj(g: GraphBatch) -> jax.Array:
    """Masked (n, n) adjacency (with self-loops) from the padded layout."""
    n = g.num_nodes
    adj = jnp.zeros((n, n), dtype=bool)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], g.neighbors.shape)
    return adj.at[rows, g.neighbors].max(g.mask)


def _dense_norm(g: GraphBatch) -> jax.Array:
    n = g.num_nodes
    out = jnp.zeros((n, n), dtype=g.norm.dtype)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], g.neighbors.shape)
    return out.at[rows, g.neighbors].max(g.norm)


# ---------------------------------------------------------------- GCN ----


def init_gcn(key: jax.Array, in_dim: int, out_dim: int) -> dict:
    return {"w": glorot(key, (in_dim, out_dim)), "b": jnp.zeros((out_dim,))}


def gcn_layer(params: dict, g: GraphBatch, h: jax.Array, *, backend: str = "padded") -> jax.Array:
    """H' = Â H W + b with symmetric normalization (Kipf & Welling)."""
    hw = h @ params["w"]
    if backend == "dense":
        agg = _dense_norm(g) @ hw
    elif backend == "pallas":
        if isinstance(g, BucketedGraphBatch):
            from repro.kernels.spmm.ops import bucketed_spmm

            nbrs, nrms, _, _ = _bucket_fields(g)
            agg = bucketed_spmm(hw, nbrs, nrms, g.gather_rows)
        else:
            from repro.kernels.spmm.ops import padded_spmm

            agg = padded_spmm(hw, g.neighbors, g.norm)
    else:
        gathered = hw[g.neighbors]  # (n, max_deg, out)
        agg = jnp.einsum("nd,ndo->no", g.norm, gathered)
    return agg + params["b"]


# ---------------------------------------------------------------- GAT ----


def init_gat(key: jax.Array, in_dim: int, out_dim: int, *, heads: int = 8) -> dict:
    kw, ks, kd = jax.random.split(key, 3)
    return {
        "w": glorot(kw, (heads, in_dim, out_dim)),
        "a_src": glorot(ks, (heads, out_dim, 1))[..., 0],
        "a_dst": glorot(kd, (heads, out_dim, 1))[..., 0],
        "b": jnp.zeros((heads, out_dim)),
    }


def gat_layer(
    params: dict,
    g: GraphBatch,
    h: jax.Array,
    *,
    concat: bool = True,
    attn_dropout: float = 0.0,
    negative_slope: float = 0.2,
    rng: jax.Array | None = None,
    train: bool = False,
    backend: str = "padded",
) -> jax.Array:
    """Multi-head GAT layer (paper eq. 3–4). Returns (n, heads*out) if concat
    else (n, out) (head average, the paper's prediction layer)."""
    if backend == "pallas" and attn_dropout > 0.0 and train and rng is not None:
        # validated up-front, BEFORE any kernel work: the fused
        # softmax-aggregate kernel cannot apply per-edge dropout inside the
        # softmax. Eval (train=False) and rate-0 paths are unaffected.
        raise ValueError(
            "pallas GAT backend is deterministic and cannot apply attention "
            f"dropout (attn_dropout={attn_dropout}) during training; set "
            "attn_dropout=0.0 or use the 'padded'/'dense' backend"
        )
    heads, _, out_dim = params["w"].shape
    hw = jnp.einsum("nf,hfo->nho", h, params["w"])  # (n, H, F')
    s_src = jnp.einsum("nho,ho->nh", hw, params["a_src"])  # importance of i as dst
    s_dst = jnp.einsum("nho,ho->nh", hw, params["a_dst"])  # importance of j as src

    if backend == "pallas":
        if isinstance(g, BucketedGraphBatch):
            from repro.kernels.gat_edge.ops import bucketed_gat_aggregate

            nbrs, _, msks, rows = _bucket_fields(g)
            out = bucketed_gat_aggregate(
                hw, s_src, s_dst, nbrs, msks, rows, g.gather_rows,
                negative_slope,
            )
        else:
            from repro.kernels.gat_edge.ops import gat_aggregate

            out = gat_aggregate(
                hw, s_src, s_dst, g.neighbors, g.mask, negative_slope=negative_slope
            )
    elif backend == "dense":
        adj = _dense_adj(g)  # (n, n)
        scores = s_src[:, None, :] + s_dst[None, :, :]  # (n, n, H)
        scores = jax.nn.leaky_relu(scores, negative_slope)
        scores = jnp.where(adj[..., None], scores, _NEG_INF)
        alpha = jax.nn.softmax(scores, axis=1)
        alpha = alpha * adj[..., None]
        alpha = dropout(alpha, attn_dropout, rng, train)
        out = jnp.einsum("njh,jho->nho", alpha, hw)
    else:
        nbr_scores = s_dst[g.neighbors]  # (n, max_deg, H)
        scores = jax.nn.leaky_relu(s_src[:, None, :] + nbr_scores, negative_slope)
        scores = jnp.where(g.mask[..., None], scores, _NEG_INF)
        alpha = jax.nn.softmax(scores, axis=1)
        alpha = alpha * g.mask[..., None]  # zero out fully-padded rows
        alpha = dropout(alpha, attn_dropout, rng, train)
        out = jnp.einsum("ndh,ndho->nho", alpha, hw[g.neighbors])

    out = out + params["b"]
    if concat:
        return out.reshape(out.shape[0], heads * out_dim)
    return out.mean(axis=1)


# ---------------------------------------------------------- GraphConv ----


def init_graph_conv(key: jax.Array, in_dim: int, out_dim: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_self": glorot(k1, (in_dim, out_dim)),
        "w_nbr": glorot(k2, (in_dim, out_dim)),
        "b": jnp.zeros((out_dim,)),
    }


def graph_conv_layer(params: dict, g: GraphBatch, h: jax.Array, *, backend: str = "padded") -> jax.Array:
    """GraphConv (Morris et al.): H' = H W1 + (A H) W2 + b (no self in A)."""
    nbr_mask = g.mask.at[:, 0].set(False)  # slot 0 is the self-loop
    if backend == "dense":
        adj = _dense_adj(g) & ~jnp.eye(g.num_nodes, dtype=bool)
        agg = adj.astype(h.dtype) @ h
    else:
        agg = jnp.einsum("nd,ndf->nf", nbr_mask.astype(h.dtype), h[g.neighbors])
    return h @ params["w_self"] + agg @ params["w_nbr"] + params["b"]


# ----------------------------------------------------- GatedGraphConv ----


def init_gated_graph_conv(key: jax.Array, dim: int) -> dict:
    # five independent keys: w_h and u_h previously shared ks[3], making the
    # GRU candidate's input and recurrent projections identical at init. The
    # propagation step count is the layer's ``steps`` kwarg (a static trace
    # constant), not a params entry.
    ks = jax.random.split(key, 5)
    return {
        "w_msg": glorot(ks[0], (dim, dim)),
        "w_zr": glorot(ks[1], (dim, 2 * dim)),
        "u_zr": glorot(ks[2], (dim, 2 * dim)),
        "w_h": glorot(ks[3], (dim, dim)),
        "u_h": glorot(ks[4], (dim, dim)),
    }


def gated_graph_conv_layer(
    params: dict, g: GraphBatch, h: jax.Array, *, steps: int = 3, backend: str = "padded"
) -> jax.Array:
    """GatedGraphConv (Li et al. 2015): GRU state updates over aggregated
    messages for a fixed number of propagation steps."""
    nbr_mask = g.mask.astype(h.dtype)

    def step(state, _):
        msg = state @ params["w_msg"]
        if backend == "dense":
            agg = _dense_adj(g).astype(h.dtype) @ msg
        else:
            agg = jnp.einsum("nd,ndf->nf", nbr_mask, msg[g.neighbors])
        zr = jax.nn.sigmoid(agg @ params["w_zr"] + state @ params["u_zr"])
        z, r = jnp.split(zr, 2, axis=-1)
        cand = jnp.tanh(agg @ params["w_h"] + (r * state) @ params["u_h"])
        return (1.0 - z) * state + z * cand, None

    out, _ = jax.lax.scan(step, h, None, length=steps)
    return out
