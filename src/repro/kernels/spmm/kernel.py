"""Padded-neighbor SpMM — Pallas TPU kernel with scalar-prefetched gather.

The TPU adaptation of the paper's DGL/PyG CSR SpMM (DESIGN.md §3): the
feature matrix stays resident in VMEM (citation-scale graphs: ≤ ~20k × 64
floats ≈ 5 MB, well under the ~128 MB v5e VMEM), the padded neighbor-index
matrix rides in scalar-prefetch (SMEM) so row indices can drive dynamic VMEM
row loads — the Pallas TPU idiom for data-dependent access. Grid over node
tiles; each tile accumulates its D weighted neighbor rows.

Two entry points share the inner kernel:

* ``padded_spmm_kernel`` — square layout, one row of ``neighbors`` per row
  of ``hw`` (the original padded path).
* ``bucket_spmm_kernel`` — a degree bucket's rectangular tile: ``neighbors``
  has R rows of width W indexing into an (N, F) feature matrix with R ≠ N.
  One launch per bucket; the per-bucket width is what makes aggregation
  cost follow the degree distribution instead of the global max degree.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime_interpret


def _kernel(nbr_ref, norm_ref, hw_ref, out_ref, *, block_n: int, max_deg: int):
    i = pl.program_id(0)

    def row_body(t, _):
        gi = i * block_n + t  # global node id (rows padded to grid)
        acc = jnp.zeros((hw_ref.shape[1],), jnp.float32)

        def nbr_body(j, acc):
            idx = nbr_ref[gi, j]  # scalar from SMEM prefetch
            row = pl.load(hw_ref, (pl.dslice(idx, 1), slice(None)))[0]
            w = norm_ref[t, j]
            return acc + w.astype(jnp.float32) * row.astype(jnp.float32)

        acc = jax.lax.fori_loop(0, max_deg, nbr_body, acc)
        out_ref[t, :] = acc.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, block_n, row_body, 0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _spmm_call(
    hw: jax.Array,  # (N, F)
    neighbors: jax.Array,  # (R, W) int32, rows indexing into hw
    norm: jax.Array,  # (R, W)
    *,
    block_n: int,
    interpret: bool,
) -> jax.Array:
    n, f = hw.shape
    r, w = neighbors.shape
    pad = (-r) % block_n
    nbr_p = jnp.pad(neighbors, ((0, pad), (0, 0)))
    norm_p = jnp.pad(norm, ((0, pad), (0, 0)))
    r_pad = r + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i, nbr: (i, 0)),
            pl.BlockSpec((n, f), lambda i, nbr: (0, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((block_n, f), lambda i, nbr: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n, max_deg=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r_pad, f), hw.dtype),
        interpret=interpret,
    )(nbr_p, norm_p, hw)
    return out[:r]


def padded_spmm_kernel(
    hw: jax.Array,  # (N, F)
    neighbors: jax.Array,  # (N, D) int32
    norm: jax.Array,  # (N, D)
    *,
    block_n: int = 256,
    interpret: bool | None = None,  # None -> kernels.runtime_interpret()
) -> jax.Array:
    if interpret is None:
        interpret = runtime_interpret()
    return _spmm_call(hw, neighbors, norm, block_n=block_n, interpret=interpret)


def bucket_spmm_kernel(
    hw: jax.Array,  # (N, F) — full feature matrix, original node numbering
    neighbors: jax.Array,  # (R, W) int32 — one degree bucket's rows
    norm: jax.Array,  # (R, W)
    *,
    block_r: int = 128,
    interpret: bool | None = None,
) -> jax.Array:  # (R, F)
    if interpret is None:
        interpret = runtime_interpret()
    return _spmm_call(hw, neighbors, norm, block_n=block_r, interpret=interpret)
