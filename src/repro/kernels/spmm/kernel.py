"""Padded-neighbor SpMM — Pallas TPU kernel with scalar-prefetched gather.

The TPU adaptation of the paper's DGL/PyG CSR SpMM (DESIGN.md §3): the
feature matrix stays resident in VMEM (citation-scale graphs: ≤ ~20k × 64
floats ≈ 5 MB, well under the ~128 MB v5e VMEM), the padded neighbor-index
matrix rides in scalar-prefetch (SMEM) so row indices can drive dynamic VMEM
row loads — the Pallas TPU idiom for data-dependent access. Grid over node
tiles; each tile accumulates its D weighted neighbor rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(nbr_ref, norm_ref, hw_ref, out_ref, *, block_n: int, max_deg: int):
    i = pl.program_id(0)

    def row_body(t, _):
        gi = i * block_n + t  # global node id (rows padded to grid)
        acc = jnp.zeros((hw_ref.shape[1],), jnp.float32)

        def nbr_body(j, acc):
            idx = nbr_ref[gi, j]  # scalar from SMEM prefetch
            row = pl.load(hw_ref, (pl.dslice(idx, 1), slice(None)))[0]
            w = norm_ref[t, j]
            return acc + w.astype(jnp.float32) * row.astype(jnp.float32)

        acc = jax.lax.fori_loop(0, max_deg, nbr_body, acc)
        out_ref[t, :] = acc.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, block_n, row_body, 0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def padded_spmm_kernel(
    hw: jax.Array,  # (N, F)
    neighbors: jax.Array,  # (N, D) int32
    norm: jax.Array,  # (N, D)
    *,
    block_n: int = 256,
    interpret: bool = True,  # CPU container: interpret; TPU target: False
) -> jax.Array:
    n, f = hw.shape
    d = neighbors.shape[1]
    pad = (-n) % block_n
    nbr_p = jnp.pad(neighbors, ((0, pad), (0, 0)))
    norm_p = jnp.pad(norm, ((0, pad), (0, 0)))
    n_pad = n + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, nbr: (i, 0)),
            pl.BlockSpec((n, f), lambda i, nbr: (0, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((block_n, f), lambda i, nbr: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n, max_deg=d),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, f), hw.dtype),
        interpret=interpret,
    )(nbr_p, norm_p, hw)
    return out[:n]
