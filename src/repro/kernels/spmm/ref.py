"""Pure-jnp oracle for padded-neighbor SpMM (GCN aggregation):

    out[i] = Σ_j norm[i, j] · hw[neighbors[i, j]]

and its degree-bucketed variant, where rows live in per-bucket dense tiles
of geometric widths and ``gather_rows`` maps original node order into the
bucket-concatenated row space.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def padded_spmm_ref(hw: jax.Array, neighbors: jax.Array, norm: jax.Array) -> jax.Array:
    """hw: (N, F); neighbors: (N, D) int32; norm: (N, D) (0 on padding)."""
    return jnp.einsum("nd,ndf->nf", norm, hw[neighbors])


def bucketed_spmm_ref(
    hw: jax.Array,  # (N, F)
    neighbors: tuple[jax.Array, ...],  # per bucket (R_b, W_b) int32
    norms: tuple[jax.Array, ...],  # per bucket (R_b, W_b), 0 on padding
    gather_rows: jax.Array,  # (N,) int32 into the bucket-concat row space
) -> jax.Array:  # (N, F)
    """Per-bucket weighted gather, concatenated and permuted back to node order."""
    outs = [jnp.einsum("rw,rwf->rf", nrm, hw[nbr]) for nbr, nrm in zip(neighbors, norms)]
    return jnp.concatenate(outs, axis=0)[gather_rows]
