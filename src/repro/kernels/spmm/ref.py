"""Pure-jnp oracle for padded-neighbor SpMM (GCN aggregation):

    out[i] = Σ_j norm[i, j] · hw[neighbors[i, j]]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def padded_spmm_ref(hw: jax.Array, neighbors: jax.Array, norm: jax.Array) -> jax.Array:
    """hw: (N, F); neighbors: (N, D) int32; norm: (N, D) (0 on padding)."""
    return jnp.einsum("nd,ndf->nf", norm, hw[neighbors])
