"""Public op for the padded SpMM kernel (+ custom VJP via the oracle)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.spmm.kernel import padded_spmm_kernel
from repro.kernels.spmm.ref import padded_spmm_ref


@jax.custom_vjp
def padded_spmm(hw, neighbors, norm):
    """out[i] = Σ_j norm[i,j] · hw[neighbors[i,j]] — Pallas forward."""
    return padded_spmm_kernel(hw, neighbors, norm)


def _fwd(hw, neighbors, norm):
    return padded_spmm(hw, neighbors, norm), (hw, neighbors, norm)


def _bwd(res, ct):
    hw, neighbors, norm = res
    _, vjp = jax.vjp(lambda a, w: padded_spmm_ref(a, neighbors, w), hw, norm)
    d_hw, d_norm = vjp(ct)
    return d_hw, None, d_norm


padded_spmm.defvjp(_fwd, _bwd)
