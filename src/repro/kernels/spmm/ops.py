"""Public ops for the SpMM kernels (+ custom VJP via the oracle).

``padded_spmm`` aggregates over the square padded-neighbor layout;
``bucketed_spmm`` over the degree-bucketed layout (tuples of per-bucket
dense tiles, see ``graphs.partition.degree_bucketed_layout``). Forward
routing follows ``kernels.use_kernel_forward()``: the Pallas kernel on TPU
(or when ``REPRO_PALLAS_FORCE_KERNEL=1``), the jnp oracle elsewhere —
interpret-mode Pallas on CPU is an emulator, not a measurement of the
layout. Backward is always the oracle vjp (kernel-forward/oracle-backward
pairing), so gradients are identical under either routing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import use_kernel_forward
from repro.kernels.spmm.kernel import bucket_spmm_kernel, padded_spmm_kernel
from repro.kernels.spmm.ref import bucketed_spmm_ref, padded_spmm_ref


@jax.custom_vjp
def padded_spmm(hw, neighbors, norm):
    """out[i] = Σ_j norm[i,j] · hw[neighbors[i,j]]."""
    if use_kernel_forward():
        return padded_spmm_kernel(hw, neighbors, norm)
    return padded_spmm_ref(hw, neighbors, norm)


def _fwd(hw, neighbors, norm):
    return padded_spmm(hw, neighbors, norm), (hw, neighbors, norm)


def _bwd(res, ct):
    hw, neighbors, norm = res
    _, vjp = jax.vjp(lambda a, w: padded_spmm_ref(a, neighbors, w), hw, norm)
    d_hw, d_norm = vjp(ct)
    return d_hw, None, d_norm


padded_spmm.defvjp(_fwd, _bwd)


@jax.custom_vjp
def bucketed_spmm(hw, neighbors, norms, gather_rows):
    """Degree-bucketed GCN aggregation back in original node order.

    ``neighbors``/``norms`` are equal-length tuples of per-bucket
    ``(R_b, W_b)`` tiles (indices into ``hw``'s rows); ``gather_rows`` maps
    node i to its row in the bucket concatenation. One kernel launch per
    non-empty bucket.
    """
    if use_kernel_forward():
        outs = []
        for nbr, nrm in zip(neighbors, norms):
            if nbr.shape[0] == 0:
                outs.append(jnp.zeros((0, hw.shape[1]), hw.dtype))
            else:
                outs.append(bucket_spmm_kernel(hw, nbr, nrm))
        return jnp.concatenate(outs, axis=0)[gather_rows]
    return bucketed_spmm_ref(hw, neighbors, norms, gather_rows)


def _bucketed_fwd(hw, neighbors, norms, gather_rows):
    return bucketed_spmm(hw, neighbors, norms, gather_rows), (hw, neighbors, norms, gather_rows)


def _bucketed_bwd(res, ct):
    hw, neighbors, norms, gather_rows = res
    _, vjp = jax.vjp(lambda a, w: bucketed_spmm_ref(a, neighbors, w, gather_rows), hw, norms)
    d_hw, d_norms = vjp(ct)
    return d_hw, tuple(None for _ in neighbors), d_norms, None


bucketed_spmm.defvjp(_bucketed_fwd, _bucketed_bwd)
