"""Mamba2 SSD chunk scan — Pallas TPU kernel.

The state-space-duality formulation maps exactly onto the MXU (DESIGN.md §7):
per chunk of Q tokens, three dense matmuls —

    CB      = C · Bᵀ                       (Q×N)·(N×Q)
    Y_intra = (CB ∘ causal-decay) · (x·dt) (Q×Q)·(Q×P)
    Y_inter = C · Hᵀ · diag(exp cums)      (Q×N)·(N×P)
    H'      = exp(la_Q)·H + (x·dt)ᵀ·(B ∘ decay)   (P×Q)·(Q×N)

— plus an O(1) inter-chunk recurrence carried in a VMEM scratch across grid
steps (the TPU grid is sequential, minor-most fastest, so the chunk axis is
the inner grid dim and the (P, N) state lives on-chip for a whole (batch,
head) row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime_interpret


def _kernel(x_ref, dt_ref, loga_ref, b_ref, c_ref, y_ref, h_scratch, *, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Q,)
    la = loga_ref[0].astype(jnp.float32)  # (Q,)
    bb = b_ref[0].astype(jnp.float32)  # (Q, N)
    cc = c_ref[0].astype(jnp.float32)  # (Q, N)
    h = h_scratch[...]  # (P, N) f32

    q = x.shape[0]
    cums = jnp.cumsum(la)  # (Q,)
    xd = x * dt[:, None]

    cb = jnp.dot(cc, bb.T, preferred_element_type=jnp.float32)  # (Q, Q)
    decay = jnp.exp(cums[:, None] - cums[None, :])
    causal = jnp.tril(jnp.ones((q, q), jnp.float32))
    g = cb * decay * causal
    y = jnp.dot(g, xd, preferred_element_type=jnp.float32)  # intra

    y = y + jnp.dot(cc, h.T, preferred_element_type=jnp.float32) * jnp.exp(cums)[:, None]

    dstate = jnp.exp(cums[-1] - cums)  # (Q,)
    h_new = jnp.exp(cums[-1]) * h + jnp.dot(
        xd.T, bb * dstate[:, None], preferred_element_type=jnp.float32
    )
    h_scratch[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)
    del nc


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_kernel(
    x: jax.Array,  # (BH, S, P)  — batch×heads flattened
    dt: jax.Array,  # (BH, S)
    loga: jax.Array,  # (BH, S)   — A[h]·dt, precomputed
    B: jax.Array,  # (BH, S, N)
    C: jax.Array,  # (BH, S, N)
    *,
    chunk: int = 128,
    interpret: bool | None = None,  # None -> kernels.runtime_interpret()
) -> jax.Array:
    if interpret is None:
        # resolved at trace time; jit caches under the None key, which is
        # stable because the backend cannot change within a process
        interpret = runtime_interpret()
    bh, s, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    out = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, loga, B, C)
    return out
