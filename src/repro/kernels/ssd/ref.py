"""Oracle for the SSD chunk kernel — re-exports the model's chunked SSD and
the sequential recurrence (both in repro.models.transformer.ssm)."""

from repro.models.transformer.ssm import ssd_chunked, ssd_reference  # noqa: F401
