"""Public op for the SSD Pallas kernel, model-layout in/out (+ custom VJP)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_kernel
from repro.models.transformer.ssm import ssd_chunked


def _to_bh(x, dt, A, B, C):
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = jnp.moveaxis(x, 2, 1).reshape(b * h, s, p)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(b * h, s)
    loga = jnp.moveaxis(dt * A[None, None, :], 2, 1).reshape(b * h, s)
    Bf = jnp.repeat(B[:, None], h, axis=1).reshape(b * h, s, n)
    Cf = jnp.repeat(C[:, None], h, axis=1).reshape(b * h, s, n)
    return xf, dtf, loga, Bf, Cf


@jax.custom_vjp
def ssd(x, dt, A, B, C, chunk=128):
    """Mamba2 SSD, model layout: x (b,s,h,p), dt (b,s,h), A (h,), B/C (b,s,n).
    Returns y (b,s,h,p); Pallas forward, oracle-derived backward."""
    b, s, h, p = x.shape
    xf, dtf, loga, Bf, Cf = _to_bh(x, dt, A, B, C)
    y = ssd_kernel(xf, dtf, loga, Bf, Cf, chunk=chunk)
    return jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)


def _fwd(x, dt, A, B, C, chunk=128):
    return ssd(x, dt, A, B, C, chunk), (x, dt, A, B, C, chunk)


def _bwd(res, ct):
    x, dt, A, B, C, chunk = res
    _, vjp = jax.vjp(lambda *args: ssd_chunked(*args, chunk=chunk)[0], x, dt, A, B, C)
    return (*vjp(ct), None)


ssd.defvjp(_fwd, _bwd)
