"""Oracle for the flash-attention kernel: the pure-jnp online-softmax
implementation already used by the models (plus a naive quadratic check)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer.attention import blocked_attention  # noqa: F401


def naive_attention(q, k, v, *, q_pos, kv_pos, window=0, attn_softcap=0.0):
    """O(S²)-memory reference. Shapes as blocked_attention."""
    b, sq, h, hd = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    qg = q.reshape(b, sq, kv_heads, g, hd).astype(jnp.float32)
    s = jnp.einsum("bikgd,bjkd->bkgij", qg, k.astype(jnp.float32)) / jnp.sqrt(hd)
    if attn_softcap > 0:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    ok = kv_pos[None, :] <= q_pos[:, None]
    if window:
        ok = ok & ((q_pos[:, None] - kv_pos[None, :]) < window)
    s = jnp.where(ok[None, None, None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgij,bjkd->bikgd", a, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)
