"""Public flash-attention op, model layout in/out (+ custom VJP)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_attention_kernel
from repro.models.transformer.attention import blocked_attention


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, window=0, softcap=0.0, block_q=128, block_kv=128):
    """Causal flash attention. q: (B,S,H,hd); k/v: (B,S,KV,hd[_v]).
    Pallas forward, oracle-derived backward."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * kv, s, -1)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * kv, s, -1)
    out = flash_attention_kernel(
        qf, kf, vf, num_q_heads=h, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv,
    )
    return jnp.moveaxis(out.reshape(b, h, s, -1), 1, 2)


def _ref(q, k, v, window, softcap):
    s = q.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    return blocked_attention(
        q, k, v, q_pos=pos, kv_pos=pos, window=window, attn_softcap=softcap,
    )


def _fwd(q, k, v, window, softcap, block_q, block_kv):
    return flash_attention(q, k, v, window, softcap, block_q, block_kv), (q, k, v)


def _bwd(window, softcap, block_q, block_kv, res, ct):
    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: _ref(a, b, c, window, softcap), q, k, v)
    return vjp(ct)


flash_attention.defvjp(_fwd, _bwd)
