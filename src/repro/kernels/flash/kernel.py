"""Flash attention — Pallas TPU kernel (causal + sliding window + softcap,
GQA-aware).

This is the §Perf pick-3 structural fix: the pure-jnp blocked attention
keeps (q_blk, kv_blk) score tiles and f32 accumulators in HBM between scan
steps; here they live in VMEM scratch for the whole KV sweep, so HBM traffic
drops to reading Q/K/V tiles once and writing O once.

Grid (B·H, n_q, n_kv) — the kv axis is minor (sequential on TPU), carrying
(m, l, acc) scratch across kv steps, exactly the ssd-kernel state pattern.
GQA: the K/V block index map folds the query head onto its kv head, so
grouped heads reread the same K/V tiles (the MXU-friendly layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime_interpret

_NEG = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bkv: int, n_kv: int, scale: float, window: int, softcap: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)  # (bkv, hd_v)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bkv)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    ok = kv_pos <= q_pos
    if window > 0:
        ok = ok & ((q_pos - kv_pos) < window)
    s = jnp.where(ok, s, _NEG)

    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
    p = jnp.exp(s - m_new) * ok
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_q_heads", "block_q", "block_kv", "window",
                              "softcap", "interpret")
)
def flash_attention_kernel(
    q: jax.Array,  # (B·H, Sq, hd)
    k: jax.Array,  # (B·KV, Skv, hd)
    v: jax.Array,  # (B·KV, Skv, hd_v)
    *,
    num_q_heads: int,
    block_q: int = 128,
    block_kv: int = 128,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool | None = None,  # None -> kernels.runtime_interpret()
) -> jax.Array:
    if interpret is None:
        # resolved at trace time; jit caches under the None key, which is
        # stable because the backend cannot change within a process
        interpret = runtime_interpret()
    bh, sq, hd = q.shape
    bkv_rows, skv, hd_v = v.shape
    h = num_q_heads
    kv_heads = bkv_rows // (bh // h)
    g = h // kv_heads
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv)
    n_q, n_kv = sq // block_q, skv // block_kv
    scale = 1.0 / (hd ** 0.5)

    def kv_row(i):  # fold query head onto its kv head
        return (i // h) * kv_heads + (i % h) // g

    out = pl.pallas_call(
        functools.partial(
            _kernel, bq=block_q, bkv=block_kv, n_kv=n_kv, scale=scale,
            window=window, softcap=softcap,
        ),
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda i, qi, ki: (kv_row(i), ki, 0)),
            pl.BlockSpec((1, block_kv, hd_v), lambda i, qi, ki: (kv_row(i), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd_v), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd_v), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
