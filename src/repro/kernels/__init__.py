"""Runtime policy shared by every Pallas kernel in this package.

Two decisions used to be hardcoded per call site and are now resolved once,
here:

* ``runtime_interpret()`` — whether ``pallas_call`` runs in interpret mode.
  Previously every ``kernels/*/kernel.py`` defaulted ``interpret=True``
  ("CPU container"), so a TPU run silently interpreted unless every call
  site passed ``interpret=False``. Now the default is ``None`` and resolves
  at trace time: compiled on TPU, interpret elsewhere, with
  ``REPRO_PALLAS_INTERPRET=0|1`` as an explicit override.

* ``use_kernel_forward()`` — whether the public ops (``padded_spmm``,
  ``gat_aggregate`` and their bucketed variants) run the Pallas kernel or
  the jnp oracle on the forward pass. Interpret-mode Pallas on CPU is a
  per-element emulator — orders of magnitude slower than the XLA oracle —
  so routing every CPU run through it would make any CPU timing of the
  ``pallas`` backend measure the emulator, not the layout. Default: kernel
  on TPU, oracle elsewhere; ``REPRO_PALLAS_FORCE_KERNEL=1`` forces the
  kernel (CI uses this to drive the real kernels through the pipeline in
  interpret mode). Backward is always the oracle vjp (kernel-forward /
  oracle-backward pairing), so gradients are identical either way.
"""

from __future__ import annotations

import os

import jax

_TRUTHY = ("1", "true", "True", "yes")


def runtime_interpret() -> bool:
    """Should ``pallas_call`` interpret? Env override, else backend autodetect."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env in _TRUTHY
    return jax.default_backend() != "tpu"


def use_kernel_forward() -> bool:
    """Should the public ops run the Pallas kernel (vs the jnp oracle)?"""
    env = os.environ.get("REPRO_PALLAS_FORCE_KERNEL")
    if env is not None:
        return env in _TRUTHY
    return jax.default_backend() == "tpu"
