"""Public op for the fused GAT attention kernel (+ custom VJP).

``gat_aggregate`` takes the UNgathered layer tensors (matching the layer
call-site in ``repro.models.gnn.layers``), performs the neighbor gather in
XLA, and runs the fused Pallas kernel forward. Backward re-derives the vjp
from the jnp oracle (kernel-forward / oracle-backward is the standard
recompute pairing; the two agree to float tolerance by the kernel tests).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gat_edge.kernel import gat_aggregate_kernel
from repro.kernels.gat_edge.ref import gat_aggregate_ref


def _prepare(hw, s_src, s_dst, neighbors):
    # hw: (N, H, F) -> head-major gathered (H, N, D, F)
    nbr_hw = jnp.moveaxis(hw[neighbors], 2, 0)  # (H, N, D, F)
    s_self = s_src.T  # (H, N)
    s_nbr = jnp.moveaxis(s_dst[neighbors], 2, 0)  # (H, N, D)
    return nbr_hw, s_self, s_nbr


@partial(jax.custom_vjp, nondiff_argnums=(5,))
def gat_aggregate(hw, s_src, s_dst, neighbors, mask, negative_slope=0.2):
    """(N, H, F) aggregated outputs; forward = Pallas kernel."""
    nbr_hw, s_self, s_nbr = _prepare(hw, s_src, s_dst, neighbors)
    out = gat_aggregate_kernel(
        nbr_hw, s_self, s_nbr, mask, negative_slope=negative_slope
    )
    return jnp.moveaxis(out, 0, 1)  # (N, H, F)


def _ref_call(hw, s_src, s_dst, neighbors, mask, negative_slope):
    nbr_hw, s_self, s_nbr = _prepare(hw, s_src, s_dst, neighbors)
    return jnp.moveaxis(
        gat_aggregate_ref(nbr_hw, s_self, s_nbr, mask, negative_slope=negative_slope),
        0,
        1,
    )


def _fwd(hw, s_src, s_dst, neighbors, mask, negative_slope):
    out = gat_aggregate(hw, s_src, s_dst, neighbors, mask, negative_slope)
    return out, (hw, s_src, s_dst, neighbors, mask)


def _bwd(negative_slope, res, ct):
    hw, s_src, s_dst, neighbors, mask = res
    _, vjp = jax.vjp(
        lambda a, b, c: _ref_call(a, b, c, neighbors, mask, negative_slope),
        hw, s_src, s_dst,
    )
    d_hw, d_src, d_dst = vjp(ct)
    return d_hw, d_src, d_dst, None, None


gat_aggregate.defvjp(_fwd, _bwd)
