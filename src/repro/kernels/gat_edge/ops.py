"""Public ops for the fused GAT attention kernels (+ custom VJP).

``gat_aggregate`` takes the UNgathered layer tensors (matching the layer
call-site in ``repro.models.gnn.layers``), performs the neighbor gather in
XLA, and runs the fused kernel forward over the padded layout.

``bucketed_gat_aggregate`` is the degree-bucketed variant: per-bucket
rectangular tiles (see ``graphs.partition.degree_bucketed_layout``), one
kernel launch per non-empty bucket, and — unlike the padded path — the
feature gather happens INSIDE the kernel, so the gathered ``(R, W, H, F)``
tensor is never materialized by XLA. Score gathers (no F factor) stay in
XLA.

Forward routing follows ``kernels.use_kernel_forward()`` (Pallas kernel on
TPU / forced, jnp oracle elsewhere); backward re-derives the vjp from the
oracle either way (kernel-forward / oracle-backward is the standard
recompute pairing; the two agree to float tolerance by the kernel tests).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import use_kernel_forward
from repro.kernels.gat_edge.kernel import bucket_gat_kernel, gat_aggregate_kernel
from repro.kernels.gat_edge.ref import bucket_gat_ref, gat_aggregate_ref


def _prepare(hw, s_src, s_dst, neighbors):
    # hw: (N, H, F) -> head-major gathered (H, N, D, F)
    nbr_hw = jnp.moveaxis(hw[neighbors], 2, 0)  # (H, N, D, F)
    s_self = s_src.T  # (H, N)
    s_nbr = jnp.moveaxis(s_dst[neighbors], 2, 0)  # (H, N, D)
    return nbr_hw, s_self, s_nbr


@partial(jax.custom_vjp, nondiff_argnums=(5,))
def gat_aggregate(hw, s_src, s_dst, neighbors, mask, negative_slope=0.2):
    """(N, H, F) aggregated outputs over the padded layout."""
    nbr_hw, s_self, s_nbr = _prepare(hw, s_src, s_dst, neighbors)
    if use_kernel_forward():
        out = gat_aggregate_kernel(
            nbr_hw, s_self, s_nbr, mask, negative_slope=negative_slope
        )
    else:
        out = gat_aggregate_ref(
            nbr_hw, s_self, s_nbr, mask, negative_slope=negative_slope
        )
    return jnp.moveaxis(out, 0, 1)  # (N, H, F)


def _ref_call(hw, s_src, s_dst, neighbors, mask, negative_slope):
    nbr_hw, s_self, s_nbr = _prepare(hw, s_src, s_dst, neighbors)
    return jnp.moveaxis(
        gat_aggregate_ref(nbr_hw, s_self, s_nbr, mask, negative_slope=negative_slope),
        0,
        1,
    )


def _fwd(hw, s_src, s_dst, neighbors, mask, negative_slope):
    out = gat_aggregate(hw, s_src, s_dst, neighbors, mask, negative_slope)
    return out, (hw, s_src, s_dst, neighbors, mask)


def _bwd(negative_slope, res, ct):
    hw, s_src, s_dst, neighbors, mask = res
    _, vjp = jax.vjp(
        lambda a, b, c: _ref_call(a, b, c, neighbors, mask, negative_slope),
        hw, s_src, s_dst,
    )
    d_hw, d_src, d_dst = vjp(ct)
    return d_hw, d_src, d_dst, None, None


gat_aggregate.defvjp(_fwd, _bwd)


def _bucket_inputs(s_src, s_dst, nbr, row):
    # per-bucket score gathers (XLA-side: no F factor, (H, R, W) is small)
    s_self = s_src[row].T  # (H, R)
    s_nbr = jnp.moveaxis(s_dst[nbr], 2, 0)  # (H, R, W)
    return s_self, s_nbr


@partial(jax.custom_vjp, nondiff_argnums=(7,))
def bucketed_gat_aggregate(
    hw, s_src, s_dst, neighbors, masks, row_nodes, gather_rows, negative_slope=0.2
):
    """(N, H, F) aggregated outputs over the degree-bucketed layout.

    ``neighbors``/``masks``/``row_nodes`` are equal-length tuples of one
    bucket's ``(R_b, W_b)`` tiles (+ ``(R_b,)`` original-row map);
    ``gather_rows`` maps node i into the bucket concatenation.
    """
    hw_heads = jnp.moveaxis(hw, 1, 0)  # (H, N, F)
    kernel = use_kernel_forward()
    outs = []
    for nbr, mask, row in zip(neighbors, masks, row_nodes):
        if nbr.shape[0] == 0:
            outs.append(jnp.zeros((0,) + hw.shape[1:], hw.dtype))
            continue
        s_self, s_nbr = _bucket_inputs(s_src, s_dst, nbr, row)
        fn = bucket_gat_kernel if kernel else bucket_gat_ref
        out = fn(hw_heads, nbr, s_self, s_nbr, mask, negative_slope=negative_slope)
        outs.append(jnp.moveaxis(out, 0, 1))  # (R, H, F)
    return jnp.concatenate(outs, axis=0)[gather_rows]


def _bucketed_ref_call(
    hw, s_src, s_dst, neighbors, masks, row_nodes, gather_rows, negative_slope
):
    hw_heads = jnp.moveaxis(hw, 1, 0)
    outs = []
    for nbr, mask, row in zip(neighbors, masks, row_nodes):
        s_self, s_nbr = _bucket_inputs(s_src, s_dst, nbr, row)
        out = bucket_gat_ref(
            hw_heads, nbr, s_self, s_nbr, mask, negative_slope=negative_slope
        )
        outs.append(jnp.moveaxis(out, 0, 1))
    return jnp.concatenate(outs, axis=0)[gather_rows]


def _bucketed_fwd(hw, s_src, s_dst, neighbors, masks, row_nodes, gather_rows, negative_slope):
    out = bucketed_gat_aggregate(
        hw, s_src, s_dst, neighbors, masks, row_nodes, gather_rows, negative_slope
    )
    return out, (hw, s_src, s_dst, neighbors, masks, row_nodes, gather_rows)


def _bucketed_bwd(negative_slope, res, ct):
    hw, s_src, s_dst, neighbors, masks, row_nodes, gather_rows = res
    _, vjp = jax.vjp(
        lambda a, b, c: _bucketed_ref_call(
            a, b, c, neighbors, masks, row_nodes, gather_rows, negative_slope
        ),
        hw, s_src, s_dst,
    )
    d_hw, d_src, d_dst = vjp(ct)
    none_like = tuple(None for _ in neighbors)
    return d_hw, d_src, d_dst, none_like, none_like, none_like, None


bucketed_gat_aggregate.defvjp(_bucketed_fwd, _bucketed_bwd)
