"""Pure-jnp oracle for the fused GAT neighbor-attention kernel.

Math (paper eq. 3–4, per head, over the padded-neighbor layout):

    e[i,j]     = LeakyReLU(s_self[i] + s_nbr[i,j])
    alpha[i,:] = masked softmax_j(e[i,:])
    out[i]     = Σ_j alpha[i,j] · nbr_hw[i,j,:]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e9


def gat_aggregate_ref(
    nbr_hw: jax.Array,  # (H, N, D, F) gathered neighbor features
    s_self: jax.Array,  # (H, N)
    s_nbr: jax.Array,  # (H, N, D)
    mask: jax.Array,  # (N, D) bool
    *,
    negative_slope: float = 0.2,
) -> jax.Array:  # (H, N, F)
    scores = jax.nn.leaky_relu(s_self[..., None] + s_nbr, negative_slope)
    scores = jnp.where(mask[None], scores.astype(jnp.float32), _NEG)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m) * mask[None]
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    alpha = (p / l).astype(nbr_hw.dtype)
    return jnp.einsum("hnd,hndf->hnf", alpha, nbr_hw)
