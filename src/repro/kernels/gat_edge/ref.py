"""Pure-jnp oracles for the fused GAT neighbor-attention kernels.

Math (paper eq. 3–4, per head, over the padded-neighbor layout):

    e[i,j]     = LeakyReLU(s_self[i] + s_nbr[i,j])
    alpha[i,:] = masked softmax_j(e[i,:])
    out[i]     = Σ_j alpha[i,j] · nbr_hw[i,j,:]

``bucket_gat_ref`` is the same math over one degree bucket's rectangular
tile: rows are bucket rows (R of them, width W), neighbor indices point into
the full (N, F) feature matrix, and the gather the kernel performs in VMEM
is materialized here explicitly — it is the oracle, and ``(H, R, W, F)`` is
bounded by the bucket's width rather than the global max degree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e9


def _masked_alpha(scores: jax.Array, mask: jax.Array, dtype) -> jax.Array:
    scores = jnp.where(mask, scores.astype(jnp.float32), _NEG)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m) * mask
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return (p / l).astype(dtype)


def gat_aggregate_ref(
    nbr_hw: jax.Array,  # (H, N, D, F) gathered neighbor features
    s_self: jax.Array,  # (H, N)
    s_nbr: jax.Array,  # (H, N, D)
    mask: jax.Array,  # (N, D) bool
    *,
    negative_slope: float = 0.2,
) -> jax.Array:  # (H, N, F)
    scores = jax.nn.leaky_relu(s_self[..., None] + s_nbr, negative_slope)
    alpha = _masked_alpha(scores, mask[None], nbr_hw.dtype)
    return jnp.einsum("hnd,hndf->hnf", alpha, nbr_hw)


def bucket_gat_ref(
    hw_heads: jax.Array,  # (H, N, F) — full feature matrix
    neighbors: jax.Array,  # (R, W) int32 — one bucket's rows
    s_self: jax.Array,  # (H, R)
    s_nbr: jax.Array,  # (H, R, W)
    mask: jax.Array,  # (R, W) bool
    *,
    negative_slope: float = 0.2,
) -> jax.Array:  # (H, R, F)
    scores = jax.nn.leaky_relu(s_self[..., None] + s_nbr, negative_slope)
    alpha = _masked_alpha(scores, mask[None], hw_heads.dtype)
    return jnp.einsum("hrw,hrwf->hrf", alpha, hw_heads[:, neighbors])
