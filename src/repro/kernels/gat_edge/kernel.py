"""Fused GAT neighbor attention — Pallas TPU kernel.

One VMEM-resident fusion of score → masked softmax → weighted aggregate over
the padded-neighbor layout (DESIGN.md §3): the (N, D, H) attention tensor is
never materialized in HBM (the paper's DGL/PyG backends materialize it and
make two extra passes). The neighbor gather itself stays in XLA — TPU has a
native efficient gather; the kernel owns everything after it.

Blocking: grid (H, N/T). Each step holds (T, D, F) neighbor features +
(T, D) scores in VMEM; the weighted sum is a (T,D)×(T,D,F) batched
contraction on the MXU. T chosen so the working set fits VMEM with
MXU-aligned F.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e9


def _kernel(s_self_ref, s_nbr_ref, mask_ref, nbr_ref, out_ref, *, negative_slope):
    # blocks: s_self (1, T); s_nbr (1, T, D); mask (T, D); nbr (1, T, D, F)
    s_self = s_self_ref[0]  # (T,)
    s_nbr = s_nbr_ref[0]  # (T, D)
    mask = mask_ref[...]  # (T, D)
    nbr = nbr_ref[0]  # (T, D, F)

    s = s_self[:, None] + s_nbr
    s = jnp.where(s >= 0, s, negative_slope * s).astype(jnp.float32)
    s = jnp.where(mask, s, _NEG)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m) * mask
    l = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    alpha = (p / l).astype(nbr.dtype)
    # (T, 1, D) @ (T, D, F) -> (T, 1, F): batched MXU contraction over D
    out = jax.lax.dot_general(
        alpha[:, None, :], nbr,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    out_ref[0] = out[:, 0].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("negative_slope", "block_n", "interpret"))
def gat_aggregate_kernel(
    nbr_hw: jax.Array,  # (H, N, D, F)
    s_self: jax.Array,  # (H, N)
    s_nbr: jax.Array,  # (H, N, D)
    mask: jax.Array,  # (N, D)
    *,
    negative_slope: float = 0.2,
    block_n: int = 128,
    interpret: bool = True,  # CPU container: interpret; TPU target: False
) -> jax.Array:
    h, n, d, f = nbr_hw.shape
    pad = (-n) % block_n
    if pad:
        nbr_hw = jnp.pad(nbr_hw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_self = jnp.pad(s_self, ((0, 0), (0, pad)))
        s_nbr = jnp.pad(s_nbr, ((0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    n_pad = n + pad

    grid = (h, n_pad // block_n)
    out = pl.pallas_call(
        functools.partial(_kernel, negative_slope=negative_slope),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda hh, i: (hh, i)),
            pl.BlockSpec((1, block_n, d), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((block_n, d), lambda hh, i: (i, 0)),
            pl.BlockSpec((1, block_n, d, f), lambda hh, i: (hh, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, f), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n_pad, f), nbr_hw.dtype),
        interpret=interpret,
    )(s_self, s_nbr, mask, nbr_hw)
    return out[:, :n]
