"""Fused GAT neighbor attention — Pallas TPU kernels.

One VMEM-resident fusion of score → masked softmax → weighted aggregate over
the padded-neighbor layout (DESIGN.md §3): the (N, D, H) attention tensor is
never materialized in HBM (the paper's DGL/PyG backends materialize it and
make two extra passes).

``gat_aggregate_kernel`` (padded layout): the neighbor gather stays in XLA —
upstream materializes the gathered ``(H, N, D, F)`` tensor. Blocking: grid
(H, N/T); each step holds (T, D, F) neighbor features + (T, D) scores in
VMEM; the weighted sum is a (T,D)×(T,D,F) batched contraction on the MXU.

``bucket_gat_kernel`` (degree-bucketed layout): the feature gather moves
INSIDE the kernel — the bucket's neighbor indices ride scalar-prefetch
(SMEM) and drive dynamic row loads out of a per-head VMEM-resident (N, F)
feature block, so the ``(R, W, H, F)`` gathered tensor never exists in HBM
at all. Scores are still gathered in XLA (no F factor — (H, R, W) is small).
Grid (H, R/T), one launch per degree bucket.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import runtime_interpret

_NEG = -1e9


def _kernel(s_self_ref, s_nbr_ref, mask_ref, nbr_ref, out_ref, *, negative_slope):
    # blocks: s_self (1, T); s_nbr (1, T, D); mask (T, D); nbr (1, T, D, F)
    s_self = s_self_ref[0]  # (T,)
    s_nbr = s_nbr_ref[0]  # (T, D)
    mask = mask_ref[...]  # (T, D)
    nbr = nbr_ref[0]  # (T, D, F)

    s = s_self[:, None] + s_nbr
    s = jnp.where(s >= 0, s, negative_slope * s).astype(jnp.float32)
    s = jnp.where(mask, s, _NEG)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m) * mask
    l = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    alpha = (p / l).astype(nbr.dtype)
    # (T, 1, D) @ (T, D, F) -> (T, 1, F): batched MXU contraction over D
    out = jax.lax.dot_general(
        alpha[:, None, :], nbr,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    out_ref[0] = out[:, 0].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("negative_slope", "block_n", "interpret"))
def _gat_call(nbr_hw, s_self, s_nbr, mask, *, negative_slope, block_n, interpret):
    h, n, d, f = nbr_hw.shape
    pad = (-n) % block_n
    if pad:
        nbr_hw = jnp.pad(nbr_hw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_self = jnp.pad(s_self, ((0, 0), (0, pad)))
        s_nbr = jnp.pad(s_nbr, ((0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    n_pad = n + pad

    grid = (h, n_pad // block_n)
    out = pl.pallas_call(
        functools.partial(_kernel, negative_slope=negative_slope),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda hh, i: (hh, i)),
            pl.BlockSpec((1, block_n, d), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((block_n, d), lambda hh, i: (i, 0)),
            pl.BlockSpec((1, block_n, d, f), lambda hh, i: (hh, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, f), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n_pad, f), nbr_hw.dtype),
        interpret=interpret,
    )(s_self, s_nbr, mask, nbr_hw)
    return out[:, :n]


def gat_aggregate_kernel(
    nbr_hw: jax.Array,  # (H, N, D, F)
    s_self: jax.Array,  # (H, N)
    s_nbr: jax.Array,  # (H, N, D)
    mask: jax.Array,  # (N, D)
    *,
    negative_slope: float = 0.2,
    block_n: int = 128,
    interpret: bool | None = None,  # None -> kernels.runtime_interpret()
) -> jax.Array:
    if interpret is None:
        interpret = runtime_interpret()
    return _gat_call(
        nbr_hw, s_self, s_nbr, mask,
        negative_slope=negative_slope, block_n=block_n, interpret=interpret,
    )


def _bucket_kernel(
    nbr_ref, s_self_ref, s_nbr_ref, mask_ref, hw_ref, out_ref,
    *, block_r, width, negative_slope,
):
    # blocks: s_self (1, T); s_nbr (1, T, W); mask (T, W); hw (N, F) — the
    # current head's full feature matrix, resident in VMEM. nbr_ref is the
    # whole (R_pad, W) index array in SMEM (scalar prefetch).
    i = pl.program_id(1)

    # vectorized masked softmax over the whole (T, W) tile
    s = s_self_ref[0][:, None] + s_nbr_ref[0]
    s = jnp.where(s >= 0, s, negative_slope * s).astype(jnp.float32)
    mask = mask_ref[...]
    s = jnp.where(mask, s, _NEG)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m) * mask
    l = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
    alpha = p / l  # (T, W) f32

    def row_body(t, _):
        gi = i * block_r + t  # global bucket row (rows padded to grid)
        acc = jnp.zeros((hw_ref.shape[1],), jnp.float32)

        def nbr_body(j, acc):
            idx = nbr_ref[gi, j]  # scalar from SMEM prefetch
            row = pl.load(hw_ref, (pl.dslice(idx, 1), slice(None)))[0]
            return acc + alpha[t, j] * row.astype(jnp.float32)

        acc = jax.lax.fori_loop(0, width, nbr_body, acc)
        out_ref[0, t, :] = acc.astype(out_ref.dtype)
        return 0

    jax.lax.fori_loop(0, block_r, row_body, 0)


@functools.partial(jax.jit, static_argnames=("negative_slope", "block_r", "interpret"))
def _bucket_gat_call(hw_heads, neighbors, s_self, s_nbr, mask, *, negative_slope, block_r, interpret):
    h, n, f = hw_heads.shape
    r, w = neighbors.shape
    pad = (-r) % block_r
    if pad:
        neighbors = jnp.pad(neighbors, ((0, pad), (0, 0)))
        s_self = jnp.pad(s_self, ((0, 0), (0, pad)))
        s_nbr = jnp.pad(s_nbr, ((0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    r_pad = r + pad

    # head-major flatten so a (n, f) block indexed by head is one reshape away
    hw_flat = hw_heads.reshape(h * n, f)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(h, r_pad // block_r),
        in_specs=[
            pl.BlockSpec((1, block_r), lambda hh, i, nbr: (hh, i)),
            pl.BlockSpec((1, block_r, w), lambda hh, i, nbr: (hh, i, 0)),
            pl.BlockSpec((block_r, w), lambda hh, i, nbr: (i, 0)),
            pl.BlockSpec((n, f), lambda hh, i, nbr: (hh, 0)),  # head hh's (N, F)
        ],
        out_specs=pl.BlockSpec((1, block_r, f), lambda hh, i, nbr: (hh, i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(
            _bucket_kernel, block_r=block_r, width=w, negative_slope=negative_slope
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((h, r_pad, f), hw_heads.dtype),
        interpret=interpret,
    )(neighbors, s_self, s_nbr, mask, hw_flat)
    return out[:, :r]


def bucket_gat_kernel(
    hw_heads: jax.Array,  # (H, N, F) — full feature matrix, original numbering
    neighbors: jax.Array,  # (R, W) int32 — one degree bucket's rows
    s_self: jax.Array,  # (H, R)
    s_nbr: jax.Array,  # (H, R, W)
    mask: jax.Array,  # (R, W) bool
    *,
    negative_slope: float = 0.2,
    block_r: int = 128,
    interpret: bool | None = None,
) -> jax.Array:  # (H, R, F)
    if interpret is None:
        interpret = runtime_interpret()
    return _bucket_gat_call(
        hw_heads, neighbors, s_self, s_nbr, mask,
        negative_slope=negative_slope, block_r=block_r, interpret=interpret,
    )
