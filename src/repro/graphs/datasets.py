"""Synthetic, stat-matched citation-network datasets.

The container is offline, so Cora/CiteSeer/PubMed are generated to match the
paper's §5 statistics exactly (nodes / undirected edges / feature dim /
classes) with a planted-partition (SBM-style) topology and TF-IDF-like
class-correlated sparse features, so the node-classification task is
actually learnable and the paper's qualitative claims can be validated.

Splits follow the standard semi-supervised protocol of Kipf & Welling /
Veličković et al.: 20 train nodes per class, 500 val, 1000 test.
"""

from __future__ import annotations

import zlib

import numpy as np

from repro.graphs.data import GraphBatch, build_graph_batch

# name: (num_nodes, num_undirected_edges, num_features, num_classes)
DATASETS: dict[str, tuple[int, int, int, int]] = {
    "cora": (2708, 5429, 1433, 7),
    "citeseer": (3312, 4732, 3703, 6),
    "pubmed": (19717, 44338, 500, 3),
    # small stand-ins for the paper's "too big for this study" §5 datasets,
    # used by the scaling example only
    "reddit-mini": (8192, 131072, 300, 50),
    "karate": (34, 78, 34, 2),
}

# Power-law (Zipf) degree graphs: max_deg ≫ median_deg, so the padded
# (n, max_deg) layout's cost is dominated by a handful of hub rows. These
# are the fixtures for the degree-bucketed sparse path (fig3 sparse rows,
# the CI sparse gate, and the engine backend-equivalence tests).
# name: (num_nodes, num_features, num_classes, zipf_a, deg_cap)
SKEWED_DATASETS: dict[str, tuple[int, int, int, float, int]] = {
    "skewed-powerlaw": (8192, 64, 16, 1.7, 1024),
    # test-sized twin: same shape of degree distribution, tractable in tier-1
    "skewed-mini": (256, 16, 4, 1.7, 96),
}


def _powerlaw_edges(
    rng: np.random.Generator,
    labels: np.ndarray,
    *,
    zipf_a: float,
    deg_cap: int,
    p_intra: float,
) -> np.ndarray:
    """Undirected edges with Zipf-distributed target degrees.

    Each node draws a target degree from Zipf(a) (capped), then connects to
    that many partners — within-class with probability ``p_intra`` so the
    classification task stays aggregation-dependent, like the planted
    citation graphs. The realized degree distribution keeps the heavy tail:
    a few hub nodes collect both their own draws and everyone else's.
    """
    n = labels.shape[0]
    by_class = [np.flatnonzero(labels == c) for c in range(labels.max() + 1)]
    target = np.minimum(rng.zipf(zipf_a, size=n), min(deg_cap, n - 1))
    edges: set[tuple[int, int]] = set()
    for i in range(n):
        want = int(target[i])
        intra = rng.random(want) < p_intra
        members = by_class[labels[i]]
        for k in range(want):
            j = int(members[rng.integers(0, len(members))]) if intra[k] else int(rng.integers(0, n))
            if i == j:
                continue
            edges.add((min(i, j), max(i, j)))
    return np.array(sorted(edges), dtype=np.int64)


def _planted_edges(rng: np.random.Generator, labels: np.ndarray, m: int, p_intra: float) -> np.ndarray:
    """Sample ~m unique undirected edges, p_intra of them within-class."""
    n = labels.shape[0]
    by_class = [np.flatnonzero(labels == c) for c in range(labels.max() + 1)]
    edges: set[tuple[int, int]] = set()
    # sample in batches until we hit m unique edges
    while len(edges) < m:
        want = m - len(edges)
        intra = rng.random(want) < p_intra
        a = rng.integers(0, n, size=want)
        b = np.empty(want, dtype=np.int64)
        for k in range(want):
            if intra[k]:
                members = by_class[labels[a[k]]]
                b[k] = members[rng.integers(0, len(members))]
            else:
                b[k] = rng.integers(0, n)
        for x, y in zip(a, b):
            if x == y:
                continue
            e = (int(min(x, y)), int(max(x, y)))
            edges.add(e)
    out = np.array(sorted(edges), dtype=np.int64)[:m]
    return out


def _tfidf_features(
    rng: np.random.Generator,
    labels: np.ndarray,
    num_features: int,
    *,
    words_per_doc: int = 24,
    on_topic_frac: float = 0.17,
) -> np.ndarray:
    """Sparse bag-of-words-ish features with per-class topic vocabularies.

    ``on_topic_frac`` is deliberately weak: the per-node feature signal alone
    should NOT solve the task, so the model has to aggregate neighborhoods —
    which is what makes the paper's Fig-4 accuracy collapse (edges lost under
    sequential micro-batching) observable.
    """
    n = labels.shape[0]
    c = labels.max() + 1
    feats = np.zeros((n, num_features), dtype=np.float32)
    # each class owns a random slice of ~num_features/(2c) topic words
    topic_size = max(4, num_features // (2 * c))
    topics = [rng.choice(num_features, size=topic_size, replace=False) for _ in range(c)]
    for i in range(n):
        k_topic = max(1, int(round(words_per_doc * on_topic_frac)))
        on_topic = topics[labels[i]][rng.integers(0, topic_size, size=k_topic)]
        off_topic = rng.integers(0, num_features, size=words_per_doc - k_topic)
        idx = np.concatenate([on_topic, off_topic])
        vals = rng.random(idx.shape[0]).astype(np.float32) + 0.5
        feats[i, idx] = vals
    # row-normalize as PyG does for citation BoW features
    row = feats.sum(axis=1, keepdims=True)
    row[row == 0] = 1.0
    return feats / row


def _standard_split(
    rng: np.random.Generator, labels: np.ndarray, *, per_class: int = 20, n_val: int = 500, n_test: int = 1000
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    n = labels.shape[0]
    c = labels.max() + 1
    train = np.zeros(n, dtype=bool)
    for cls in range(c):
        members = np.flatnonzero(labels == cls)
        # tiny graphs (karate): keep ≥2/3 of each class out of train so the
        # val/test splits are non-empty
        take = min(per_class, max(1, len(members) // 3))
        train[rng.choice(members, size=take, replace=False)] = True
    rest = np.flatnonzero(~train)
    rest = rng.permutation(rest)
    n_val = min(n_val, max(0, len(rest) - 1))
    n_test = min(n_test, max(0, len(rest) - n_val))
    val = np.zeros(n, dtype=bool)
    test = np.zeros(n, dtype=bool)
    val[rest[:n_val]] = True
    test[rest[n_val : n_val + n_test]] = True
    return train, val, test


def load_dataset(
    name: str,
    *,
    seed: int = 0,
    max_degree: int | None = None,
    p_intra: float = 0.9,
) -> GraphBatch:
    """Generate the stat-matched synthetic dataset ``name`` deterministically."""
    if name not in DATASETS and name not in SKEWED_DATASETS:
        raise KeyError(
            f"unknown dataset {name!r}; have {sorted(DATASETS) + sorted(SKEWED_DATASETS)}"
        )
    # crc32, not hash(): str hashing is salted per process (PYTHONHASHSEED),
    # which silently made "deterministic" datasets differ between runs
    name_key = zlib.crc32(name.encode()) & 0xFFFF
    rng = np.random.default_rng(np.random.SeedSequence([name_key, seed]))
    if name in SKEWED_DATASETS:
        n, d, c, zipf_a, deg_cap = SKEWED_DATASETS[name]
        labels = rng.integers(0, c, size=n).astype(np.int64)
        edges = _powerlaw_edges(rng, labels, zipf_a=zipf_a, deg_cap=deg_cap, p_intra=p_intra)
    else:
        n, m, d, c = DATASETS[name]
        labels = rng.integers(0, c, size=n).astype(np.int64)
        edges = _planted_edges(rng, labels, m, p_intra)
    feats = _tfidf_features(rng, labels, d)
    train, val, test = _standard_split(rng, labels)
    return build_graph_batch(
        feats,
        edges,
        labels,
        c,
        train_mask=train,
        val_mask=val,
        test_mask=test,
        max_degree=max_degree,
    )
