"""Synthetic, stat-matched citation-network datasets.

The container is offline, so Cora/CiteSeer/PubMed are generated to match the
paper's §5 statistics exactly (nodes / undirected edges / feature dim /
classes) with a planted-partition (SBM-style) topology and TF-IDF-like
class-correlated sparse features, so the node-classification task is
actually learnable and the paper's qualitative claims can be validated.

Splits follow the standard semi-supervised protocol of Kipf & Welling /
Veličković et al.: 20 train nodes per class, 500 val, 1000 test.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.data import GraphBatch, build_graph_batch

# name: (num_nodes, num_undirected_edges, num_features, num_classes)
DATASETS: dict[str, tuple[int, int, int, int]] = {
    "cora": (2708, 5429, 1433, 7),
    "citeseer": (3312, 4732, 3703, 6),
    "pubmed": (19717, 44338, 500, 3),
    # small stand-ins for the paper's "too big for this study" §5 datasets,
    # used by the scaling example only
    "reddit-mini": (8192, 131072, 300, 50),
    "karate": (34, 78, 34, 2),
}

# Power-law (Zipf) degree graphs: max_deg ≫ median_deg, so the padded
# (n, max_deg) layout's cost is dominated by a handful of hub rows. These
# are the fixtures for the degree-bucketed sparse path (fig3 sparse rows,
# the CI sparse gate, and the engine backend-equivalence tests).
# name: (num_nodes, num_features, num_classes, zipf_a, deg_cap)
SKEWED_DATASETS: dict[str, tuple[int, int, int, float, int]] = {
    "skewed-powerlaw": (8192, 64, 16, 1.7, 1024),
    # test-sized twin: same shape of degree distribution, tractable in tier-1
    "skewed-mini": (256, 16, 4, 1.7, 96),
}


def _powerlaw_edges(
    rng: np.random.Generator,
    labels: np.ndarray,
    *,
    zipf_a: float,
    deg_cap: int,
    p_intra: float,
) -> np.ndarray:
    """Undirected edges with Zipf-distributed target degrees.

    Each node draws a target degree from Zipf(a) (capped), then connects to
    that many partners — within-class with probability ``p_intra`` so the
    classification task stays aggregation-dependent, like the planted
    citation graphs. The realized degree distribution keeps the heavy tail:
    a few hub nodes collect both their own draws and everyone else's.
    """
    n = labels.shape[0]
    by_class = [np.flatnonzero(labels == c) for c in range(labels.max() + 1)]
    target = np.minimum(rng.zipf(zipf_a, size=n), min(deg_cap, n - 1))
    edges: set[tuple[int, int]] = set()
    for i in range(n):
        want = int(target[i])
        intra = rng.random(want) < p_intra
        members = by_class[labels[i]]
        for k in range(want):
            j = int(members[rng.integers(0, len(members))]) if intra[k] else int(rng.integers(0, n))
            if i == j:
                continue
            edges.add((min(i, j), max(i, j)))
    return np.array(sorted(edges), dtype=np.int64)


def _planted_edges(rng: np.random.Generator, labels: np.ndarray, m: int, p_intra: float) -> np.ndarray:
    """Sample ~m unique undirected edges, p_intra of them within-class."""
    n = labels.shape[0]
    by_class = [np.flatnonzero(labels == c) for c in range(labels.max() + 1)]
    edges: set[tuple[int, int]] = set()
    # sample in batches until we hit m unique edges
    while len(edges) < m:
        want = m - len(edges)
        intra = rng.random(want) < p_intra
        a = rng.integers(0, n, size=want)
        b = np.empty(want, dtype=np.int64)
        for k in range(want):
            if intra[k]:
                members = by_class[labels[a[k]]]
                b[k] = members[rng.integers(0, len(members))]
            else:
                b[k] = rng.integers(0, n)
        for x, y in zip(a, b):
            if x == y:
                continue
            e = (int(min(x, y)), int(max(x, y)))
            edges.add(e)
    out = np.array(sorted(edges), dtype=np.int64)[:m]
    return out


def _tfidf_features(
    rng: np.random.Generator,
    labels: np.ndarray,
    num_features: int,
    *,
    words_per_doc: int = 24,
    on_topic_frac: float = 0.17,
) -> np.ndarray:
    """Sparse bag-of-words-ish features with per-class topic vocabularies.

    ``on_topic_frac`` is deliberately weak: the per-node feature signal alone
    should NOT solve the task, so the model has to aggregate neighborhoods —
    which is what makes the paper's Fig-4 accuracy collapse (edges lost under
    sequential micro-batching) observable.
    """
    n = labels.shape[0]
    c = labels.max() + 1
    feats = np.zeros((n, num_features), dtype=np.float32)
    # each class owns a random slice of ~num_features/(2c) topic words
    topic_size = max(4, num_features // (2 * c))
    topics = [rng.choice(num_features, size=topic_size, replace=False) for _ in range(c)]
    for i in range(n):
        k_topic = max(1, int(round(words_per_doc * on_topic_frac)))
        on_topic = topics[labels[i]][rng.integers(0, topic_size, size=k_topic)]
        off_topic = rng.integers(0, num_features, size=words_per_doc - k_topic)
        idx = np.concatenate([on_topic, off_topic])
        vals = rng.random(idx.shape[0]).astype(np.float32) + 0.5
        feats[i, idx] = vals
    # row-normalize as PyG does for citation BoW features
    row = feats.sum(axis=1, keepdims=True)
    row[row == 0] = 1.0
    return feats / row


def _standard_split(
    rng: np.random.Generator, labels: np.ndarray, *, per_class: int = 20, n_val: int = 500, n_test: int = 1000
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    n = labels.shape[0]
    c = labels.max() + 1
    train = np.zeros(n, dtype=bool)
    for cls in range(c):
        members = np.flatnonzero(labels == cls)
        # tiny graphs (karate): keep ≥2/3 of each class out of train so the
        # val/test splits are non-empty
        take = min(per_class, max(1, len(members) // 3))
        train[rng.choice(members, size=take, replace=False)] = True
    rest = np.flatnonzero(~train)
    rest = rng.permutation(rest)
    n_val = min(n_val, max(0, len(rest) - 1))
    n_test = min(n_test, max(0, len(rest) - n_val))
    val = np.zeros(n, dtype=bool)
    test = np.zeros(n, dtype=bool)
    val[rest[:n_val]] = True
    test[rest[n_val : n_val + n_test]] = True
    return train, val, test


def load_dataset(
    name: str,
    *,
    seed: int = 0,
    max_degree: int | None = None,
    p_intra: float = 0.9,
) -> GraphBatch:
    """Generate the stat-matched synthetic dataset ``name`` deterministically."""
    if name not in DATASETS and name not in SKEWED_DATASETS:
        raise KeyError(
            f"unknown dataset {name!r}; have {sorted(DATASETS) + sorted(SKEWED_DATASETS)}"
        )
    # crc32, not hash(): str hashing is salted per process (PYTHONHASHSEED),
    # which silently made "deterministic" datasets differ between runs
    name_key = zlib.crc32(name.encode()) & 0xFFFF
    rng = np.random.default_rng(np.random.SeedSequence([name_key, seed]))
    if name in SKEWED_DATASETS:
        n, d, c, zipf_a, deg_cap = SKEWED_DATASETS[name]
        labels = rng.integers(0, c, size=n).astype(np.int64)
        edges = _powerlaw_edges(rng, labels, zipf_a=zipf_a, deg_cap=deg_cap, p_intra=p_intra)
    else:
        n, m, d, c = DATASETS[name]
        labels = rng.integers(0, c, size=n).astype(np.int64)
        edges = _planted_edges(rng, labels, m, p_intra)
    feats = _tfidf_features(rng, labels, d)
    train, val, test = _standard_split(rng, labels)
    return build_graph_batch(
        feats,
        edges,
        labels,
        c,
        train_mask=train,
        val_mask=val,
        test_mask=test,
        max_degree=max_degree,
    )


# ------------------------------------------------ streamed power-law graphs --
#
# The registries above generate the WHOLE graph in one rng stream, so every
# node's data depends on every draw before it — fine at 20k nodes, hopeless at
# a million (and it forces the full (n, d) feature matrix into memory at
# once). The streamed generator below is random-access by fixed-size BLOCK:
# each block of ``block_size`` nodes owns an independent rng seeded
# ``[name_key, seed, block_index]`` and draws, in a fixed order, its labels,
# its nodes' out-edges, its features, and its split coins. Any node range
# ``[lo, hi)`` can therefore be materialized by generating only the blocks it
# overlaps — the chunk a pipeline micro-batch needs, never the full graph —
# and the result is invariant to HOW the graph is chunked (property-tested in
# tests/test_streamed.py: a chunk's edge set equals the restriction of any
# containing chunk's edge set).
#
# Blocks double as the planted communities: a node's intra-class partners are
# drawn from its own block (global partners are uniform over all n nodes), so
# edge generation never needs another block's labels.

# name: (num_nodes, num_features, num_classes, zipf_a, deg_cap)
STREAMED_DATASETS: dict[str, tuple[int, int, int, float, int]] = {
    "powerlaw-64k": (65_536, 64, 16, 1.7, 48),
    "powerlaw-256k": (262_144, 64, 16, 1.7, 48),
    "powerlaw-1m": (1_048_576, 64, 16, 1.7, 48),
}

# third SeedSequence word for the stream shared across blocks (class topic
# vocabularies); block streams use the block index, which starts at 0, so the
# salt must sit outside the block-index range
_TOPIC_SALT = 0x7F000001


def _padded_rows_from_edges(
    n: int, edges: np.ndarray, max_degree: int | None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized twin of ``build_graph_batch``'s padded-layout construction
    (which walks Python adjacency sets — fine at 20k nodes, minutes at 1M).

    Same contract bit for bit: unique undirected ``edges`` (m, 2) with no
    self-loops -> (neighbors, mask, norm) with the self-loop in slot 0,
    neighbors sorted ascending, truncation keeping the lowest-index
    neighbors, and GCN norm computed from the UNtruncated degree.
    """
    if len(edges):
        directed = np.concatenate([edges, edges[:, ::-1]])
        order = np.lexsort((directed[:, 1], directed[:, 0]))
        src, dst = directed[order, 0], directed[order, 1]
    else:
        src = dst = np.zeros(0, dtype=np.int64)
    deg_full = np.bincount(src, minlength=n)
    true_max = int(deg_full.max(initial=0))
    width = 1 + (true_max if max_degree is None else min(max_degree, true_max))

    # rank of each directed edge within its source's sorted run; keep the
    # first width-1 (== build_graph_batch's "drop highest-index" truncation)
    starts = np.concatenate([[0], np.cumsum(deg_full)[:-1]])
    rank = np.arange(len(src)) - starts[src]
    keep = rank < width - 1

    neighbors = np.zeros((n, width), dtype=np.int32)
    mask = np.zeros((n, width), dtype=bool)
    neighbors[:, 0] = np.arange(n)
    mask[:, 0] = True
    neighbors[src[keep], 1 + rank[keep]] = dst[keep]
    mask[src[keep], 1 + rank[keep]] = True

    inv_sqrt = 1.0 / np.sqrt(deg_full + 1.0)  # self-looped, untruncated
    norm = inv_sqrt[:, None] * inv_sqrt[neighbors] * mask
    return neighbors, mask, norm.astype(np.float32)


@dataclasses.dataclass(frozen=True)
class StreamedPowerlaw:
    """A power-law graph generated lazily, one node block at a time.

    Never holds the full graph: ``chunk_batch(lo, hi)`` materializes exactly
    the blocks overlapping ``[lo, hi)`` and returns a host-built
    ``GraphBatch`` of that node range with boundary-crossing edges dropped
    (the paper's sequential-lossy micro-batching, applied at generation
    time). Chunk contents are independent of the chunking because every
    block draws from its own ``[name_key, seed, block]`` rng.
    """

    name: str
    num_nodes: int
    num_features: int
    num_classes: int
    zipf_a: float
    deg_cap: int
    seed: int = 0
    block_size: int = 4096
    p_intra: float = 0.9

    @property
    def num_blocks(self) -> int:
        """Generator blocks covering the node axis (last may be short)."""
        return -(-self.num_nodes // self.block_size)

    @property
    def _name_key(self) -> int:
        return zlib.crc32(self.name.encode()) & 0xFFFF

    def _block_rng(self, block: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self._name_key, self.seed, block])
        )

    @functools.cached_property
    def _topics(self) -> np.ndarray:
        """Per-class topic vocabularies, shared by every block (seeded off a
        dedicated stream so block generation stays random-access)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self._name_key, self.seed, _TOPIC_SALT])
        )
        topic_size = max(4, self.num_features // (2 * self.num_classes))
        return np.stack(
            [
                rng.choice(self.num_features, size=topic_size, replace=False)
                for _ in range(self.num_classes)
            ]
        )

    def generate_block(self, block: int):
        """All of one block's node data, drawn in a FIXED order from the
        block's own rng (labels -> out-edges -> features -> split coins).
        Returns ``(labels, edges, features, train, val, test)``; ``edges``
        are (m, 2) unique undirected pairs in GLOBAL indices whose source
        node lives in this block (partners may be anywhere)."""
        if not 0 <= block < self.num_blocks:
            raise IndexError(f"block {block} out of range [0, {self.num_blocks})")
        rng = self._block_rng(block)
        lo = block * self.block_size
        nb = min(self.block_size, self.num_nodes - lo)

        labels = rng.integers(0, self.num_classes, size=nb).astype(np.int64)

        # Zipf out-degree draws, vectorized over the block: repeat each
        # source by its target degree, flip one intra/inter coin per slot,
        # intra partners uniform over the SAME block's class members
        target = np.minimum(rng.zipf(self.zipf_a, size=nb), min(self.deg_cap, self.num_nodes - 1))
        src_local = np.repeat(np.arange(nb), target)
        total = int(target.sum())
        intra = rng.random(total) < self.p_intra
        partners = rng.integers(0, self.num_nodes, size=total)
        src_labels = labels[src_local]
        for c in range(self.num_classes):
            sel = intra & (src_labels == c)
            if not sel.any():
                continue
            members = np.flatnonzero(labels == c) + lo
            partners[sel] = members[rng.integers(0, len(members), size=int(sel.sum()))]
        src = src_local + lo
        a, b = np.minimum(src, partners), np.maximum(src, partners)
        keep = a != b
        edges = (
            np.unique(np.stack([a[keep], b[keep]], axis=1), axis=0)
            if keep.any()
            else np.zeros((0, 2), dtype=np.int64)
        )

        # vectorized _tfidf_features twin over the shared topic vocabularies
        words, on_topic_frac = 24, 0.17
        k_topic = max(1, int(round(words * on_topic_frac)))
        topics = self._topics
        on = topics[labels[:, None], rng.integers(0, topics.shape[1], size=(nb, k_topic))]
        off = rng.integers(0, self.num_features, size=(nb, words - k_topic))
        idx = np.concatenate([on, off], axis=1)
        vals = (rng.random((nb, words)) + 0.5).astype(np.float32)
        feats = np.zeros((nb, self.num_features), dtype=np.float32)
        feats[np.arange(nb)[:, None], idx] = vals
        row = feats.sum(axis=1, keepdims=True)
        row[row == 0] = 1.0
        feats /= row

        # streaming-friendly split: one uniform coin per node instead of the
        # global 20-per-class protocol (which needs every label at once)
        u = rng.random(nb)
        train = u < 0.10
        val = (u >= 0.10) & (u < 0.15)
        test = (u >= 0.15) & (u < 0.20)
        return labels, edges, feats, train, val, test

    def chunk_ranges(self, chunks: int) -> list[tuple[int, int]]:
        """``chunks`` near-equal contiguous node ranges covering the graph."""
        bounds = np.linspace(0, self.num_nodes, chunks + 1).astype(np.int64)
        return [(int(bounds[i]), int(bounds[i + 1])) for i in range(chunks)]

    @functools.cached_property
    def _edge_memo(self) -> dict:
        # plan construction asks for a range's edges twice (batch + cut
        # accounting); memoize per range, bounded by ranges actually used
        return {}

    def chunk_edges(self, lo: int, hi: int) -> tuple[np.ndarray, int]:
        """Edges of the node range ``[lo, hi)`` in LOCAL indices, plus the
        count of generated edges dropped for crossing the range boundary
        (the edge-cut numerator). Only blocks overlapping the range are
        generated; an edge with both endpoints inside always has its source
        endpoint in such a block, so the kept set equals the restriction of
        any containing range's kept set."""
        if not 0 <= lo < hi <= self.num_nodes:
            raise ValueError(f"bad chunk range [{lo}, {hi}) for {self.num_nodes} nodes")
        hit = self._edge_memo.get((lo, hi))
        if hit is not None:
            return hit
        parts, dropped = [], 0
        for blk in range(lo // self.block_size, -(-hi // self.block_size)):
            _, edges, *_ = self.generate_block(blk)
            touches = ((edges >= lo) & (edges < hi)).any(axis=1) if len(edges) else np.zeros(0, bool)
            inside = ((edges >= lo) & (edges < hi)).all(axis=1) if len(edges) else touches
            dropped += int(touches.sum() - inside.sum())
            parts.append(edges[inside])
        kept = np.concatenate(parts) if parts else np.zeros((0, 2), dtype=np.int64)
        # adjacent blocks can both source an edge that lands in the range
        kept = np.unique(kept, axis=0) if len(kept) else kept
        self._edge_memo[(lo, hi)] = (kept - lo, dropped)
        return kept - lo, dropped

    def chunk_batch(self, lo: int, hi: int, *, max_degree: int | None = None) -> GraphBatch:
        """Materialize node range ``[lo, hi)`` as a host-built GraphBatch
        (boundary-crossing edges dropped). ``max_degree`` caps the padded
        neighbor width like ``build_graph_batch``'s parameter."""
        feats, labels, train, val, test = [], [], [], [], []
        for blk in range(lo // self.block_size, -(-hi // self.block_size)):
            blk_lo = blk * self.block_size
            lab, _, f, tr, va, te = self.generate_block(blk)
            s = slice(max(lo - blk_lo, 0), min(hi - blk_lo, len(lab)))
            feats.append(f[s])
            labels.append(lab[s])
            train.append(tr[s])
            val.append(va[s])
            test.append(te[s])
        edges, _ = self.chunk_edges(lo, hi)
        neighbors, mask, norm = _padded_rows_from_edges(hi - lo, edges, max_degree)
        return GraphBatch(
            features=jnp.asarray(np.concatenate(feats)),
            neighbors=jnp.asarray(neighbors),
            mask=jnp.asarray(mask),
            norm=jnp.asarray(norm),
            labels=jnp.asarray(np.concatenate(labels), dtype=jnp.int32),
            train_mask=jnp.asarray(np.concatenate(train)),
            val_mask=jnp.asarray(np.concatenate(val)),
            test_mask=jnp.asarray(np.concatenate(test)),
            node_ids=jnp.arange(lo, hi, dtype=jnp.int32),
            num_classes=self.num_classes,
        )


def open_streamed(
    name: str,
    *,
    seed: int = 0,
    num_nodes: int | None = None,
    block_size: int = 4096,
    p_intra: float = 0.9,
) -> StreamedPowerlaw:
    """Open a ``STREAMED_DATASETS`` entry as a lazy block generator.

    ``num_nodes`` overrides the registry size (tests shrink the graph;
    benchmarks sweep sizes at fixed density knobs); ``block_size`` trades
    generation granularity for memory and NEVER changes the generated data
    of a block-aligned range of the same dataset name/seed/block_size.
    """
    if name not in STREAMED_DATASETS:
        raise KeyError(f"unknown streamed dataset {name!r}; have {sorted(STREAMED_DATASETS)}")
    n, d, c, zipf_a, deg_cap = STREAMED_DATASETS[name]
    return StreamedPowerlaw(
        name=name,
        num_nodes=n if num_nodes is None else num_nodes,
        num_features=d,
        num_classes=c,
        zipf_a=zipf_a,
        deg_cap=deg_cap,
        seed=seed,
        block_size=block_size,
        p_intra=p_intra,
    )


class DoubleBufferedLoader:
    """Iterate host pytrees as device-resident pytrees with the NEXT item's
    host->device transfer already dispatched while the caller computes on the
    current one.

    ``jax.device_put`` enqueues the copy asynchronously; by putting item
    ``t+1`` before yielding item ``t``, the transfer overlaps whatever the
    caller launches on ``t`` (the double-buffered ``device_put`` pattern —
    two items are in flight at any moment, never the whole stream). Used by
    the streamed-graph benches and examples to walk chunk batches a
    million-node graph can't hold on device all at once.
    """

    def __init__(self, source, device=None):
        self._source = source
        self._device = device

    def _put(self, item):
        return (
            jax.device_put(item, self._device)
            if self._device is not None
            else jax.device_put(item)
        )

    def __iter__(self):
        it = iter(self._source)
        try:
            nxt = self._put(next(it))
        except StopIteration:
            return
        for item in it:
            cur, nxt = nxt, self._put(item)
            yield cur
        yield nxt
