from repro.graphs.data import (
    BucketedGraphBatch,
    DegreeBucket,
    GraphBatch,
    build_graph_batch,
    subgraph,
    validate_graph,
)
from repro.graphs.datasets import DATASETS, SKEWED_DATASETS, load_dataset
from repro.graphs.partition import (
    bucketize_stacked,
    degree_bucket_widths,
    degree_bucketed_layout,
)

__all__ = [
    "GraphBatch",
    "BucketedGraphBatch",
    "DegreeBucket",
    "build_graph_batch",
    "subgraph",
    "validate_graph",
    "load_dataset",
    "DATASETS",
    "SKEWED_DATASETS",
    "degree_bucket_widths",
    "degree_bucketed_layout",
    "bucketize_stacked",
]
