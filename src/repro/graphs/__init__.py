from repro.graphs.data import GraphBatch, build_graph_batch, subgraph, validate_graph
from repro.graphs.datasets import load_dataset, DATASETS

__all__ = [
    "GraphBatch",
    "build_graph_batch",
    "subgraph",
    "validate_graph",
    "load_dataset",
    "DATASETS",
]
