"""Graph substrate: padded/bucketed batch layouts, the paper datasets,
the streamed power-law generator, and the chunk partitioners."""

from repro.graphs.data import (
    BucketedGraphBatch,
    DegreeBucket,
    GraphBatch,
    build_graph_batch,
    subgraph,
    validate_graph,
)
from repro.graphs.datasets import (
    DATASETS,
    SKEWED_DATASETS,
    STREAMED_DATASETS,
    DoubleBufferedLoader,
    StreamedPowerlaw,
    load_dataset,
    open_streamed,
)
from repro.graphs.partition import (
    bucketize_stacked,
    degree_bucket_widths,
    degree_bucketed_layout,
    streamed_plan,
)

__all__ = [
    "GraphBatch",
    "BucketedGraphBatch",
    "DegreeBucket",
    "build_graph_batch",
    "subgraph",
    "validate_graph",
    "load_dataset",
    "open_streamed",
    "streamed_plan",
    "DATASETS",
    "SKEWED_DATASETS",
    "STREAMED_DATASETS",
    "StreamedPowerlaw",
    "DoubleBufferedLoader",
    "degree_bucket_widths",
    "degree_bucketed_layout",
    "bucketize_stacked",
]
