"""Graph containers in a TPU-native *padded-neighbor* layout.

The paper's DGL/PyG backends aggregate with CUDA scatter/gather kernels.
TPUs have no fast random scatter, so the framework stores every node's
neighborhood padded to a fixed width ``max_deg``:

    neighbors : (n, max_deg) int32   — column j is the j-th neighbor of node i
    mask      : (n, max_deg) bool    — False on padding slots
    norm      : (n, max_deg) float32 — GCN symmetric-normalization 1/sqrt(d_i d_j)

Gathers over this layout are contiguous VMEM tiles and the weighted sums hit
the VPU/MXU — this is the hardware adaptation recorded in DESIGN.md §3.

Self-loops are stored explicitly in slot 0 (both GCN and GAT attend to the
node itself).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "features",
        "neighbors",
        "mask",
        "norm",
        "labels",
        "train_mask",
        "val_mask",
        "test_mask",
        "node_ids",
    ],
    meta_fields=["num_classes"],
)
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """A (sub)graph in padded-neighbor layout. A pytree — jit/shard friendly."""

    features: jax.Array  # (n, d) float
    neighbors: jax.Array  # (n, max_deg) int32, local indices; 0 on padding
    mask: jax.Array  # (n, max_deg) bool
    norm: jax.Array  # (n, max_deg) float32 GCN coefficients
    labels: jax.Array  # (n,) int32
    train_mask: jax.Array  # (n,) bool
    val_mask: jax.Array  # (n,) bool
    test_mask: jax.Array  # (n,) bool
    node_ids: jax.Array  # (n,) int32 global ids (for sub-graph bookkeeping)
    num_classes: int = 2

    @property
    def num_nodes(self) -> int:
        """Rows in the batch (padding rows included once padded)."""
        return self.features.shape[0]

    @property
    def max_degree(self) -> int:
        """Width of the padded neighbor table (excluding nothing: slot 0
        is the self-loop)."""
        return self.neighbors.shape[1]

    @property
    def num_features(self) -> int:
        """Feature dimensionality."""
        return self.features.shape[1]

    @property
    def num_edges(self) -> jax.Array:
        """Directed edge slots in use, excluding self-loops."""
        return jnp.sum(self.mask) - self.num_nodes


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["neighbors", "norm", "mask", "row_node"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class DegreeBucket:
    """One degree bucket: a dense ``(rows_b, width_b)`` neighbor tile.

    Rows whose (compacted) slot count fits ``width_b`` but not the previous
    bucket's width live here, padded up to the bucket's row capacity with
    inert rows (mask all-False, norm 0 — they aggregate to zero and are
    never gathered). ``neighbors`` indexes the ORIGINAL node numbering, so
    the feature matrix needs no reordering.
    """

    neighbors: jax.Array  # (rows_b, width_b) int32, original node indices
    norm: jax.Array  # (rows_b, width_b) float — 0 on padding slots/rows
    mask: jax.Array  # (rows_b, width_b) bool
    row_node: jax.Array  # (rows_b,) int32 — original row each tile row holds

    @property
    def width(self) -> int:
        """Neighbor-slot width of this bucket's tile."""
        return self.neighbors.shape[-1]

    @property
    def rows(self) -> int:
        """Row capacity of this bucket's tile (padding rows included)."""
        return self.neighbors.shape[-2]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["base", "buckets", "gather_rows"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class BucketedGraphBatch:
    """A GraphBatch plus its degree-bucketed aggregation layout.

    Wraps (not replaces) the padded batch: attribute access falls through to
    ``base``, so every consumer of the padded layout — loss masks, pipeline
    plumbing, dense/padded backends — works unchanged, while the pallas
    layers pick up ``buckets``/``gather_rows`` when present. Aggregation
    reads per-bucket tiles and writes rows back through ``gather_rows``
    (node i's output lives at concat-row ``gather_rows[i]``); inert bucket
    padding rows are never referenced.
    """

    base: GraphBatch
    buckets: tuple[DegreeBucket, ...]
    gather_rows: jax.Array  # (n,) int32 into the bucket-concat row space

    def __getattr__(self, name):
        # only reached when normal lookup fails -> delegate to the base batch
        return getattr(object.__getattribute__(self, "base"), name)


def _edges_to_adj_lists(num_nodes: int, edges: np.ndarray) -> list[list[int]]:
    """Undirected edge list (m, 2) -> per-node sorted neighbor lists."""
    adj: list[set[int]] = [set() for _ in range(num_nodes)]
    for a, b in edges:
        a, b = int(a), int(b)
        if a == b:
            continue
        adj[a].add(b)
        adj[b].add(a)
    return [sorted(s) for s in adj]


def build_graph_batch(
    features: np.ndarray,
    edges: np.ndarray,
    labels: np.ndarray,
    num_classes: int,
    *,
    train_mask: np.ndarray | None = None,
    val_mask: np.ndarray | None = None,
    test_mask: np.ndarray | None = None,
    max_degree: int | None = None,
    dtype=jnp.float32,
) -> GraphBatch:
    """Build a GraphBatch from a numpy undirected edge list.

    ``max_degree`` caps the padded width (excess neighbors dropped
    deterministically, highest-index first); default is the true max degree.
    Slot 0 always holds the self-loop.
    """
    n = features.shape[0]
    adj = _edges_to_adj_lists(n, edges)
    true_max = max((len(a) for a in adj), default=0)
    width = 1 + (true_max if max_degree is None else min(max_degree, true_max))

    neighbors = np.zeros((n, width), dtype=np.int32)
    mask = np.zeros((n, width), dtype=bool)
    deg = np.array([len(a) for a in adj], dtype=np.float64) + 1.0  # self-loop

    for i, nbrs in enumerate(adj):
        nbrs = nbrs[: width - 1]
        neighbors[i, 0] = i  # self-loop
        mask[i, 0] = True
        neighbors[i, 1 : 1 + len(nbrs)] = nbrs
        mask[i, 1 : 1 + len(nbrs)] = True

    # GCN symmetric normalization over the self-looped graph.
    inv_sqrt = 1.0 / np.sqrt(deg)
    norm = inv_sqrt[:, None] * inv_sqrt[neighbors] * mask

    def _m(m):
        return np.ones(n, dtype=bool) if m is None else np.asarray(m, dtype=bool)

    return GraphBatch(
        features=jnp.asarray(features, dtype=dtype),
        neighbors=jnp.asarray(neighbors),
        mask=jnp.asarray(mask),
        norm=jnp.asarray(norm, dtype=dtype),
        labels=jnp.asarray(labels, dtype=jnp.int32),
        train_mask=jnp.asarray(_m(train_mask)),
        val_mask=jnp.asarray(_m(val_mask)),
        test_mask=jnp.asarray(_m(test_mask)),
        node_ids=jnp.arange(n, dtype=jnp.int32),
        num_classes=int(num_classes),
    )


def subgraph(g: GraphBatch, node_idx: np.ndarray, *, keep_halo_edges: bool = False) -> GraphBatch:
    """Re-build the sub-graph induced by ``node_idx`` — the paper's §6 step.

    Exactly reproduces the paper's lossy behaviour: every edge with an
    endpoint outside ``node_idx`` is dropped (unless the halo machinery in
    graphs/partition.py has already extended ``node_idx``).

    Host-side (numpy) by design: the paper performs this on CPU per
    micro-batch, and our Fig-3 analogue charges this exact cost.
    """
    node_idx = np.asarray(node_idx)
    n_sub = node_idx.shape[0]
    old_neighbors = np.asarray(g.neighbors)[node_idx]
    old_mask = np.asarray(g.mask)[node_idx]

    # global -> local remap; -1 marks "outside the chunk"
    remap = -np.ones(g.num_nodes, dtype=np.int64)
    remap[node_idx] = np.arange(n_sub)

    local = remap[old_neighbors]
    keep = old_mask & (local >= 0)
    local = np.where(keep, local, 0)

    deg = keep.sum(axis=1).astype(np.float64)  # includes self-loop
    inv_sqrt = np.zeros_like(deg)
    nz = deg > 0
    inv_sqrt[nz] = 1.0 / np.sqrt(deg[nz])
    norm = inv_sqrt[:, None] * inv_sqrt[np.where(keep, local, 0)] * keep
    del keep_halo_edges  # halo logic lives in graphs/partition.py

    return GraphBatch(
        features=g.features[node_idx],
        neighbors=jnp.asarray(local.astype(np.int32)),
        mask=jnp.asarray(keep),
        norm=jnp.asarray(norm, dtype=g.norm.dtype),
        labels=g.labels[node_idx],
        train_mask=g.train_mask[node_idx],
        val_mask=g.val_mask[node_idx],
        test_mask=g.test_mask[node_idx],
        node_ids=g.node_ids[node_idx],
        num_classes=g.num_classes,
    )


def pad_graph(g: GraphBatch, n_pad: int, max_deg: int) -> GraphBatch:
    """Pad a (sub)graph to exactly ``n_pad`` nodes and ``max_deg`` neighbor
    slots so chunks of different sizes become one uniform-shape pytree.

    Extra rows are isolated non-nodes: no edge slots (mask False everywhere,
    so even the self-loop is absent), zero norm, label 0, every split mask
    False, node_id -1. They contribute nothing to aggregation or loss.
    Extra neighbor columns are padding slots (mask False, norm 0).
    """
    n, w = g.num_nodes, g.max_degree
    if n_pad < n or max_deg < w:
        raise ValueError(f"pad target ({n_pad}, {max_deg}) smaller than graph ({n}, {w})")
    if n_pad == n and max_deg == w:
        return g
    dn, dw = n_pad - n, max_deg - w

    def rows(a, fill=0):
        pad_widths = [(0, dn)] + [(0, 0)] * (a.ndim - 1)
        return jnp.pad(a, pad_widths, constant_values=fill)

    return GraphBatch(
        features=rows(g.features),
        neighbors=rows(jnp.pad(g.neighbors, ((0, 0), (0, dw)))),
        mask=rows(jnp.pad(g.mask, ((0, 0), (0, dw)))),
        norm=rows(jnp.pad(g.norm, ((0, 0), (0, dw)))),
        labels=rows(g.labels),
        train_mask=rows(g.train_mask),
        val_mask=rows(g.val_mask),
        test_mask=rows(g.test_mask),
        node_ids=rows(g.node_ids, fill=-1),
        num_classes=g.num_classes,
    )


def validate_graph(g: GraphBatch) -> None:
    """Structural invariants (used by tests and the data pipeline)."""
    n, w = g.neighbors.shape
    assert g.mask.shape == (n, w)
    assert g.norm.shape == (n, w)
    assert g.features.shape[0] == n
    assert g.labels.shape == (n,)
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    assert nbr.min() >= 0 and nbr.max() < max(n, 1), "neighbor index out of range"
    assert np.all(np.asarray(g.norm)[~msk] == 0), "norm must be 0 on padding"
    # self-loop in slot 0 wherever the node has any edge slot at all
    has_any = msk.any(axis=1)
    assert np.all(nbr[has_any, 0] == np.arange(n)[has_any]), "slot 0 must be the self-loop"
