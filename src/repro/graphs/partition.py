"""Node partitioners + halo expansion for graph micro-batching.

``sequential`` is the paper's §6/§7.3 behaviour: GPipe splits the node-index
tensor *by position*, so chunk boundaries cut edges arbitrarily. ``greedy``
is a lightweight edge-cut-aware partitioner (METIS stand-in). ``halo``
expands a chunk with its k-hop neighborhood so message passing stays exact —
the "intelligent graph batching" the paper calls for in §8.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.data import GraphBatch


def sequential_partition(num_nodes: int, chunks: int) -> list[np.ndarray]:
    """Index-sequential split — exactly what torchgpipe does to a tensor."""
    return [np.asarray(p) for p in np.array_split(np.arange(num_nodes), chunks)]


def random_partition(num_nodes: int, chunks: int, *, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_nodes)
    return [np.sort(p) for p in np.array_split(perm, chunks)]


def _adjacency_sets(g: GraphBatch) -> list[set[int]]:
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    out: list[set[int]] = []
    for i in range(nbr.shape[0]):
        s = set(int(j) for j, m in zip(nbr[i], msk[i]) if m and j != i)
        out.append(s)
    return out


def greedy_partition(g: GraphBatch, chunks: int, *, seed: int = 0) -> list[np.ndarray]:
    """Greedy BFS-grown balanced partitions (edge-cut-aware METIS stand-in).

    Grows each part from a random seed by BFS, preferring frontier nodes, so
    intra-part connectivity is much higher than an index split."""
    n = g.num_nodes
    adj = _adjacency_sets(g)
    rng = np.random.default_rng(seed)
    target = [len(p) for p in np.array_split(np.arange(n), chunks)]
    unassigned = set(range(n))
    parts: list[list[int]] = []
    order = rng.permutation(n)
    cursor = 0
    for c in range(chunks):
        part: list[int] = []
        frontier: list[int] = []
        while len(part) < target[c] and unassigned:
            if not frontier:
                # pick a fresh unassigned seed
                while cursor < n and order[cursor] not in unassigned:
                    cursor += 1
                if cursor >= n:
                    frontier = [next(iter(unassigned))]
                else:
                    frontier = [int(order[cursor])]
            node = frontier.pop()
            if node not in unassigned:
                continue
            unassigned.discard(node)
            part.append(node)
            frontier.extend(j for j in adj[node] if j in unassigned)
        parts.append(part)
    # dump any stragglers into the last part
    parts[-1].extend(unassigned)
    return [np.sort(np.array(p, dtype=np.int64)) for p in parts]


def pad_partition(
    nodes: np.ndarray, core: np.ndarray, n_pad: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pad a chunk's (nodes, core_mask) spec to ``n_pad`` entries by repeating
    node 0 with core_mask False — the padded duplicates lose their edges in
    ``subgraph()``'s remap and their loss mask is off, so they are inert.
    Uniform chunk sizes let one jitted step (or one stacked scan) serve every
    chunk."""
    extra = n_pad - len(nodes)
    if extra < 0:
        raise ValueError(f"chunk of {len(nodes)} nodes exceeds pad target {n_pad}")
    if extra == 0:
        return nodes, core
    nodes = np.concatenate([nodes, np.zeros(extra, dtype=nodes.dtype)])
    core = np.concatenate([core, np.zeros(extra, dtype=bool)])
    return nodes, core


def expand_halo(g: GraphBatch, core: np.ndarray, hops: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (nodes, core_mask): ``core`` plus its ``hops``-hop neighborhood.

    ``core_mask[i]`` is True iff nodes[i] is a core node (loss/update target).
    With hops == model receptive depth, aggregation on the halo'd sub-graph is
    exact for every core node."""
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    current = np.zeros(g.num_nodes, dtype=bool)
    current[core] = True
    reach = current.copy()
    for _ in range(hops):
        sel = np.flatnonzero(reach)
        hop = nbr[sel][msk[sel]]
        nxt = reach.copy()
        nxt[hop] = True
        reach = nxt
    nodes = np.flatnonzero(reach)
    core_mask = current[nodes]
    return nodes, core_mask


def ego_subgraph(
    g: GraphBatch, seeds: np.ndarray, hops: int
) -> tuple[GraphBatch, np.ndarray]:
    """The ``hops``-hop ego-subgraph around ``seeds`` plus the seeds' local
    row indices — the serving frontend's extraction step.

    With ``hops`` >= the model's receptive depth the halo is lossless:
    every message a seed aggregates exists in the sub-graph, so its
    prediction equals the full-graph one (bit-identically on the padded
    backend — ``subgraph`` preserves each kept node's neighbor column order
    and trailing pad columns contribute exact zeros)."""
    from repro.graphs.data import subgraph

    seeds = np.asarray(seeds)
    nodes, _ = expand_halo(g, seeds, hops)
    sub = subgraph(g, nodes)
    # expand_halo returns nodes as flatnonzero output — sorted ascending —
    # so the seeds' local rows come from a binary search
    rows = np.searchsorted(nodes, seeds)
    return sub, rows


def edge_cut_fraction(g: GraphBatch, parts: list[np.ndarray]) -> float:
    """Fraction of (directed, non-self) edge slots crossing part boundaries —
    the information the paper's sequential split throws away."""
    owner = np.empty(g.num_nodes, dtype=np.int64)
    for pid, p in enumerate(parts):
        owner[p] = pid
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask).copy()
    msk[:, 0] = False  # ignore self-loops
    src_owner = np.broadcast_to(owner[:, None], nbr.shape)
    cut = (owner[nbr] != src_owner) & msk
    total = msk.sum()
    return float(cut.sum()) / float(max(total, 1))
