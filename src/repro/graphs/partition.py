"""Node partitioners + halo expansion for graph micro-batching, plus the
degree-bucketed aggregation layout.

``sequential`` is the paper's §6/§7.3 behaviour: GPipe splits the node-index
tensor *by position*, so chunk boundaries cut edges arbitrarily. ``greedy``
is a lightweight edge-cut-aware partitioner (METIS stand-in). ``halo``
expands a chunk with its k-hop neighborhood so message passing stays exact —
the "intelligent graph batching" the paper calls for in §8.

``degree_bucketed_layout`` re-tiles the padded ``(n, max_deg)`` neighbor
matrix into geometric degree buckets (widths 8/16/32/…/max_deg): each row
moves to the narrowest bucket its live slot count fits, so aggregation work
scales with the degree *distribution* instead of the single worst-case
degree — on power-law graphs the padded layout spends almost all its slots
on padding for a handful of hubs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.data import BucketedGraphBatch, DegreeBucket, GraphBatch


def sequential_partition(num_nodes: int, chunks: int) -> list[np.ndarray]:
    """Index-sequential split — exactly what torchgpipe does to a tensor."""
    return [np.asarray(p) for p in np.array_split(np.arange(num_nodes), chunks)]


def random_partition(num_nodes: int, chunks: int, *, seed: int = 0) -> list[np.ndarray]:
    """Uniformly random node split — the locality-free baseline the greedy
    partitioner is compared against."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_nodes)
    return [np.sort(p) for p in np.array_split(perm, chunks)]


def _adjacency_sets(g: GraphBatch) -> list[set[int]]:
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    out: list[set[int]] = []
    for i in range(nbr.shape[0]):
        s = set(int(j) for j, m in zip(nbr[i], msk[i]) if m and j != i)
        out.append(s)
    return out


def greedy_partition(g: GraphBatch, chunks: int, *, seed: int = 0) -> list[np.ndarray]:
    """Greedy BFS-grown balanced partitions (edge-cut-aware METIS stand-in).

    Grows each part from a random seed by BFS, preferring frontier nodes, so
    intra-part connectivity is much higher than an index split."""
    n = g.num_nodes
    adj = _adjacency_sets(g)
    rng = np.random.default_rng(seed)
    target = [len(p) for p in np.array_split(np.arange(n), chunks)]
    unassigned = set(range(n))
    parts: list[list[int]] = []
    order = rng.permutation(n)
    cursor = 0
    for c in range(chunks):
        part: list[int] = []
        frontier: list[int] = []
        while len(part) < target[c] and unassigned:
            if not frontier:
                # pick a fresh unassigned seed
                while cursor < n and order[cursor] not in unassigned:
                    cursor += 1
                if cursor >= n:
                    frontier = [next(iter(unassigned))]
                else:
                    frontier = [int(order[cursor])]
            node = frontier.pop()
            if node not in unassigned:
                continue
            unassigned.discard(node)
            part.append(node)
            frontier.extend(j for j in adj[node] if j in unassigned)
        parts.append(part)
    # dump any stragglers into the last part
    parts[-1].extend(unassigned)
    return [np.sort(np.array(p, dtype=np.int64)) for p in parts]


def pad_partition(
    nodes: np.ndarray, core: np.ndarray, n_pad: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pad a chunk's (nodes, core_mask) spec to ``n_pad`` entries by repeating
    node 0 with core_mask False — the padded duplicates lose their edges in
    ``subgraph()``'s remap and their loss mask is off, so they are inert.
    Uniform chunk sizes let one jitted step (or one stacked scan) serve every
    chunk."""
    extra = n_pad - len(nodes)
    if extra < 0:
        raise ValueError(f"chunk of {len(nodes)} nodes exceeds pad target {n_pad}")
    if extra == 0:
        return nodes, core
    nodes = np.concatenate([nodes, np.zeros(extra, dtype=nodes.dtype)])
    core = np.concatenate([core, np.zeros(extra, dtype=bool)])
    return nodes, core


def expand_halo(g: GraphBatch, core: np.ndarray, hops: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (nodes, core_mask): ``core`` plus its ``hops``-hop neighborhood.

    ``core_mask[i]`` is True iff nodes[i] is a core node (loss/update target).
    With hops == model receptive depth, aggregation on the halo'd sub-graph is
    exact for every core node."""
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    current = np.zeros(g.num_nodes, dtype=bool)
    current[core] = True
    reach = current.copy()
    for _ in range(hops):
        sel = np.flatnonzero(reach)
        hop = nbr[sel][msk[sel]]
        nxt = reach.copy()
        nxt[hop] = True
        reach = nxt
    nodes = np.flatnonzero(reach)
    core_mask = current[nodes]
    return nodes, core_mask


def ego_subgraph(
    g: GraphBatch, seeds: np.ndarray, hops: int
) -> tuple[GraphBatch, np.ndarray]:
    """The ``hops``-hop ego-subgraph around ``seeds`` plus the seeds' local
    row indices — the serving frontend's extraction step.

    With ``hops`` >= the model's receptive depth the halo is lossless:
    every message a seed aggregates exists in the sub-graph, so its
    prediction equals the full-graph one (bit-identically on the padded
    backend — ``subgraph`` preserves each kept node's neighbor column order
    and trailing pad columns contribute exact zeros)."""
    from repro.graphs.data import subgraph

    seeds = np.asarray(seeds)
    nodes, _ = expand_halo(g, seeds, hops)
    sub = subgraph(g, nodes)
    # expand_halo returns nodes as flatnonzero output — sorted ascending —
    # so the seeds' local rows come from a binary search
    rows = np.searchsorted(nodes, seeds)
    return sub, rows


def degree_bucket_widths(max_deg: int, *, base: int = 8) -> tuple[int, ...]:
    """Geometric bucket-width ladder ``(base, 2·base, …, max_deg)``.

    ``max_deg`` is the padded layout's slot width (self-loop included) and is
    always the last rung, so every row fits somewhere.
    """
    if max_deg <= 0:
        raise ValueError(f"max_deg must be positive, got {max_deg}")
    widths: list[int] = []
    w = base
    while w < max_deg:
        widths.append(w)
        w *= 2
    widths.append(max_deg)
    return tuple(widths)


def degree_bucketed_layout(
    g: GraphBatch,
    widths: tuple[int, ...] | None = None,
    *,
    row_capacities: tuple[int, ...] | None = None,
    block: int = 8,
) -> BucketedGraphBatch:
    """Permute rows into degree buckets; carry the permutation + inverse.

    Each row's live slots are first compacted leftward (``subgraph()`` can
    leave holes in ``mask``), then the row is assigned to the narrowest
    bucket whose width covers its slot count (slot-less padding rows land in
    bucket 0 as inert all-masked rows). Each bucket is padded to a row
    capacity — a multiple of ``block`` by default, or the caller's
    ``row_capacities`` when several chunks must share one set of bucket
    shapes (one jitted program for all chunks). The permutation lives in
    ``row_node`` (bucket row -> original row) and its inverse in
    ``gather_rows`` (original row -> bucket-concat row).

    Host-side (numpy) by design, like ``subgraph``: layout construction is a
    per-plan preprocessing step, never part of the jitted hot path.
    """
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    nrm = np.asarray(g.norm)
    n, max_deg = nbr.shape
    if widths is None:
        widths = degree_bucket_widths(max_deg)
    if widths[-1] < max_deg:
        raise ValueError(f"last bucket width {widths[-1]} < layout width {max_deg}")
    if row_capacities is not None and len(row_capacities) != len(widths):
        raise ValueError("row_capacities must match widths")

    # compact live slots leftward: stable argsort of ~mask keeps live-slot
    # order (slot 0's self-loop stays first) while closing subgraph() holes.
    # Within-row slot order only affects float summation order, which the
    # oracle-tolerance equivalence tests already absorb.
    order = np.argsort(~msk, axis=1, kind="stable")
    nbr = np.take_along_axis(nbr, order, axis=1)
    nrm = np.take_along_axis(nrm, order, axis=1)
    msk = np.take_along_axis(msk, order, axis=1)
    slots = msk.sum(axis=1)  # live slots per row (self-loop included)

    # narrowest bucket whose width >= slots; slot-less rows -> bucket 0
    bucket_of = np.searchsorted(np.asarray(widths), slots)

    buckets: list[DegreeBucket] = []
    gather = np.zeros(n, dtype=np.int32)
    offset = 0
    for b, wb in enumerate(widths):
        rows = np.flatnonzero(bucket_of == b)
        if row_capacities is not None:
            cap = int(row_capacities[b])
        else:
            cap = -(-len(rows) // block) * block if len(rows) else 0
        if cap < len(rows):
            raise ValueError(f"bucket {b}: capacity {cap} < {len(rows)} rows")
        b_nbr = np.zeros((cap, wb), dtype=np.int32)
        b_nrm = np.zeros((cap, wb), dtype=nrm.dtype)
        b_msk = np.zeros((cap, wb), dtype=bool)
        b_row = np.zeros(cap, dtype=np.int32)
        b_nbr[: len(rows)] = nbr[rows, :wb]
        b_nrm[: len(rows)] = nrm[rows, :wb]
        b_msk[: len(rows)] = msk[rows, :wb]
        b_row[: len(rows)] = rows
        gather[rows] = offset + np.arange(len(rows), dtype=np.int32)
        buckets.append(
            DegreeBucket(
                neighbors=jnp.asarray(b_nbr),
                norm=jnp.asarray(b_nrm, dtype=g.norm.dtype),
                mask=jnp.asarray(b_msk),
                row_node=jnp.asarray(b_row),
            )
        )
        offset += cap
    return BucketedGraphBatch(
        base=g, buckets=tuple(buckets), gather_rows=jnp.asarray(gather)
    )


def bucketize_stacked(
    g: GraphBatch, *, widths: tuple[int, ...] | None = None, block: int = 8
) -> BucketedGraphBatch:
    """Bucketize a chunk-stacked graph (leading ``chunks`` axis on every leaf).

    All chunks share one set of bucket row capacities (the per-bucket max
    over chunks, rounded up to ``block``), so the per-chunk layouts stack
    into uniform-shape arrays and one jitted stage program serves every
    chunk — the same uniformity contract ``MicroBatchPlan.stacked()`` keeps
    for the padded layout.
    """
    msk = np.asarray(g.mask)  # (chunks, n_pad, max_deg)
    chunks, _, max_deg = msk.shape
    if widths is None:
        widths = degree_bucket_widths(max_deg)
    slots = msk.sum(axis=2)  # (chunks, n_pad)
    bucket_of = np.searchsorted(np.asarray(widths), slots)
    caps = []
    for b in range(len(widths)):
        most = int((bucket_of == b).sum(axis=1).max())
        caps.append(-(-most // block) * block if most else 0)
    caps = tuple(caps)

    per_chunk = [
        degree_bucketed_layout(
            jax.tree_util.tree_map(lambda a, c=c: a[c], g),
            widths,
            row_capacities=caps,
            block=block,
        )
        for c in range(chunks)
    ]
    stacked_buckets = tuple(
        DegreeBucket(
            neighbors=jnp.stack([pc.buckets[b].neighbors for pc in per_chunk]),
            norm=jnp.stack([pc.buckets[b].norm for pc in per_chunk]),
            mask=jnp.stack([pc.buckets[b].mask for pc in per_chunk]),
            row_node=jnp.stack([pc.buckets[b].row_node for pc in per_chunk]),
        )
        for b in range(len(widths))
    )
    gather = jnp.stack([pc.gather_rows for pc in per_chunk])
    return BucketedGraphBatch(base=g, buckets=stacked_buckets, gather_rows=gather)


def edge_cut_fraction(g: GraphBatch, parts: list[np.ndarray]) -> float:
    """Fraction of (directed, non-self) edge slots crossing part boundaries —
    the information the paper's sequential split throws away."""
    owner = np.empty(g.num_nodes, dtype=np.int64)
    for pid, p in enumerate(parts):
        owner[p] = pid
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask).copy()
    msk[:, 0] = False  # ignore self-loops
    src_owner = np.broadcast_to(owner[:, None], nbr.shape)
    cut = (owner[nbr] != src_owner) & msk
    total = msk.sum()
    return float(cut.sum()) / float(max(total, 1))


def streamed_plan(ds, chunks: int, *, max_degree: int | None = None):
    """Micro-batch plan over a ``repro.graphs.datasets.StreamedPowerlaw``:
    ``chunks`` contiguous node ranges, each materialized independently via
    ``ds.chunk_batch`` so the full graph never exists in memory — the
    streamed analogue of ``make_plan(..., strategy="sequential")`` (same
    lossy boundary semantics, same all-core masks, same plan container the
    pipeline engines consume).

    ``edge_cut`` is computed from the generator's own drop counts (edges
    generated with exactly one endpoint inside a chunk), since there is no
    whole graph to diff against.
    """
    import time

    from repro.core.microbatch import MicroBatch, MicroBatchPlan

    t0 = time.perf_counter()
    batches, kept, dropped = [], 0, 0
    for lo, hi in ds.chunk_ranges(chunks):
        g = ds.chunk_batch(lo, hi, max_degree=max_degree)
        _, d = ds.chunk_edges(lo, hi)
        kept += int(g.num_edges) // 2
        dropped += d
        batches.append(MicroBatch(graph=g, core_mask=jnp.ones(g.num_nodes, dtype=bool)))
    return MicroBatchPlan(
        strategy="streamed",
        chunks=chunks,
        batches=batches,
        rebuild_seconds=time.perf_counter() - t0,
        edge_cut=float(dropped) / float(max(kept + dropped, 1)),
    )
