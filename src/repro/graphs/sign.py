"""SIGN — Scalable Inception Graph Networks (Frasca et al. 2020).

The paper's §8 names SIGN as "may be the best batching approach ... for
parallelizing GNNs with our implementation": precompute r-hop diffusion
operators ONCE, after which the model is a plain MLP over concatenated
diffused features — micro-batching becomes trivially exact (no graph
structure rides through the pipeline at all).

``sign_features``: X ↦ [X, ÂX, Â²X, …, ÂʳX]  (Â = sym-normalized adjacency)
``build_sign_mlp``: the inception-style classifier, expressed as a
``GNNModel`` so the same GPipe engine drives it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graphs.data import GraphBatch
from repro.models.gnn import layers as L
from repro.models.gnn.net import GNNModel, SeqLayer


def diffuse(g: GraphBatch, h: jax.Array) -> jax.Array:
    """One Â·h step over the padded-neighbor layout."""
    return jnp.einsum("nd,ndf->nf", g.norm, h[g.neighbors])


def sign_features(g: GraphBatch, *, hops: int = 2) -> jax.Array:
    """(n, (hops+1)·d) concatenated diffusion features, precomputed once."""
    feats = [g.features]
    h = g.features
    for _ in range(hops):
        h = diffuse(g, h)
        feats.append(h)
    return jnp.concatenate(feats, axis=-1)


def build_sign_mlp(
    in_dim: int, num_classes: int, *, hidden: int = 64, dropout: float = 0.5
) -> GNNModel:
    """Inception MLP over precomputed features. Structure-free: every layer
    ignores the graph, so ANY micro-batching strategy is exact."""

    def dense(name, din, dout, act):
        def init(key):
            return {"w": L.glorot(key, (din, dout)), "b": jnp.zeros((dout,))}

        def apply(p, g, h, rng, train):
            out = h @ p["w"] + p["b"]
            return act(out) if act is not None else out

        return SeqLayer(name, init, apply)

    layers = (
        dense("sign_fc0", in_dim, hidden, jax.nn.relu),
        SeqLayer("dropout", lambda k: {},
                 lambda p, g, h, rng, train: L.dropout(h, dropout, rng, train)),
        dense("sign_fc1", hidden, num_classes, None),
        SeqLayer("log_softmax", lambda k: {},
                 lambda p, g, h, rng, train: jax.nn.log_softmax(h, axis=-1)),
    )
    return GNNModel(layers=layers, in_dim=in_dim, out_dim=num_classes)


def as_sign_graph(g: GraphBatch, *, hops: int = 2) -> GraphBatch:
    """GraphBatch whose features are SIGN-diffused and whose edges are
    DROPPED (self-loops only) — proving downstream exactness needs no
    structure. Plugs straight into the GPipe engine + any chunking."""
    import dataclasses
    import numpy as np

    feats = sign_features(g, hops=hops)
    n = g.num_nodes
    neighbors = jnp.asarray(np.arange(n, dtype=np.int32)[:, None])
    mask = jnp.ones((n, 1), bool)
    norm = jnp.ones((n, 1), feats.dtype)
    return dataclasses.replace(
        g, features=feats, neighbors=neighbors, mask=mask, norm=norm
    )
