"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L, d_model=768, attention-free, ssm_state=128, expand 2 (d_inner 1536,
head_dim 64 → 24 ssm heads), vocab=50280. The only fully sub-quadratic
assigned arch — long_500k runs natively.
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m",
        arch_type="ssm",
        source="arXiv:2405.21060",
        num_layers=24,
        d_model=768,
        num_heads=0,
        num_kv_heads=0,
        head_dim=1,
        d_ff=0,
        vocab_size=50280,
        attn_kind="none",
        rope_kind="none",
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        mlp_kind="swiglu",
        tie_embeddings=True,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m",
        arch_type="ssm",
        source="arXiv:2405.21060",
        num_layers=2,
        d_model=128,
        num_heads=0,
        num_kv_heads=0,
        head_dim=1,
        d_ff=0,
        vocab_size=512,
        attn_kind="none",
        rope_kind="none",
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=32,
        mlp_kind="swiglu",
        tie_embeddings=True,
    )


register_arch(config, smoke)
