"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

81 layer slots, d_model=3584, ssm_state=64; a weight-SHARED attention block
(32 heads, GQA kv=32) is applied every 6th slot, mamba2 elsewhere. The
shared block's weights are passed as non-scanned captures through the
pipeline (DESIGN.md §6); mamba parameters at attention slots are inert.
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        arch_type="hybrid",
        source="arXiv:2411.15242",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        hybrid_attn_every=6,
        mlp_kind="swiglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b",
        arch_type="hybrid",
        source="arXiv:2411.15242",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        ssm_chunk=32,
        hybrid_attn_every=2,
        mlp_kind="swiglu",
    )


register_arch(config, smoke)
