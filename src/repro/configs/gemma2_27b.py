"""gemma2-27b [dense] — local/global alternation + softcaps [arXiv:2408.00118].

46L, d_model=4608, 32 heads (head_dim 128), GQA kv=16, d_ff=36864 (GeGLU),
vocab=256000. Even layers use a 4096 sliding window; attention softcap 50,
final-logit softcap 30; sandwich (pre+post) RMSNorms.
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b",
        arch_type="dense",
        source="arXiv:2408.00118",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        window_size=4096,
        window_pattern="alternate",
        attn_softcap=50.0,
        logit_softcap=30.0,
        sandwich_norms=True,
        mlp_kind="geglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b",
        arch_type="dense",
        source="arXiv:2408.00118",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        window_size=64,
        window_pattern="alternate",
        attn_softcap=50.0,
        logit_softcap=30.0,
        sandwich_norms=True,
        mlp_kind="geglu",
    )


register_arch(config, smoke)
