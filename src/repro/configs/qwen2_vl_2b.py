"""qwen2-vl-2b [vlm] — M-RoPE + dynamic resolution [arXiv:2409.12191].

Transformer backbone only (assignment carve-out): the ViT vision encoder +
projector is a stub; ``input_specs`` provides precomputed patch embeddings
occupying ``frontend_frac`` of the sequence. 28L, d_model=1536, 12 heads,
GQA kv=2, d_ff=8960, vocab=151936, M-RoPE (3-section rotary).
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        source="arXiv:2409.12191",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        rope_kind="mrope",
        mlp_kind="swiglu",
        frontend="vision",
        frontend_frac=0.25,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        source="arXiv:2409.12191",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        rope_kind="mrope",
        mlp_kind="swiglu",
        frontend="vision",
        frontend_frac=0.25,
    )


register_arch(config, smoke)
