"""deepseek-v3-671b [moe] — MLA + 1 shared/256 routed top-8 + MTP
[arXiv:2412.19437].

61L, d_model=7168, 128 heads MLA (kv_lora 512, q_lora 1536, nope 128 +
rope 64, v 128), expert d_ff=2048, vocab=129280, sigmoid router with top-8 of
256 routed experts + 1 shared expert. MTP implemented as an auxiliary
next-next-token head. Deviation from the HF card: the first-3-dense-layers
exception is dropped so layer slots stay homogeneous for the pipeline scan
(DESIGN.md §6).
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        source="arXiv:2412.19437",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        d_ff=2048,
        vocab_size=129280,
        attn_kind="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=256,
        experts_per_token=8,
        num_shared_experts=1,
        router_kind="sigmoid",
        mtp=True,
        mlp_kind="swiglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        source="arXiv:2412.19437",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        attn_kind="mla",
        q_lora_rank=48,
        kv_lora_rank=32,
        qk_nope_head_dim=32,
        qk_rope_head_dim=16,
        v_head_dim=32,
        num_experts=4,
        experts_per_token=2,
        num_shared_experts=1,
        router_kind="sigmoid",
        mtp=True,
        mlp_kind="swiglu",
    )


register_arch(config, smoke)
