"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

Transformer backbone only (assignment carve-out): the EnCodec conv codec is
a stub; ``input_specs`` provides precomputed frame embeddings for the audio
prompt portion of the sequence. 48L, d_model=2048, 32 heads (MHA, kv=32),
d_ff=8192 (GELU MLP, as in the paper's standard transformer), vocab=2048
(EnCodec codebook size).
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        arch_type="audio",
        source="arXiv:2306.05284",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        mlp_kind="gelu",
        frontend="audio",
        frontend_frac=0.25,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        arch_type="audio",
        source="arXiv:2306.05284",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=128,
        mlp_kind="gelu",
        frontend="audio",
        frontend_frac=0.25,
    )


register_arch(config, smoke)
