"""qwen2.5-32b [dense] — GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B family card].

64L, d_model=5120, 40 heads, GQA kv=8, d_ff=27648, vocab=152064.
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-32b",
        arch_type="dense",
        source="hf:Qwen/Qwen2.5-0.5B",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mlp_kind="swiglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-32b",
        arch_type="dense",
        source="hf:Qwen/Qwen2.5-0.5B",
        num_layers=2,
        d_model=160,
        num_heads=5,
        num_kv_heads=1,
        d_ff=320,
        vocab_size=512,
        qkv_bias=True,
        mlp_kind="swiglu",
    )


register_arch(config, smoke)
