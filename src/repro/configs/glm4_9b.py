"""glm4-9b [dense] — RoPE + aggressive GQA [hf:THUDM/glm-4-9b].

40L, d_model=4096, 32 heads, GQA kv=2, d_ff=13696, vocab=151552.
GLM uses partial rotary (half the head dim) and QKV bias.
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b",
        arch_type="dense",
        source="hf:THUDM/glm-4-9b",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        qkv_bias=True,
        rope_theta=10_000.0,
        mlp_kind="swiglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b",
        arch_type="dense",
        source="hf:THUDM/glm-4-9b",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        mlp_kind="swiglu",
    )


register_arch(config, smoke)
