"""Architecture + input-shape config system.

One ``ArchConfig`` fully determines a model in ``repro.models.transformer``;
one ``ShapeConfig`` is an assigned input shape. Every assigned architecture
registers itself (``register_arch``) with the exact public-literature
hyper-parameters plus a reduced ``smoke`` variant (≤2 layers, d_model ≤ 512,
≤4 experts) used by CPU smoke tests.

Layer heterogeneity (gemma2 local/global alternation, zamba2 shared-attention
interleave, deepseek dense-first-k) is encoded by ``layer_kinds()`` /
``layer_windows()`` — per-layer-slot arrays that ride through the pipeline's
stacked-parameter scan as "extras" (DESIGN.md §5/§6).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

# ----------------------------------------------------------------- shapes --


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


# ------------------------------------------------------------------ archs --

LayerKind = str  # "attn" | "mamba" | "pad"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation bracket from the assignment
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention
    attn_kind: str = "gqa"  # "gqa" | "mla" | "none"
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_kind: str = "rope"  # "rope" | "mrope" | "none"
    window_size: int = 0  # 0 = all layers global
    window_pattern: str = "none"  # "none" | "alternate" (gemma2: even layers local)
    attn_softcap: float = 0.0  # gemma2: 50.0
    logit_softcap: float = 0.0  # gemma2: 30.0
    sandwich_norms: bool = False  # gemma2 pre+post norms

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel
    router_kind: str = "softmax"  # "softmax" | "sigmoid" (deepseek v3)
    mtp: bool = False  # deepseek multi-token-prediction aux head

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0  # zamba2: shared attn block every k slots

    # misc
    mlp_kind: str = "swiglu"  # "swiglu" | "geglu" | "gelu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # modality frontend stub: extra precomputed-embedding inputs
    frontend: str = "none"  # "none" | "vision" | "audio"
    frontend_frac: float = 0.25  # fraction of seq filled by frontend embeds

    # sub-quadratic long-context variant (beyond-paper; auto-selected for
    # long_500k on archs without native sub-quadratic layers)
    long_context_window: int = 8_192

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---------------------------------------------------------- patterns --

    def layer_kinds(self) -> list[LayerKind]:
        """Per-layer block kind, before pipeline padding."""
        kinds: list[LayerKind] = []
        for i in range(self.num_layers):
            if self.arch_type == "ssm":
                kinds.append("mamba")
            elif self.arch_type == "hybrid":
                every = max(self.hybrid_attn_every, 1)
                kinds.append("attn" if (i % every) == (every - 1) else "mamba")
            else:
                kinds.append("attn")
        return kinds

    def layer_windows(self, *, long_context: bool = False) -> list[int]:
        """Per-layer sliding-window size; 0 = full/global attention."""
        wins: list[int] = []
        for i in range(self.num_layers):
            if self.window_pattern == "alternate":
                w = self.window_size if i % 2 == 0 else 0
            else:
                w = self.window_size
            if long_context and w == 0:
                # beyond-paper sliding-window fallback so long_500k lowers
                w = self.long_context_window
            wins.append(w)
        return wins

    def is_subquadratic(self) -> bool:
        """True if *every* layer is O(seq)-bounded natively (no fallback)."""
        if self.arch_type in ("ssm",):
            return True
        if self.arch_type == "hybrid":
            # mamba layers are O(1)/token; attention layers still need a
            # window for 500k unless we accept O(seq) per token (decode-only
            # cost is linear; we still window them — see DESIGN.md)
            return True
        return False

    # ------------------------------------------------------------- sizes --

    @property
    def moe_layers(self) -> int:
        return self.num_layers if self.num_experts else 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size  # head
        for kind in self.layer_kinds():
            n += 2 * d  # norms (approx; sandwich adds 2 more)
            if self.sandwich_norms:
                n += 2 * d
            if kind == "attn":
                n += self._attn_params()
                n += self._ffn_params()
            elif kind == "mamba":
                n += self._mamba_params()
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_kind == "mla":
            qk = self.qk_nope_head_dim + self.qk_rope_head_dim
            n = 0
            if self.q_lora_rank:
                n += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * qk
            else:
                n += d * self.num_heads * qk
            n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            n += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
            n += self.num_heads * self.v_head_dim * d
            return n
        hd = self.head_dim
        n = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        if self.qkv_bias:
            n += (self.num_heads + 2 * self.num_kv_heads) * hd
        return n

    def _ffn_params(self) -> int:
        d = self.d_model
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        if self.num_experts:
            expert = gates * d * self.d_ff
            n = self.num_experts * expert + self.num_shared_experts * expert
            n += d * self.num_experts  # router
            if self.moe_dense_residual:
                n += gates * d * self.d_ff
            return n
        return gates * d * self.d_ff

    def _mamba_params(self) -> int:
        d = self.d_model
        d_in = self.ssm_expand * d
        h = d_in // self.ssm_head_dim
        n_state = self.ssm_state
        n = 0
        n += d * (2 * d_in + 2 * n_state + h)  # in_proj (x, z, B, C, dt)
        n += self.ssm_conv_width * (d_in + 2 * n_state)  # depthwise conv
        n += h * 3  # A_log, dt_bias, D
        n += d_in  # gate norm
        n += d_in * d  # out_proj
        return n

    def active_param_count(self) -> int:
        """Active params per token (= param_count for dense)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        expert = gates * d * self.d_ff
        inactive_per_layer = (self.num_experts - self.experts_per_token) * expert
        return self.param_count() - self.moe_layers * inactive_per_layer


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}
_SMOKE: dict[str, Callable[[], ArchConfig]] = {}


def register_arch(full: Callable[[], ArchConfig], smoke: Callable[[], ArchConfig]):
    cfg = full()
    _REGISTRY[cfg.name] = full
    _SMOKE[cfg.name] = smoke
    return full


def get_arch(name: str, *, smoke: bool = False) -> ArchConfig:
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def pipeline_padding(num_layers: int, num_stages: int) -> tuple[int, int]:
    """(layers_per_stage, pad_slots) for a stage count."""
    per = math.ceil(num_layers / num_stages)
    return per, per * num_stages - num_layers
