"""arctic-480b [moe] — dense-MoE hybrid: 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56 heads, GQA kv=8, expert d_ff=4864, vocab=32000.
Every layer runs a dense FFN residual IN PARALLEL with the top-2 MoE.
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b",
        arch_type="moe",
        source="hf:Snowflake/snowflake-arctic-base",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        num_experts=128,
        experts_per_token=2,
        moe_dense_residual=True,
        router_kind="softmax",
        mlp_kind="swiglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b",
        arch_type="moe",
        source="hf:Snowflake/snowflake-arctic-base",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        moe_dense_residual=True,
        router_kind="softmax",
        mlp_kind="swiglu",
    )


register_arch(config, smoke)
