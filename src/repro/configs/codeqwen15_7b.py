"""codeqwen1.5-7b [dense] — qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B].

32L, d_model=4096, 32 heads (GQA kv=32 — effectively MHA), d_ff=13440,
vocab=92416, QKV bias (qwen1.5 family), rope.
"""

from repro.configs.base import ArchConfig, register_arch


def config() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b",
        arch_type="dense",
        source="hf:Qwen/CodeQwen1.5-7B",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mlp_kind="swiglu",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b",
        arch_type="dense",
        source="hf:Qwen/CodeQwen1.5-7B",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        mlp_kind="swiglu",
    )


register_arch(config, smoke)
