from repro.configs.base import (
    ArchConfig,
    ShapeConfig,
    SHAPES,
    get_arch,
    get_shape,
    list_archs,
    register_arch,
)

# importing the package registers every assigned architecture
from repro.configs import (  # noqa: F401
    codeqwen15_7b,
    qwen25_32b,
    qwen2_vl_2b,
    gemma2_27b,
    glm4_9b,
    zamba2_7b,
    deepseek_v3_671b,
    arctic_480b,
    musicgen_large,
    mamba2_130m,
)

__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "get_arch",
    "get_shape",
    "list_archs",
    "register_arch",
]
