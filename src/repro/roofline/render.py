"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run reports.

    PYTHONPATH=src python -m repro.roofline.render [--dir reports/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(reports_dir: str):
    rows = []
    for fn in sorted(glob.glob(os.path.join(reports_dir, "*.json"))):
        rows.append(json.load(open(fn)))
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(rows, mesh):
    out = [
        "| arch | shape | µbatch | peak GiB/dev | HLO GFLOPs/dev | collective GiB/dev (top op) |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        coll = r["collective_bytes"]
        top = max((k for k in coll if k != "total"), key=lambda k: coll[k], default="-")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['num_micro']} | "
            f"{r['memory']['peak_estimate_gib']} | "
            f"{r['walk']['flops']/1e9:.0f} | "
            f"{fmt_bytes(coll['total'])} ({top}) |"
        )
    return "\n".join(out)


def roofline_table(rows, mesh="16x16"):
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful-FLOPs ratio |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['dominant'].replace('_s','')} | {rf['useful_flops_ratio']:.3f} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    print("## §Dry-run — single pod 16×16 (256 chips)\n")
    print(dryrun_table(rows, "16x16"))
    print("\n## §Dry-run — multi-pod 2×16×16 (512 chips)\n")
    print(dryrun_table(rows, "2x16x16"))
    print("\n## §Roofline — single pod\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
