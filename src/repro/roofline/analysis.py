"""Three-term roofline from compiled dry-run artifacts (no real hardware).

Terms (seconds, per step, per chip — ``cost_analysis()``/HLO are per-device
under SPMD, verified empirically):

    compute    = device_FLOPs / peak_FLOPs
    memory     = device_HLO_bytes / HBM_bw
    collective = device_collective_bytes / (links × link_bw)

``collective_bytes`` is not in cost_analysis; we parse the optimized HLO and
sum the *output* shapes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (a standard proxy for bytes-on-wire; an
all-reduce moves ~2× its size ring-wise — we report the raw sum plus a
per-op-type breakdown so the dominant collective is visible).
"""

from __future__ import annotations

import dataclasses
import re

# TPU v5e, per assignment
@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    ici_links: int = 4  # usable links/chip on a 2D torus (2 axes × 2 dirs)


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# matches e.g.: "  %x = bf16[8,128]{1,0} all-gather(...)" and tuple results
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},: ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-type output bytes of every collective in the HLO (per device).
    '-start' ops counted, '-done' skipped (same tensor)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue
        out[op] += _shape_bytes(type_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def model_flops(cfg, shape, *, training: bool) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for a
    forward/serve step (D = tokens processed in the step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens


def roofline_report(
    *,
    device_flops: float,
    device_bytes: float,
    device_collective: dict[str, int],
    chips: int,
    model_flops_global: float,
    hw: HW = HW(),
) -> dict:
    compute_s = device_flops / hw.peak_flops
    memory_s = device_bytes / hw.hbm_bw
    coll_s = device_collective["total"] / (hw.ici_links * hw.ici_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_global / max(device_flops * chips, 1.0)
    return {
        **terms,
        "dominant": dominant,
        "bound_s": max(terms.values()),
        "model_flops_global": model_flops_global,
        "hlo_flops_global": device_flops * chips,
        "useful_flops_ratio": useful,
        "collective_breakdown": {
            k: v for k, v in device_collective.items() if k != "total" and v
        },
    }
