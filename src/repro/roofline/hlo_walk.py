"""Loop-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts a rolled pipeline/layer scan by its trip count (verified against
this framework's pipelines — a 23-tick × 2-layer scan is undercounted ~30×).
This walker parses the post-optimization HLO, recurses through the call graph
with ``known_trip_count`` multipliers, and produces:

  * flops            — 2 · |result| · contracted-dim product, summed over
                       every ``dot`` (convolutions are not emitted by this
                       framework's models); descends into fusions and loops.
  * bytes_accessed   — Σ (operand + result bytes) per op, cost_analysis
                       style; does NOT descend into fusions (a fusion is one
                       kernel — its internals stay on-chip) but DOES multiply
                       through loops.
  * collectives      — per-type output bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       multiplied through loops.

Everything is per-device (the HLO is the SPMD program).
"""

from __future__ import annotations

import dataclasses
import json
import math
import re

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RE = re.compile(
    r"(?:body=|calls=|to_apply=|true_computation=|false_computation=)%?([\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    symtab: dict[str, str]  # result name -> type str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and ("->" in line):
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        cur.ops.append(Op(name, type_str, opcode, line))
        cur.symtab[name] = type_str
    return comps


def _dot_flops(op: Op, symtab: dict[str, str]) -> float:
    """2 · |result| · Π contracted dims (from the lhs operand's shape)."""
    result_elems = 1
    dims = _shape_dims(op.type_str)
    if not dims:
        return 0.0
    for d in dims[0][1]:
        result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m:
        return 2.0 * result_elems  # dot with no info; minimal estimate
    contracting = [int(x) for x in m.group(1).split(",") if x]
    # first operand name = lhs
    after = op.line.split(f" {op.opcode}(", 1)[1]
    ops_m = _OPERAND_RE.findall(after.split(")")[0])
    contracted = 1
    if ops_m:
        lhs_type = symtab.get(ops_m[0])
        if lhs_type:
            lhs_dims = _shape_dims(lhs_type)
            if lhs_dims:
                for c in contracting:
                    if c < len(lhs_dims[0][1]):
                        contracted *= lhs_dims[0][1][c]
    return 2.0 * result_elems * contracted


_SKIP_BYTES = {"parameter", "tuple", "get-tuple-element", "constant", "bitcast", "iota"}


class Walker:
    def __init__(self, comps: dict[str, Computation]):
        self.comps = comps
        self._memo: dict[tuple[str, str], dict] = {}

    def analyze(self, comp_name: str, *, in_fusion: bool = False) -> dict:
        key = (comp_name, "f" if in_fusion else "t")
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        zero = {"flops": 0.0, "bytes": 0.0, "coll": {k: 0.0 for k in _COLLECTIVES}}
        if comp is None:
            return zero
        total = {"flops": 0.0, "bytes": 0.0, "coll": {k: 0.0 for k in _COLLECTIVES}}
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                total["flops"] += _dot_flops(op, comp.symtab)
            base = oc.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                total["coll"][base] += _shape_bytes(op.type_str)
            if not in_fusion and oc not in _SKIP_BYTES:
                b = _shape_bytes(op.type_str)
                after = op.line.split("(", 1)
                if len(after) == 2:
                    for operand in _OPERAND_RE.findall(after[1].split(")")[0]):
                        t = comp.symtab.get(operand)
                        if t:
                            b += _shape_bytes(t)
                total["bytes"] += b
            # recurse
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                body = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if body:
                    sub = self.analyze(body, in_fusion=in_fusion)
                    total["flops"] += trip * sub["flops"]
                    total["bytes"] += trip * sub["bytes"]
                    for k in _COLLECTIVES:
                        total["coll"][k] += trip * sub["coll"][k]
            elif oc == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.line)
                if cm:
                    sub = self.analyze(cm.group(1), in_fusion=True)
                    total["flops"] += sub["flops"]
                    for k in _COLLECTIVES:
                        total["coll"][k] += sub["coll"][k]
            elif oc in ("call", "async-start", "custom-call"):
                cm = re.search(r"(?:to_apply|called_computation)=%?([\w.\-]+)", op.line)
                if cm:
                    sub = self.analyze(cm.group(1), in_fusion=in_fusion)
                    total["flops"] += sub["flops"]
                    total["bytes"] += sub["bytes"]
                    for k in _COLLECTIVES:
                        total["coll"][k] += sub["coll"][k]
            elif oc == "conditional":
                branches = []
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                else:
                    branches = [
                        g for g in re.findall(
                            r"(?:true_computation|false_computation)=%?([\w.\-]+)", op.line
                        )
                    ]
                if branches:
                    subs = [self.analyze(b, in_fusion=in_fusion) for b in branches]
                    # runtime executes one branch; take the max-cost branch
                    best = max(subs, key=lambda s: s["flops"] + s["bytes"])
                    total["flops"] += best["flops"]
                    total["bytes"] += best["bytes"]
                    for k in _COLLECTIVES:
                        total["coll"][k] += best["coll"][k]
        self._memo[key] = total
        return total


def analyze_hlo(text: str) -> dict:
    """Per-device loop-weighted costs for the ENTRY computation."""
    comps = parse_module(text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        entry = m.group(1)
    else:  # fall back: computation named main-ish
        for name in comps:
            if "main" in name:
                entry = name
                break
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO")
    w = Walker(comps)
    out = w.analyze(entry)
    coll = {k: int(v) for k, v in out["coll"].items()}
    coll["total"] = sum(coll.values())
    return {"flops": out["flops"], "bytes": out["bytes"], "collectives": coll}
