from repro.roofline.analysis import (
    HW,
    collective_bytes,
    roofline_report,
    model_flops,
)
from repro.roofline.stage_report import (
    layout_slots,
    live_slots,
    sparse_stage_report,
    stage_report,
)

__all__ = [
    "HW",
    "collective_bytes",
    "roofline_report",
    "model_flops",
    "stage_report",
    "sparse_stage_report",
    "layout_slots",
    "live_slots",
]
