"""Per-stage roofline accounting for the GNN pipeline's aggregation layouts.

For each pipeline stage this lowers the REAL stage-slice program
(``make_gnn_stage_slices`` — the exact function the scheduled executor
dispatches per tick) at the stacked plan's shape, walks the optimized HLO
(``roofline.hlo_walk.analyze_hlo``), and sets the measured FLOPs/bytes next
to an analytic *roof*: the floor cost of the stage's layers if aggregation
touched only the graph's LIVE edge slots. The padded layout's distance to
that roof is pure padding traffic — ``n_pad · max_deg`` slots for a
power-law degree distribution whose live count is a fraction of that — and
the degree-bucketed layout's distance shows how much of it bucketing wins
back (its slot count is ``Σ rows_b · width_b``).

Everything is per (stage, chunk): the stage program processes one chunk per
dispatch, so live-slot counts are averaged over chunks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.data import BucketedGraphBatch
from repro.models.gnn.net import (
    GNNModel,
    activation_widths,
    make_gnn_stage_slices,
    travel_width,
)
from repro.roofline.hlo_walk import analyze_hlo

_F32 = 4  # bytes; the framework's layers run f32


def layout_slots(graph) -> int:
    """Neighbor slots the aggregation layout materializes per chunk:
    ``n_pad · max_deg`` for the padded layout, ``Σ rows_b · width_b`` for a
    degree-bucketed wrapper."""
    if isinstance(graph, BucketedGraphBatch):
        return int(sum(b.rows * b.width for b in graph.buckets))
    return int(graph.neighbors.shape[-2] * graph.neighbors.shape[-1])


def live_slots(graph) -> float:
    """Mean live (mask-True) neighbor slots per chunk — the roof's edge
    count: no layout can aggregate fewer slots and stay exact."""
    msk = np.asarray(graph.mask)
    chunks = msk.shape[0] if msk.ndim == 3 else 1
    return float(msk.sum()) / chunks


def _layer_roof(params: dict, n: int, live: float) -> tuple[float, float]:
    """(flops, bytes) floor for one layer at ``live`` aggregated slots.

    Recognizes the framework's layer param shapes: a 2-D ``w`` is a
    GCN/GraphConv-style transform + weighted-sum aggregate; a 3-D ``w`` is
    the multi-head GAT (transform, per-edge score, masked softmax,
    aggregate). Param-less layers (dropout/elu/log_softmax) are elementwise
    and contribute no flops floor.
    """
    w = params.get("w") if isinstance(params, dict) else None
    if w is None:
        return 0.0, 0.0
    if w.ndim == 2:
        d_in, d_out = w.shape
        flops = 2.0 * n * d_in * d_out + 2.0 * live * d_out
        byts = _F32 * (n * d_in + d_in * d_out + live * d_out + n * d_out)
        return flops, byts
    heads, d_in, d_out = w.shape
    flops = (
        2.0 * n * d_in * heads * d_out  # feature transform
        + 4.0 * n * heads * d_out  # a_src/a_dst score projections
        + 6.0 * live * heads  # leaky-relu + masked softmax per edge
        + 2.0 * live * heads * d_out  # attention-weighted aggregate
    )
    byts = _F32 * (
        n * d_in + heads * d_in * d_out + live * heads * (d_out + 1) + n * heads * d_out
    )
    return flops, byts


def stage_report(
    model: GNNModel,
    params: list,
    graph,
    balance: tuple[int, ...],
    *,
    train: bool = False,
) -> list[dict]:
    """Measured-vs-roof rows, one per pipeline stage.

    ``graph`` is a chunk-stacked batch (padded ``GraphBatch`` or its
    ``BucketedGraphBatch`` wrapper, leaves ``(chunks, n_pad, ...)``). Each
    stage's slice program is jit-lowered at that shape and its optimized
    HLO walked for per-dispatch FLOPs/bytes; the roof comes from
    ``_layer_roof`` at the graph's live slot count.
    """
    bounds = []
    lo = 0
    for b in balance:
        bounds.append((lo, lo + b))
        lo += b
    chunk0 = jax.tree_util.tree_map(lambda a: a[0], graph)
    widths = activation_widths(model, params, chunk0)
    slices = make_gnn_stage_slices(
        model, bounds, widths, graph, jax.random.PRNGKey(0), train=train
    )
    n_pad = graph.features.shape[1]
    d_travel = travel_width(bounds, widths)
    h_like = jax.ShapeDtypeStruct((n_pad, d_travel), jnp.float32)
    chunk_like = jax.ShapeDtypeStruct((), jnp.int32)
    live = live_slots(graph)

    rows = []
    for s, fn in enumerate(slices):
        text = jax.jit(fn).lower(params, chunk_like, h_like).compile().as_text()
        measured = analyze_hlo(text)
        roof_flops = roof_bytes = 0.0
        for i in range(*bounds[s]):
            f, b = _layer_roof(params[i], n_pad, live)
            roof_flops += f
            roof_bytes += b
        rows.append(
            {
                "stage": s,
                "layers": [model.layers[i].name for i in range(*bounds[s])],
                "measured_flops": float(measured["flops"]),
                "measured_bytes": float(measured["bytes"]),
                "roof_flops": roof_flops,
                "roof_bytes": roof_bytes,
            }
        )
    return rows


def sparse_stage_report(
    model: GNNModel,
    params: list,
    padded_graph,
    bucketed_graph,
    balance: tuple[int, ...],
) -> dict:
    """The fig-row payload: per-stage measured-vs-roof for the padded layout
    next to the degree-bucketed one, plus the slot accounting that explains
    the gap (live edge slots vs each layout's materialized slots)."""
    padded = stage_report(model, params, padded_graph, balance)
    bucketed = stage_report(model, params, bucketed_graph, balance)
    slots = {
        "live": live_slots(padded_graph),
        "padded": layout_slots(padded_graph),
        "bucketed": layout_slots(bucketed_graph),
    }
    stages = [
        {
            "stage": p["stage"],
            "layers": p["layers"],
            "roof_flops": p["roof_flops"],
            "roof_bytes": p["roof_bytes"],
            "padded": {k: p[k] for k in ("measured_flops", "measured_bytes")},
            "bucketed": {k: b[k] for k in ("measured_flops", "measured_bytes")},
        }
        for p, b in zip(padded, bucketed)
    ]
    return {"slots": slots, "stages": stages}
