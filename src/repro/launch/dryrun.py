import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
)

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, record memory/cost/collective analysis for §Dry-run and §Roofline.

The two lines above MUST precede any other import (jax locks the device
count on first init). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch codeqwen1.5-7b \
        --shape train_4k [--multi-pod] [--out reports/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Exit code is non-zero if any requested combination fails — failures here are
sharding bugs by definition (see MULTI-POD DRY-RUN in the brief).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch, get_shape, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models.transformer.model import (
    Topology,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.roofline.analysis import model_flops, roofline_report
from repro.roofline.hlo_walk import analyze_hlo


def topology_for(cfg, shape, *, multi_pod: bool, moe_mode: str = "gathered",
                 num_micro: int | None = None, remat: bool = True,
                 zero3: bool = True) -> Topology:
    data = 16
    pods = 2 if multi_pod else 1
    b_local = max(shape.global_batch // (data * pods), 1)
    if num_micro is None:
        # more microbatches = smaller bubble AND smaller per-tick residuals;
        # capped by the local batch (see EXPERIMENTS.md §Perf)
        target = {"train": 16, "prefill": 4, "decode": 4}[shape.kind]
        num_micro = max(min(target, b_local), 1)
    seq_shard = shape.kind == "decode" and shape.global_batch == 1 and cfg.arch_type != "ssm"
    return Topology(
        num_stages=16,
        stage_axis="model",
        fsdp_axis="data",
        pod_axis="pod" if multi_pod else None,
        fsdp_size=data,
        num_micro=num_micro,
        moe_mode=moe_mode,
        zero3=zero3,
        remat=remat,
        seq_shard_decode=seq_shard,
        loss_chunks=8,
    )


def build_step(cfg, shape, topo, mesh):
    if shape.kind == "train":
        return make_train_step(cfg, topo, shape, mesh)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, topo, shape, mesh)
    return make_serve_step(cfg, topo, shape, mesh)


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str | None,
            moe_mode: str = "gathered", zero3: bool = True,
            num_micro: int | None = None, remat: bool = True,
            verbose: bool = True, tag: str = "") -> dict:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    topo = topology_for(cfg, shape, multi_pod=multi_pod, moe_mode=moe_mode,
                        zero3=zero3, num_micro=num_micro, remat=remat)
    art = build_step(cfg, shape, topo, mesh)

    t0 = time.time()
    jitted = jax.jit(art.fn, in_shardings=art.in_shardings, out_shardings=art.out_shardings)
    lowered = jitted.lower(*art.abstract_inputs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # loop-aware costs: XLA's cost_analysis counts while bodies once; the
    # walker multiplies through known_trip_counts (see roofline/hlo_walk.py)
    walk = analyze_hlo(compiled.as_text())
    mf = model_flops(cfg, shape, training=shape.kind == "train")
    report = roofline_report(
        device_flops=walk["flops"],
        device_bytes=walk["bytes"],
        device_collective=walk["collectives"],
        chips=chips,
        model_flops_global=mf,
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "num_micro": topo.num_micro,
        "seq_shard_decode": topo.seq_shard_decode,
        "moe_mode": moe_mode,
        "zero3": zero3,
        "tag": tag,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 - mem.alias_size_in_bytes + mem.temp_size_in_bytes) / 2**30, 3
            ),
        },
        "cost": {k: cost[k] for k in ("flops", "bytes accessed") if k in cost},
        "walk": {"flops": walk["flops"], "bytes": walk["bytes"]},
        "collective_bytes": walk["collectives"],
        "roofline": report,
        "ok": True,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"peak/dev {result['memory']['peak_estimate_gib']} GiB "
              f"dominant {report['dominant']} ({report['bound_s']:.4f}s)")
        print("  memory_analysis:", mem)
        cost_str = {k: f"{v:.3e}" for k, v in result["cost"].items()}
        print("  cost_analysis:", cost_str, " walk:", {k: f"{v:.3e}" for k, v in result["walk"].items()})
        print("  collectives:", {k: v for k, v in walk["collectives"].items() if v})
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{result['mesh']}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-mode", default="gathered", choices=["gathered", "a2a"])
    ap.add_argument("--no-zero3", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--num-micro", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    combos: list[tuple[str, str]]
    if args.all:
        combos = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            run_one(arch, shape, multi_pod=args.multi_pod, out_dir=args.out,
                    moe_mode=args.moe_mode, zero3=not args.no_zero3,
                    num_micro=args.num_micro, remat=not args.no_remat,
                    tag=args.tag)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((arch, shape, repr(e)))
            print(f"[dryrun] FAIL {arch} × {shape}: {e}")
            traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures: {[(a, s) for a, s, _ in failures]}")
        raise SystemExit(1)
    print(f"[dryrun] all {len(combos)} combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
