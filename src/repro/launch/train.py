"""End-to-end training driver.

Two modes:

  * ``gnn`` — the paper's experiment: GAT node classification on the
    citation datasets, single-device or pipelined with a chunking strategy
    (paper-faithful ``sequential`` or beyond-paper ``halo``) on either
    engine — ``--engine host`` (torchgpipe-style queue loop) or ``--engine
    compiled`` (one jitted SPMD program). Both engines take any
    ``--schedule`` (fill_drain / 1f1b / interleaved / zb-h1); the compiled
    engine lowers 1F1B/interleaved/zero-bubble timelines into the jitted
    program (``spmd_pipeline_scheduled``), so the memory/bubble wins run on
    the fast path too, and ``--engine compiled`` validation runs through
    the engine's forward-only jitted eval pipeline instead of a host
    full-batch fallback:

        PYTHONPATH=src python -m repro.launch.train --mode gnn \
            --dataset pubmed --epochs 300 --stages 4 --chunks 4 \
            --strategy sequential --schedule 1f1b
        PYTHONPATH=src python -m repro.launch.train --mode gnn \
            --dataset cora --stages 4 --chunks 4 --engine compiled \
            --schedule 1f1b
        PYTHONPATH=src python -m repro.launch.train --mode gnn \
            --dataset cora --stages 4 --chunks 4 --engine compiled \
            --schedule zb-h1
        PYTHONPATH=src python -m repro.launch.train --mode gnn \
            --dataset cora --stages 4 --chunks 4 --engine compiled \
            --schedule interleaved --pipe-devices 2

  * ``lm`` — pipelined LM pretraining on the synthetic token stream (any
    assigned arch; smoke-sized by default so it runs on CPU). ``--schedule
    interleaved`` routes through the circular ``spmd_pipeline_interleaved``
    (``--pipe-devices`` physical stages, V = stages/devices virtual each):

        PYTHONPATH=src python -m repro.launch.train --mode lm \
            --arch mamba2-130m --steps 200 --seq 256 --batch 8
        PYTHONPATH=src python -m repro.launch.train --mode lm \
            --arch mamba2-130m --stages 2 --schedule interleaved --steps 50
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_gnn(args) -> dict:
    from repro.core.cli import PipelineCLIConfig
    from repro.core.microbatch import make_plan
    from repro.core.pipeline import make_engine
    from repro.graphs import (
        STREAMED_DATASETS,
        load_dataset,
        open_streamed,
        streamed_plan,
    )
    from repro.models.gnn.net import build_paper_gat
    from repro.train import optimizer as opt_lib
    from repro.train.loop import make_eval, train

    streamed = args.dataset in STREAMED_DATASETS
    if streamed:
        # streamed graphs never materialize whole — the pipeline path is the
        # only consumer (chunks generated block-by-block on the host), and
        # evaluation has to run over the plan, not a full-graph batch
        if args.stages <= 1:
            raise ValueError(
                f"streamed dataset {args.dataset!r} requires the pipeline path (--stages > 1)"
            )
        stream_plan = streamed_plan(
            open_streamed(args.dataset, seed=args.seed, num_nodes=args.num_nodes),
            args.chunks,
            max_degree=args.max_degree,
        )
        g = stream_plan.batches[0].graph
    else:
        g = load_dataset(args.dataset, seed=args.seed)
    gat_kwargs = {}
    if args.backend == "pallas":
        # the fused pallas GAT kernel is deterministic; training it with the
        # paper's attn-dropout would raise in gat_layer — opt out explicitly
        # and say so, instead of silently zeroing the rate
        print("[gnn] pallas backend: attention dropout disabled (fused kernel is deterministic)")
        gat_kwargs["attn_dropout"] = 0.0
    model = build_paper_gat(g.num_features, g.num_classes, backend=args.backend, **gat_kwargs)

    if args.stages <= 1:
        res = train(model, g, epochs=args.epochs, seed=args.seed, log_every=args.log_every)
        out = {
            "mode": "single",
            "val_acc": res.val_acc,
            "test_acc": res.test_acc,
            "train_loss": res.train_loss,
            "avg_epoch_s": res.avg_epoch_s,
            "first_epoch_s": res.first_epoch_s,
        }
        print(out)
        return out

    # pipeline path (paper §6) — flag bundle lifted off the shared CLI surface
    cli = PipelineCLIConfig.from_args(args)
    schedule, engine, partition = cli.schedule, cli.engine, cli.partition
    pipe_devices = cli.resolved_pipe_devices
    chunks = args.chunks

    if cli.auto:
        # self-tuning planner: profile -> enumerate -> predict -> pick.
        # Overrides --schedule/--chunks/--partition/--placement with the
        # argmin-predicted configuration; --dry-run stops after the table.
        if streamed:
            raise ValueError(
                "--auto profiles representative chunks of the full graph; "
                "streamed datasets have no full-graph batch to plan over"
            )
        from repro.core.autotune import plan_for_cli

        auto_plan = plan_for_cli(
            model, g, cli,
            strategy=args.strategy,
            seed=args.seed,
            cache_path=getattr(args, "cost_cache", None),
            costs_by_chunks=getattr(args, "costs_by_chunks", None),
        )
        print(auto_plan.format_table(limit=10))
        if cli.dry_run:
            out = {
                "mode": "auto-dry-run",
                "schedule": auto_plan.schedule,
                "chunks": auto_plan.chunks,
                "balance": list(auto_plan.balance),
                "predicted_step_s": auto_plan.predicted_step_s,
                "evaluated": auto_plan.evaluated,
            }
            print(out)
            return out
        schedule, partition = auto_plan.schedule, "auto"
        chunks, balance = auto_plan.chunks, auto_plan.balance
        plan = make_plan(g, chunks, strategy=args.strategy, halo_hops=2, seed=args.seed)
        pipe = make_engine(model, auto_plan)
        print(f"[gnn] engine={engine} stages={len(balance)} chunks={chunks} "
              f"strategy={plan.strategy} schedule={schedule} balance={balance} "
              f"edge_cut={plan.edge_cut:.3f} rebuild_s={plan.rebuild_seconds:.3f} "
              f"bubble={pipe.describe()['bubble_fraction']:.2f} "
              f"predicted_step={auto_plan.predicted_step_s * 1e3:.2f}ms")
        return _train_pipeline(
            args, g, model, plan, pipe,
            engine=engine, schedule=schedule, partition=partition,
            balance=balance, chunks=chunks, streamed=streamed,
            predicted_step_s=auto_plan.predicted_step_s,
        )

    if streamed:
        plan = stream_plan
    else:
        plan = make_plan(g, chunks, strategy=args.strategy, halo_hops=2, seed=args.seed)

    if partition == "profiled":
        # cost-model-driven balance: measure per-layer fwd/B/W cost on one
        # padded chunk of THIS plan (the shape the engines dispatch per
        # tick), then pick the contiguous grouping minimizing the chosen
        # schedule's weighted makespan. A caller sweeping many configs over
        # the same model/plan shape (fig3's matrix) passes the measured
        # ``layer_costs`` in to skip re-profiling per cell.
        from repro.core.costmodel import cached_profile_layer_costs, choose_balance
        from repro.core.schedule import get_schedule

        costs = getattr(args, "layer_costs", None)
        if costs is None:
            chunk0 = jax.tree_util.tree_map(lambda a: a[0], plan.stacked().graph)
            costs = cached_profile_layer_costs(
                model, model.init_params(jax.random.PRNGKey(args.seed)), chunk0,
                backend=args.backend,
                cache_path=getattr(args, "cost_cache", None),
            )
        balance, predicted = choose_balance(
            costs,
            args.stages,
            get_schedule(schedule, num_devices=pipe_devices),
            args.chunks,
        )
        print("[gnn] per-layer profile (ms/chunk):")
        for row in costs.table():
            print(f"  {row['layer']:2d} {row['name']:<14s} "
                  f"fwd {row['fwd_s'] * 1e3:7.3f}  B {row['bwd_b_s'] * 1e3:7.3f}  "
                  f"W {row['bwd_w_s'] * 1e3:7.3f}")
        print(f"[gnn] profiled balance={balance} predicted_step={predicted * 1e3:.2f}ms")
    else:
        balance = cli.uniform_balance()

    pipe = make_engine(model, cli.gpipe_config(balance))
    print(f"[gnn] engine={engine} stages={args.stages} chunks={chunks} "
          f"strategy={plan.strategy} schedule={schedule} balance={balance} "
          f"edge_cut={plan.edge_cut:.3f} rebuild_s={plan.rebuild_seconds:.3f} "
          f"bubble={pipe.describe()['bubble_fraction']:.2f}")
    return _train_pipeline(
        args, g, model, plan, pipe,
        engine=engine, schedule=schedule, partition=partition,
        balance=balance, chunks=chunks, streamed=streamed,
    )


def _train_pipeline(
    args, g, model, plan, pipe, *,
    engine, schedule, partition, balance, chunks, streamed,
    predicted_step_s=None,
):
    """The shared pipeline training loop: epochs over ``pipe.train_step``,
    engine-appropriate evaluation, and the result/metrics dict every caller
    (manual flags, profiled partition, ``--auto`` plan) prints and
    returns."""
    from repro.train import optimizer as opt_lib
    from repro.train.loop import make_eval

    key = jax.random.PRNGKey(args.seed)
    key, init_key = jax.random.split(key)
    params = pipe.init_params(init_key)
    optimizer = opt_lib.adam(5e-3, weight_decay=5e-4)
    opt_state = optimizer.init(params)
    if engine == "compiled" or streamed:
        # validation runs through the engine's forward-only jitted pipeline
        # (no host full-batch fallback): same metric dict, computed over the
        # plan's core nodes by the scheduled executor's eval twin. Streamed
        # datasets have no full-graph batch, so the host engine evaluates
        # over the plan too.
        evaluate = lambda p, _g: pipe.evaluate(p, plan)  # noqa: E731
    else:
        evaluate = make_eval(model)

    times = []
    loss = jnp.zeros(())
    sched_stats: dict = {}
    for epoch in range(args.epochs):
        key, rng = jax.random.split(key)
        t0 = time.perf_counter()
        params, opt_state, loss = pipe.train_step(
            params, opt_state, plan, rng, optimizer,
            stats=sched_stats if epoch == 0 else None,
        )
        jax.block_until_ready(loss)
        times.append(time.perf_counter() - t0)
        if args.log_every and epoch % args.log_every == 0:
            m = evaluate(params, g)
            print(f"epoch {epoch:4d} loss {float(loss):.4f} val {float(m['val_acc']):.3f}")
    m = evaluate(params, g)
    out = {
        "mode": f"gpipe-{plan.strategy}",
        "engine": engine,
        "schedule": schedule,
        "partition": partition,
        "balance": list(balance),
        "chunks": chunks,
        "edge_cut": plan.edge_cut,
        "bubble_fraction": sched_stats.get("bubble_fraction"),
        "peak_live_activations": sched_stats.get("measured_peak_live_activations"),
        "peak_live_accounted": sched_stats.get("peak_live_activations"),
        "train_loss": float(m["train_loss"]),
        "train_acc": float(m["train_acc"]),
        "val_acc": float(m["val_acc"]),
        "test_acc": float(m["test_acc"]),
        "first_epoch_s": times[0],
        "avg_epoch_s": float(np.mean(times[1:])) if len(times) > 1 else times[0],
        # the perf gate's estimator: on shared CPU runners a handful of
        # scheduler hiccups inflate the mean severalfold; the median is the
        # honest "typical step" the gate's strict/thresholded comparisons need
        "median_epoch_s": float(np.median(times[1:])) if len(times) > 1 else times[0],
        "rebuild_s": plan.rebuild_seconds,
    }
    if predicted_step_s is not None:
        out["predicted_step_s"] = predicted_step_s
    print(out)
    return out


def run_lm(args) -> dict:
    from repro.configs import get_arch, ShapeConfig
    from repro.data.tokens import token_batch, frontend_embeds
    from repro.models.transformer.model import Topology, init_params, make_train_step

    cfg = get_arch(args.arch, smoke=not args.full_arch)
    n_dev = jax.device_count()
    stages = args.stages if args.stages > 1 else 1
    schedule = getattr(args, "schedule", "fill_drain")
    schedule = "fill_drain" if schedule in ("fill_drain", "gpipe") else schedule
    if schedule not in ("fill_drain", "interleaved"):
        raise ValueError(
            f"--mode lm supports fill_drain|interleaved schedules, got {schedule!r} "
            "(1f1b/zb-h1 are GNN-engine schedules)"
        )
    if schedule == "interleaved" and stages > 1:
        # physical stage devices: --pipe-devices, else the largest divisor of
        # stages that fits the host (V = stages / devices virtual each)
        pipe_dev = getattr(args, "pipe_devices", None) or max(
            d for d in range(1, min(n_dev, stages) + 1) if stages % d == 0
        )
        if stages % pipe_dev:
            raise ValueError(f"--pipe-devices {pipe_dev} must divide --stages {stages}")
        num_virtual = stages // pipe_dev
    else:
        schedule, pipe_dev, num_virtual = "fill_drain", stages, 1
    num_micro = args.chunks
    if schedule == "interleaved" and num_micro < pipe_dev:
        num_micro = pipe_dev  # the ring needs C >= devices
        print(f"[lm] bumping --chunks to {num_micro} (interleaved needs >= --pipe-devices)")
    data = max(n_dev // pipe_dev, 1)
    b_local = max(args.batch // data, 1)
    if b_local % num_micro:
        raise ValueError(
            f"micro-batch count {num_micro} must divide the per-device batch "
            f"{b_local} (--batch {args.batch} over {data} data shards)"
        )
    mesh = jax.make_mesh((data, pipe_dev), ("data", "model"))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    topo = Topology(
        num_stages=stages, fsdp_size=data, num_micro=num_micro,
        loss_chunks=min(4, args.batch),
        schedule=schedule, num_virtual=num_virtual,
    )
    if schedule == "interleaved":
        print(f"[lm] schedule=interleaved stages={stages} devices={pipe_dev} "
              f"virtual/device={num_virtual} micro={num_micro}")
    art = make_train_step(cfg, topo, shape, mesh, lr=args.lr, dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(args.seed), num_stages=stages, dtype=jnp.float32)
    params = jax.device_put(params, art.in_shardings[0])
    opt_state = art.meta["optimizer"].init(params)
    step = jax.jit(art.fn, in_shardings=art.in_shardings, out_shardings=art.out_shardings)

    s_front = int(args.seq * cfg.frontend_frac) if cfg.frontend != "none" else 0
    losses, times = [], []
    for i in range(args.steps):
        batch = {
            "tokens": jnp.asarray(token_batch(
                batch=args.batch, seq=args.seq - s_front, vocab=cfg.vocab_size,
                seed=args.seed, step=i,
            ))
        }
        if s_front:
            batch["frontend_embeds"] = jnp.asarray(frontend_embeds(
                batch=args.batch, seq=s_front, d_model=cfg.d_model, seed=i,
            ))
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        times.append(time.perf_counter() - t0)
        losses.append(loss)
        if args.log_every and i % args.log_every == 0:
            print(f"step {i:4d} loss {loss:.4f} ({times[-1]:.2f}s)")
    assert np.isfinite(losses).all(), "training diverged"
    out = {
        "arch": cfg.name,
        "first_loss": losses[0],
        "last_loss": losses[-1],
        "improved": bool(losses[-1] < losses[0]),
        "avg_step_s": float(np.mean(times[1:])) if len(times) > 1 else times[0],
    }
    print(out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["gnn", "lm"], default="gnn")
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--full-arch", action="store_true", help="use the full (not smoke) config")
    ap.add_argument("--strategy", default="sequential")
    from repro.core.cli import add_pipeline_args

    # --engine/--schedule/--stages/--chunks/--pipe-devices/--partition/
    # --placement/--backend
    add_pipeline_args(ap)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--num-nodes", type=int, default=None,
                    help="streamed datasets only: override the registry node count")
    ap.add_argument("--max-degree", type=int, default=32,
                    help="streamed datasets only: neighbor-slot cap per node")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    if getattr(args, "overlap", "off") == "async":
        # must land in XLA_FLAGS before the first backend touch — main() is
        # the one place that runs ahead of any jax.devices() call
        from repro.core.overlap_report import apply_async_overlap_flags

        apply_async_overlap_flags()
    if args.mode == "gnn":
        run_gnn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
