"""Batched GNN serving: shape-bucketed ego-subgraph inference at production
rates, pumped through the pipelined compiled eval program.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
        python -m repro.launch.serve_gnn --dataset cora --qps 50 --duration 5 \\
        --engine compiled --stages 4 --chunks 4

A synthetic open-loop arrival process (Poisson at ``--qps``) emits
node-classification and link-prediction queries against a loaded graph. Each
query is served from its seed nodes' k-hop **ego-subgraph**
(``graphs/partition.ego_subgraph``): with ``--hops`` >= the model's
receptive depth (2 for the paper GAT) the halo is lossless, so the served
prediction is *bit-identical* to a full-graph forward pass — ``--verify``
checks exactly that against a host full-batch apply.

Shape discipline: arbitrary traffic produces arbitrary ego sizes, and every
new array shape is a new XLA compilation. The server therefore pads each
ego-subgraph into a small static ladder of node-count **buckets**
(``ShapeBuckets``; neighbor width is always the full graph's ``max_degree``),
so the jitted program count is bounded by the ladder length regardless of
traffic — the same reason the training path stacks uniform-shape chunks.
Same-bucket requests batch together, ``--chunks`` per dispatch, and run as
ONE stacked batch through the engine's ``compile_eval`` program — the
pipelined scheduled executor on ``--engine compiled``, the fused host scan
on ``--engine host`` (the interface is symmetric).

The driver reports achieved queries/s, p50/p99 latency and per-bucket batch
occupancy, writes a machine-readable row for the CI serving gate
(``benchmarks/check_perf.py --serving-current``) plus a latency histogram
artifact, and exits non-zero if ``--verify`` finds any served prediction
diverging from the full-batch oracle.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from collections import deque

import numpy as np
import jax

from repro.graphs.data import GraphBatch, pad_graph
from repro.graphs.partition import ego_subgraph


@dataclasses.dataclass(frozen=True)
class Query:
    """One serving request: classify node ``u`` ("node") or score the pair
    ``(u, v)`` ("link"). ``arrival_s`` is the open-loop schedule offset."""

    qid: int
    kind: str  # "node" | "link"
    u: int
    v: int = -1
    arrival_s: float = 0.0

    @property
    def seeds(self) -> tuple[int, ...]:
        return (self.u,) if self.kind == "node" else (self.u, self.v)


@dataclasses.dataclass
class PreparedQuery:
    """A query with its bucket-padded ego-subgraph attached."""

    query: Query
    graph: GraphBatch  # padded to (bucket size, full-graph max_degree)
    rows: tuple[int, ...]  # seed rows in the padded subgraph
    bucket: int
    ego_nodes: int  # pre-pad ego size (diagnostics)


@dataclasses.dataclass
class ServedResult:
    query: Query
    latency_s: float
    pred: int  # node: argmax class; link: 1 iff score >= 0
    score: float  # node: max logp; link: logp_u . logp_v
    logp: np.ndarray  # (num_seeds, out_dim) — the verification surface


class ShapeBuckets:
    """A static, sorted node-count ladder. ``bucket_of(n)`` is a pure
    function of the ego size, so bucket assignment is deterministic and
    independent of arrival order; the jitted-program count is bounded by
    ``len(sizes)`` no matter what traffic arrives."""

    def __init__(self, sizes):
        self.sizes = tuple(sorted(set(int(s) for s in sizes)))
        if not self.sizes:
            raise ValueError("ShapeBuckets needs at least one size")

    @classmethod
    def geometric(cls, g: GraphBatch, *, base: int = 64, factor: int = 2) -> "ShapeBuckets":
        """base, base*factor, ... capped at the full graph's node count (the
        largest possible ego-subgraph, so the ladder always has a fit)."""
        sizes, s = [], base
        while s < g.num_nodes:
            sizes.append(s)
            s *= factor
        sizes.append(g.num_nodes)
        return cls(sizes)

    def __len__(self) -> int:
        return len(self.sizes)

    def bucket_of(self, n: int) -> int:
        for i, s in enumerate(self.sizes):
            if n <= s:
                return i
        raise ValueError(f"ego of {n} nodes exceeds the largest bucket {self.sizes[-1]}")

    def size_of(self, bucket: int) -> int:
        return self.sizes[bucket]


def _stack(graphs):
    import jax.numpy as jnp

    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *graphs)


class GNNServer:
    """Bucketed batching frontend over a pipeline engine's compiled eval
    programs: ``prepare`` extracts/pads one query's ego-subgraph, ``execute``
    runs up to ``chunks`` same-bucket prepared queries as one stacked batch.
    Params are bound to each bucket's ``EvalProgram`` once at warmup —
    serving never re-replicates the param tree per call."""

    def __init__(self, engine, params, g: GraphBatch, *, hops: int = 2, buckets=None):
        self.engine = engine
        self.params = params
        self.g = g
        self.hops = hops
        self.chunks = engine.config.chunks
        self.buckets = buckets if buckets is not None else ShapeBuckets.geometric(g)
        # one neighbor width everywhere: ego max_deg never exceeds the full
        # graph's, and a fixed width keeps the bucket key one-dimensional
        self.max_deg = g.max_degree
        self.stats = {}  # bucket -> {"batches": int, "queries": int}

    def prepare(self, query: Query) -> PreparedQuery:
        sub, rows = ego_subgraph(self.g, list(query.seeds), self.hops)
        bucket = self.buckets.bucket_of(sub.num_nodes)
        padded = pad_graph(sub, self.buckets.size_of(bucket), self.max_deg)
        return PreparedQuery(query, padded, tuple(int(r) for r in rows), bucket, sub.num_nodes)

    def warm(self, bucket: int, probe: PreparedQuery) -> float:
        """Compile (and time one warm call of) the bucket's program.
        Returns the warm per-batch call time in seconds."""
        batch = _stack([probe.graph] * self.chunks)
        prog = self.engine.compile_eval(self.params, batch)
        np.asarray(prog(batch))  # compile + first run
        reps = []
        for _ in range(3):
            t0 = time.perf_counter()
            np.asarray(prog(batch))
            reps.append(time.perf_counter() - t0)
        return float(np.median(reps))

    def execute(self, prepared: list[PreparedQuery]) -> list[ServedResult]:
        """Run one same-bucket batch (1..chunks real requests; partial
        batches are padded by repeating the first request's subgraph)."""
        assert 0 < len(prepared) <= self.chunks
        bucket = prepared[0].bucket
        assert all(p.bucket == bucket for p in prepared)
        graphs = [p.graph for p in prepared]
        graphs += [prepared[0].graph] * (self.chunks - len(prepared))
        batch = _stack(graphs)
        prog = self.engine.compile_eval(self.params, batch)
        logp = np.asarray(prog(batch))  # (chunks, n_pad, out_dim); blocks
        st = self.stats.setdefault(bucket, {"batches": 0, "queries": 0})
        st["batches"] += 1
        st["queries"] += len(prepared)
        out = []
        for i, p in enumerate(prepared):
            rows = logp[i][list(p.rows)]
            if p.query.kind == "node":
                pred, score = int(rows[0].argmax()), float(rows[0].max())
            else:
                score = float(np.dot(rows[0], rows[1]))
                pred = int(score >= 0.0)
            out.append(ServedResult(p.query, 0.0, pred, score, rows))
        return out

    def occupancy(self) -> dict:
        """Per-bucket fill: real requests / (batches * chunks)."""
        return {
            self.buckets.size_of(b): {
                "batches": st["batches"],
                "queries": st["queries"],
                "occupancy": st["queries"] / (st["batches"] * self.chunks),
            }
            for b, st in sorted(self.stats.items())
        }


def synth_queries(g: GraphBatch, n: int, *, qps: float, link_frac: float, seed: int):
    """n queries over random seed nodes with exponential inter-arrivals
    (open-loop Poisson at ``qps``). Half the link queries score a real edge,
    half a random pair."""
    rng = np.random.default_rng(seed)
    nbr, msk = np.asarray(g.neighbors), np.asarray(g.mask)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n))
    queries = []
    for qid in range(n):
        u = int(rng.integers(g.num_nodes))
        if rng.random() < link_frac:
            row = nbr[u][msk[u]]
            if rng.random() < 0.5 and len(row) > 1:
                v = int(rng.choice(row[1:]))  # slot 0 is the self-loop
            else:
                v = int(rng.integers(g.num_nodes))
            if v == u:
                v = (u + 1) % g.num_nodes
            queries.append(Query(qid, "link", u, v, float(arrivals[qid])))
        else:
            queries.append(Query(qid, "node", u, -1, float(arrivals[qid])))
    return queries


def serve(server: GNNServer, queries: list[Query], *, max_wait_s: float) -> list[ServedResult]:
    """The open-loop driver: queries become eligible at their scheduled
    arrival time; same-bucket requests batch up to ``chunks``, a partial
    batch dispatches once its oldest request has waited ``max_wait_s``.
    Latency is completion minus *scheduled* arrival (queueing included), the
    honest open-loop definition — a server that can't keep up pays for it."""
    pending: dict[int, deque] = {}
    results: list[ServedResult] = []
    n_pending = 0
    i = 0
    t0 = time.perf_counter()

    def dispatch(bucket):
        nonlocal n_pending
        q = pending[bucket]
        batch = [q.popleft() for _ in range(min(len(q), server.chunks))]
        n_pending -= len(batch)
        done = server.execute(batch)
        t_done = time.perf_counter() - t0
        for r in done:
            r.latency_s = t_done - r.query.arrival_s
        results.extend(done)

    while i < len(queries) or n_pending:
        now = time.perf_counter() - t0
        while i < len(queries) and queries[i].arrival_s <= now:
            p = server.prepare(queries[i])  # prep cost is inside the clock
            pending.setdefault(p.bucket, deque()).append(p)
            n_pending += 1
            i += 1
        # full batches first; then age out partial batches; then, once the
        # arrival stream is exhausted, drain whatever is left
        ready = [b for b, q in pending.items() if len(q) >= server.chunks]
        if not ready:
            now = time.perf_counter() - t0
            ready = [
                b for b, q in pending.items()
                if q and now - q[0].query.arrival_s >= max_wait_s
            ]
        if not ready and i >= len(queries):
            ready = [b for b, q in pending.items() if q]
        if ready:
            dispatch(ready[0])
            continue
        if i < len(queries):
            now = time.perf_counter() - t0
            wake = queries[i].arrival_s
            for q in pending.values():
                if q:
                    wake = min(wake, q[0].query.arrival_s + max_wait_s)
            if wake > now:
                time.sleep(min(wake - now, 0.05))
    return results


def verify_results(
    model, params, g: GraphBatch, results: list[ServedResult], *, atol: float = 0.0
) -> tuple[int, int, float]:
    """Served-vs-full-batch check. Returns ``(mismatches, exact, max_diff)``
    where ``exact`` counts bit-identical results and ``mismatches`` counts
    results with any |diff| > ``atol``.

    On a real (single-device) host every served logp row is bit-identical to
    the full-batch forward — lossless halo + preserved neighbor column order
    + identical per-row reductions. Under ``--xla_force_host_platform_
    device_count`` XLA CPU divides its thread pool and may re-tile the
    bucket-shaped gemms, re-ordering a dot product's accumulation: rare rows
    then differ by ~1 ULP (observed 1/250 at 1.19e-7). That is XLA numerics
    vs shape+threading, not the serving path — a plain ``model.apply`` on
    the same padded ego reproduces it — so the forced-device CI smoke
    verifies with a 1e-6 tolerance while the single-device tests pin strict
    bit-identity."""
    full = np.asarray(model.apply(params, g, train=False))
    bad = exact = 0
    max_diff = 0.0
    for r in results:
        want = full[list(r.query.seeds)]
        if np.array_equal(r.logp, want):
            exact += 1
        else:
            diff = float(np.abs(r.logp - want).max())
            max_diff = max(max_diff, diff)
            if diff > atol:
                bad += 1
    return bad, exact, max_diff


def run(args) -> dict:
    from repro.core.cli import PipelineCLIConfig
    from repro.core.pipeline import make_engine
    from repro.graphs import load_dataset
    from repro.models.gnn.net import build_paper_gat

    g = load_dataset(args.dataset, seed=args.seed)
    # serving is forward-only (train=False), so the pallas backend's
    # attn-dropout restriction never triggers and the paper rate can stay
    model = build_paper_gat(g.num_features, g.num_classes, backend=args.backend)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    cli = PipelineCLIConfig.from_args(args)
    if cli.auto:
        # serving shares the planner: the pick's schedule/chunks/balance/
        # placement configure the engine whose eval programs serve traffic
        from repro.core.autotune import plan_for_cli

        auto_plan = plan_for_cli(model, g, cli, params=params, seed=args.seed)
        print(auto_plan.format_table(limit=10))
        if cli.dry_run:
            return {"mode": "auto-dry-run", "schedule": auto_plan.schedule,
                    "chunks": auto_plan.chunks, "balance": list(auto_plan.balance)}
        cli = dataclasses.replace(
            cli, schedule=auto_plan.schedule, chunks=auto_plan.chunks,
            stages=auto_plan.num_stages,
        )
        engine = make_engine(model, auto_plan)
    else:
        engine = make_engine(model, cli.gpipe_config())
    buckets = ShapeBuckets.geometric(g, base=args.bucket_base)
    server = GNNServer(engine, params, g, hops=args.hops, buckets=buckets)

    n = max(1, int(round(args.qps * args.duration)))
    queries = synth_queries(g, n, qps=args.qps, link_frac=args.link_frac, seed=args.seed)

    # warmup: compile every bucket this query set will touch (compile time
    # must not land inside the measured window) and time one warm call each
    probes, order = {}, []
    for q in queries:
        p = server.prepare(q)
        if p.bucket not in probes:
            probes[p.bucket] = p
            order.append(p.bucket)
    eval_call_s = {b: server.warm(b, probes[b]) for b in order}
    server.stats.clear()
    print(f"[serve] dataset={args.dataset} engine={cli.engine} schedule={cli.schedule} "
          f"stages={cli.stages} chunks={cli.chunks} hops={args.hops} "
          f"buckets={[buckets.size_of(b) for b in sorted(probes)]} "
          f"warm_call_ms={ {buckets.size_of(b): round(t * 1e3, 2) for b, t in sorted(eval_call_s.items())} }")

    results = serve(server, queries, max_wait_s=args.max_wait_ms / 1e3)
    assert len(results) == n

    lat = np.array([r.latency_s for r in results])
    span = max(max(r.query.arrival_s + r.latency_s for r in results), 1e-9)
    occupancy = server.occupancy()
    total_batches = sum(v["batches"] for v in occupancy.values())
    summary = {
        "dataset": args.dataset,
        "engine": cli.engine,
        "schedule": cli.schedule,
        "chunks": cli.chunks,
        "stages": cli.stages,
        "hops": args.hops,
        "qps": args.qps,
        "queries": n,
        "achieved_qps": n / span,
        "p50_s": float(np.percentile(lat, 50)),
        "p99_s": float(np.percentile(lat, 99)),
        "mean_s": float(lat.mean()),
        # the gate's machine-cancelling normalizer: one warm batch call of
        # the heaviest bucket in use, measured in the same run
        "eval_call_s": float(max(eval_call_s.values())),
        "occupancy": sum(v["queries"] for v in occupancy.values())
        / max(total_batches * server.chunks, 1),
        "buckets": occupancy,
    }
    print(f"[serve] {n} queries in {span:.2f}s: {summary['achieved_qps']:.1f} q/s "
          f"(offered {args.qps}), p50 {summary['p50_s'] * 1e3:.1f}ms "
          f"p99 {summary['p99_s'] * 1e3:.1f}ms, occupancy {summary['occupancy']:.2f}")
    for size, v in occupancy.items():
        print(f"[serve]   bucket n<={size}: {v['queries']} queries / "
              f"{v['batches']} batches (occupancy {v['occupancy']:.2f})")

    mismatches = None
    if args.verify:
        mismatches, exact, max_diff = verify_results(
            model, params, g, results, atol=args.verify_atol
        )
        summary["verify_mismatches"] = mismatches
        summary["verify_exact"] = exact
        summary["verify_max_diff"] = max_diff
        print(f"[serve] verify: {exact}/{n} served predictions bit-identical "
              f"to host full-batch eval, {mismatches} beyond "
              f"atol={args.verify_atol:g} (max diff {max_diff:.3g})")

    if args.json_out:
        os.makedirs(args.json_out, exist_ok=True)
        key = f"serving/{args.dataset}/{cli.engine}/qps{args.qps:g}"
        with open(os.path.join(args.json_out, "BENCH_serve.json"), "w") as f:
            json.dump({"rows": {key: summary}}, f, indent=2, sort_keys=True)
            f.write("\n")
        counts, edges = np.histogram(lat * 1e3, bins=30)
        with open(os.path.join(args.json_out, "latency_hist.json"), "w") as f:
            json.dump({
                "unit": "ms",
                "bin_edges": [float(e) for e in edges],
                "counts": [int(c) for c in counts],
                "p50": summary["p50_s"] * 1e3,
                "p99": summary["p99_s"] * 1e3,
            }, f, indent=2)
            f.write("\n")
    if mismatches:
        raise SystemExit(f"--verify: {mismatches} served predictions diverged")
    return summary


def main():
    from repro.core.cli import add_pipeline_args

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--qps", type=float, default=50.0, help="offered load (open-loop Poisson)")
    ap.add_argument("--duration", type=float, default=5.0, help="arrival window, seconds")
    ap.add_argument("--hops", type=int, default=2,
                    help="ego-subgraph halo depth; >= model receptive depth (2 for "
                         "the paper GAT) makes served predictions exact")
    ap.add_argument("--link-frac", type=float, default=0.25,
                    help="fraction of link-prediction queries in the stream")
    ap.add_argument("--max-wait-ms", type=float, default=50.0,
                    help="partial batches dispatch after the oldest request waits this long")
    ap.add_argument("--bucket-base", type=int, default=64,
                    help="smallest shape bucket; ladder doubles up to the full graph")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None,
                    help="directory for BENCH_serve.json + latency_hist.json")
    ap.add_argument("--verify", action="store_true",
                    help="check every served prediction bit-identical to host full-batch eval")
    ap.add_argument("--verify-atol", type=float, default=0.0,
                    help="--verify failure tolerance; 0 = strict bit-identity (the "
                         "single-real-device guarantee). Forced-device CI uses 1e-6: "
                         "XLA CPU re-tiles bucket-shaped gemms under a divided thread "
                         "pool and rare rows shift ~1 ULP (see verify_results)")
    add_pipeline_args(ap, engine="compiled", chunks=4, stages=4)
    run(ap.parse_args())


if __name__ == "__main__":
    main()
