"""Production mesh builders (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state; call it only after the XLA device count is configured
(dryrun.py sets the 512-placeholder-device flag before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 (data, model). Two pods: 2×16×16 (pod, data, model).

    ``model`` is the pipeline-stage axis, ``data`` is DP+ZeRO-3(+EP),
    ``pod`` is cross-pod data parallelism — see DESIGN.md §5.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, data: int = 2, model: int = 4, pod: int | None = None):
    """Reduced mesh for CPU smoke tests (requires host-device override)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
