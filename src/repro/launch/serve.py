"""Batched serving driver: prefill a request batch, then decode N tokens
through the pipelined ``serve_step`` (greedy).

    PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b \
        --prompt-len 64 --decode-steps 16 --batch 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run(args) -> dict:
    from repro.configs import get_arch, ShapeConfig
    from repro.data.tokens import token_batch, frontend_embeds
    from repro.models.transformer.model import (
        Topology, init_params, make_prefill_step, make_serve_step,
    )

    cfg = get_arch(args.arch, smoke=not args.full_arch)
    n_dev = jax.device_count()
    stages = args.stages if args.stages > 1 else 1
    data = max(n_dev // stages, 1)
    mesh = jax.make_mesh((data, stages), ("data", "model"))
    topo = Topology(num_stages=stages, fsdp_size=data, num_micro=args.chunks)

    total = args.prompt_len + args.decode_steps
    pshape = ShapeConfig("serve_prefill", args.prompt_len, args.batch, "prefill")
    dshape = ShapeConfig("serve_decode", total + 16, args.batch, "decode")

    part = make_prefill_step(cfg, topo, pshape, mesh, dtype=jnp.float32)
    sart = make_serve_step(cfg, topo, dshape, mesh, dtype=jnp.float32)

    params = init_params(cfg, jax.random.PRNGKey(args.seed), num_stages=stages, dtype=jnp.float32)
    params = jax.device_put(params, part.in_shardings[0])

    s_front = int(args.prompt_len * cfg.frontend_frac) if cfg.frontend != "none" else 0
    prompt = {
        "tokens": jnp.asarray(token_batch(
            batch=args.batch, seq=args.prompt_len - s_front, vocab=cfg.vocab_size, seed=args.seed,
        ))[:, :-1][:, : args.prompt_len - s_front]
    }
    if s_front:
        prompt["frontend_embeds"] = jnp.asarray(frontend_embeds(
            batch=args.batch, seq=s_front, d_model=cfg.d_model, seed=args.seed,
        ))

    # prefill into a decode-width cache: run prefill at prompt length, then
    # copy entries into the wider serving cache (host-side splice)
    pcache0 = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), part.abstract_inputs[1])
    t0 = time.perf_counter()
    logits, pcache = jax.jit(part.fn, in_shardings=part.in_shardings,
                             out_shardings=part.out_shardings)(params, pcache0, prompt)
    t_prefill = time.perf_counter() - t0

    dcache = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), sart.abstract_inputs[1])

    def splice(dst, src):
        if dst.ndim >= 5 and src.ndim == dst.ndim and src.shape[:3] == dst.shape[:3]:
            # KV-like leaves: (S, NM, per, B, W, ...) — copy prefilled W slots
            w = src.shape[4]
            return dst.at[:, :, :, :, :w].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)  # state-like leaves (ssm/conv): carry over

    dcache = jax.tree_util.tree_map(splice, dcache, pcache)

    step = jax.jit(sart.fn, in_shardings=sart.in_shardings, out_shardings=sart.out_shardings)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.decode_steps):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        tok, dcache = step(params, dcache, {"tokens": tok, "pos": pos})
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)
    out = {
        "arch": cfg.name,
        "batch": args.batch,
        "prefill_s": round(t_prefill, 3),
        "decode_s_per_tok": round(t_decode / max(args.decode_steps, 1), 4),
        "tokens_generated": int(gen.size),
        "sample": gen[0][:8].tolist(),
    }
    print(out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--full-arch", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--stages", type=int, default=1)
    ap.add_argument("--chunks", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    run(ap.parse_args())


if __name__ == "__main__":
    main()
