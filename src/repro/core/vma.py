"""Varying-manual-axes (vma) helpers for JAX >= 0.8 shard_map bodies.

Inside ``shard_map``, `lax.scan` requires carry input/output types to agree
on which mesh axes they vary over. Freshly-built carries (``jnp.zeros(...)``)
are unvarying; if the scan body mixes in varying operands the carry output
becomes varying and tracing fails. ``match_vma`` pre-casts an init pytree to
vary over the union of the reference operands' axes (plus any extras), and is
a no-op outside shard_map.
"""

from __future__ import annotations

import jax
from jax import lax


def vma_of(*refs) -> frozenset[str]:
    """Union of the varying-manual-axes sets across every leaf of ``refs``
    (empty on jax versions that predate vma tracking)."""
    axes: frozenset[str] = frozenset()
    for r in refs:
        for leaf in jax.tree_util.tree_leaves(r):
            try:
                axes = axes | jax.typeof(leaf).vma
            except (AttributeError, TypeError):
                pass
    return axes


def match_vma(init, *refs, extra: tuple[str, ...] = ()):
    """Cast every leaf of ``init`` to vary over vma(refs) ∪ extra."""
    if not hasattr(lax, "pcast"):  # pre-vma JAX: shard_map doesn't track vma
        return init
    want = vma_of(*refs) | frozenset(extra)
    if not want:
        return init

    def fix(a):
        try:
            have = jax.typeof(a).vma
        except (AttributeError, TypeError):
            have = frozenset()
        need = tuple(sorted(want - have))
        return lax.pcast(a, need, to="varying") if need else a

    return jax.tree_util.tree_map(fix, init)
