"""GPipe fill-drain schedule model + bubble accounting.

The schedule is the paper's object of study: with S stages and C chunks the
synchronous fill-drain pipeline runs C + S - 1 forward ticks and C + S - 1
backward ticks; the idle ("bubble") fraction is (S - 1) / (C + S - 1).

``fill_drain_timeline`` enumerates (tick, stage, chunk, phase) work items —
used both by the Python-scheduled GNN engine (execution order) and by the
benchmark harness (predicted-vs-measured epoch time, Fig 3 analogue).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WorkItem:
    tick: int
    stage: int
    chunk: int
    phase: str  # "fwd" | "bwd"


def fill_drain_timeline(num_stages: int, num_chunks: int) -> list[WorkItem]:
    items: list[WorkItem] = []
    # forward: stage s handles chunk c at tick c + s
    for t in range(num_chunks + num_stages - 1):
        for s in range(num_stages):
            c = t - s
            if 0 <= c < num_chunks:
                items.append(WorkItem(t, s, c, "fwd"))
    off = num_chunks + num_stages - 1
    # backward: reverse stage order; stage s handles chunk c at tick
    # off + (num_chunks - 1 - c) + (num_stages - 1 - s)
    for t in range(num_chunks + num_stages - 1):
        for s in range(num_stages):
            c = (num_chunks - 1) - (t - (num_stages - 1 - s))
            if 0 <= c < num_chunks:
                items.append(WorkItem(off + t, s, c, "bwd"))
    return items


def bubble_fraction(num_stages: int, num_chunks: int) -> float:
    """Idle fraction of the synchronous fill-drain schedule (per GPipe)."""
    return (num_stages - 1) / (num_chunks + num_stages - 1)


def predicted_step_time(
    num_stages: int,
    num_chunks: int,
    *,
    fwd_cost_per_chunk: float,
    bwd_cost_per_chunk: float,
    transfer_cost: float = 0.0,
    rebuild_cost_per_chunk: float = 0.0,
) -> float:
    """Analytic fill-drain step time with per-chunk stage costs.

    Per-stage per-chunk cost is cost/num_stages (balanced partition);
    the critical path runs (C + S - 1) ticks each phase. The paper's observed
    slowdown is the ``rebuild_cost_per_chunk * C`` term (host-side sub-graph
    rebuilds) dominating at small graph scale."""
    f = fwd_cost_per_chunk / num_stages + transfer_cost
    b = bwd_cost_per_chunk / num_stages + transfer_cost
    ticks = num_chunks + num_stages - 1
    return ticks * (f + b) + num_chunks * rebuild_cost_per_chunk
