"""Pluggable pipeline schedules + bubble/memory accounting.

The schedule is the paper's object of study: with S stages and C chunks the
synchronous fill-drain pipeline runs C + S - 1 forward ticks and C + S - 1
backward ticks; the idle ("bubble") fraction is (S - 1) / (C + S - 1).
GNNPipe/GraphPipe show smarter schedules are the main lever for closing that
gap, so the schedule is now an abstraction, not a single function:

  * ``fill_drain``  — GPipe's synchronous schedule (the paper's §6 baseline).
    All C forwards complete before any backward: peak live activations are
    C per stage, bubble (S-1)/(C+S-1).
  * ``1f1b``        — one-forward-one-backward (PipeDream-flush /
    Megatron-LM's non-interleaved schedule). Same bubble as fill-drain for
    equal fwd/bwd tick costs, but stage s holds at most min(S-s, C) live
    activations — the memory lever.
  * ``interleaved`` — interleaved 1F1B: each of D physical devices hosts
    V = S/D *virtual* stages placed round-robin (stage k on device k mod D);
    activations hop device→device circularly. The bubble shrinks by ~V:
    (D-1)/(V·C+D-1) instead of (D-1)/(C+D-1).
  * ``zb-h1``      — zero-bubble H1 (Qi et al.): the backward is SPLIT into
    a **B** phase (input-grad — the only part on the pipeline's critical
    path) and a **W** phase (weight-grad — needed only by the optimizer at
    the end of the step). B items keep 1F1B's ordering and memory window;
    W items fill the drain bubble whenever their device would otherwise
    idle. With unit costs the makespan is 3C + S - 1 ticks for 3C work
    units per device — the drain bubble all but disappears.

Split-backward timelines use two extra phases: ``bwd_b`` (consumes the
stashed stage input + downstream cotangent, emits the upstream cotangent
and a residual) and ``bwd_w`` (consumes the residual, emits the stage's
parameter gradients). A (stage, chunk) pair either runs the fused ``bwd``
or BOTH split halves, W strictly after its matching B on the same device.

Every schedule emits a ``WorkItem`` timeline — (tick, stage, chunk, phase,
device) — consumed generically by the host-driven GNN engine
(``repro.core.pipeline``) and by the benchmark harness (predicted-vs-measured
epoch time, Fig 3 analogue). ``validate_timeline`` checks the invariants any
correct timeline must satisfy; the 1F1B/interleaved timelines come out of a
greedy list scheduler whose dependency graph encodes both data flow and the
1F1B in-flight activation window, so they are correct by construction.

Module-level ``fill_drain_timeline`` / ``bubble_fraction`` /
``predicted_step_time`` are kept as the fill-drain shorthand (the paper's
formulas, used throughout the benchmarks).

Lowering contract (``lower_timeline`` -> ``LoweredTimeline``) — the bridge
between a ``WorkItem`` timeline and the compiled executors: the timeline
becomes dense per-tick ``(T, D)`` index arrays (phase / stage / chunk) plus
stash-slot routing, one slot family per buffer kind:

  * **fslot** — activation-stash slots. ``in_fslot[t, d]`` banks the
    forward-wire value arriving at device d this tick; ``work_fslot[t, d]``
    is where this tick's item reads its stage input (bwd/bwd_b re-derive
    the vjp from it — GPipe re-materialization).
  * **bslot** — cotangent-stash slots, same in/work pattern for the
    backward wire.
  * **wslot** — deferred-W residual slots: ``bwd_b`` writes its residual to
    ``store_wslot``; the matching ``bwd_w`` reads ``work_wslot``. Empty
    (``n_wslots == 0``) for fused-backward schedules.

Slot indices come from a FREE-LIST simulation over the timeline (allocate
at arrival, release after last read, reuse eagerly), so ``n_fslots`` /
``n_bslots`` / ``n_wslots`` are the schedule's *real* live windows — 1F1B
lowers to ~min(S, C) activation slots where fill-drain needs C — and the
executors' stash arrays are sized by them, never by S*C. Every slot array
reserves index ``n_*slots`` as the sacrificial slot: idle ticks read/write
it so the scan body stays branch-free. See ``LoweredTimeline`` for the
authoritative field-by-field statement.

Wire parity (communication/compute overlap): ``wire_latency`` is the number
of ticks between a value's producing tick and the tick its arrival is
banked. Latency 1 is the serialized executor — the ``ppermute`` for tick
t's output issues after tick t's work, and the value is banked at t+1.
Latency 2 is the DOUBLE-BUFFERED executor: each direction holds two wire
buffers alternating by tick parity — the value produced at tick t sits in
the *pending* buffer through tick t+1 (its ``ppermute`` is issued at the
top of tick t+1, BEFORE t+1's work, so the collective has a full tick of
compute to hide behind) and is banked from the *wire* buffer at t+2.
``retime_timeline`` stretches any validated timeline so every wire edge
has >= ``wire_latency`` ticks of slack; ``lower_timeline(...,
wire_latency=2)`` then emits arrival indices one tick ahead of consumption
and rejects timelines whose wire edges are too tight. All-idle ticks
(ragged plans lowered with ``skip_chunks`` produce them) are deleted from
the emitted arrays — the remap keeps every producer→arrival distance
exactly ``wire_latency``, so dead ticks never pay their two ppermutes.
"""

from __future__ import annotations

import abc
import dataclasses
import heapq

import numpy as np

# ----------------------------------------------------------------- items --


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One scheduled unit of work: (tick, stage, chunk, phase, device) —
    the element every timeline is a sorted list of."""

    tick: int
    stage: int
    chunk: int
    phase: str  # "fwd" | "bwd" (fused) | "bwd_b" (input-grad) | "bwd_w" (weight-grad)
    device: int = -1  # physical device; defaults to == stage (one stage/device)

    def __post_init__(self):
        if self.device < 0:
            object.__setattr__(self, "device", self.stage)


def _sort_key(it: WorkItem):
    # canonical execution order: tick-major, forwards before backwards inside
    # a tick (a tick's items are concurrent on real hardware; a host executor
    # running them in this order never frees an activation before its save)
    return (it.tick, 0 if it.phase == "fwd" else 1, it.stage, it.chunk)


@dataclasses.dataclass(frozen=True)
class Placement:
    """Stage -> device assignment for a pipeline timeline.

    ``stage_to_device[s]`` is the RING POSITION hosting stage ``s``. The only
    placements the compiled executors can route are the ring-compatible ones
    ``lower_timeline`` accepts — stage s+1 one ``ppermute`` hop downstream of
    stage s (``stage_to_device[s + 1] == (stage_to_device[s] + 1) % D``) — so
    every valid placement is a rotation of the schedule's default: one stage
    per device rotated by k, or the interleaved round-robin rotated by k.
    ``validate`` enforces exactly that rule (the same check the lowering
    performs) so a bad placement fails loudly at construction instead of
    surfacing as mis-routed activations.

    ``device_order`` (optional) maps ring position -> PHYSICAL device index
    (an index into the host's device list): it chooses which real device
    hosts which ring position without changing the logical dataflow — the
    knob for heterogeneous hosts where the slowest stage should sit on the
    fastest device. ``None`` means positions 0..D-1 in enumeration order.
    """

    stage_to_device: tuple[int, ...]
    device_order: tuple[int, ...] | None = None

    @property
    def num_devices(self) -> int:
        """Physical ring size implied by the stage->device map."""
        return max(self.stage_to_device) + 1

    @classmethod
    def ring(
        cls,
        num_stages: int,
        num_devices: int | None = None,
        *,
        rotation: int = 0,
        device_order: tuple[int, ...] | None = None,
    ) -> "Placement":
        """The canonical ring placements: stage s on ring position
        ``(s + rotation) % D`` — one stage per device when ``num_devices`` is
        omitted, the interleaved round-robin otherwise."""
        D = num_stages if num_devices is None else num_devices
        return cls(
            tuple((s + rotation) % D for s in range(num_stages)),
            device_order=device_order,
        ).validate(num_stages)

    def validate(self, num_stages: int) -> "Placement":
        """Check stage count, device contiguity and device_order arity;
        returns self for chaining."""
        std = self.stage_to_device
        if len(std) != num_stages:
            raise ValueError(
                f"placement maps {len(std)} stages, schedule has {num_stages}"
            )
        D = self.num_devices
        if sorted(set(std)) != list(range(D)):
            raise ValueError(
                f"placement must use ring positions 0..{D - 1} contiguously, "
                f"got {std}"
            )
        for s in range(num_stages - 1):
            if std[s + 1] != (std[s] + 1) % D:
                raise ValueError(
                    f"placement is not ring-compatible: stage {s + 1} on "
                    f"device {std[s + 1]}, expected {(std[s] + 1) % D} (one "
                    f"hop after stage {s} on device {std[s]})"
                )
        if self.device_order is not None:
            if len(self.device_order) != D or len(set(self.device_order)) != D:
                raise ValueError(
                    f"device_order must list {D} distinct physical device "
                    f"indices, got {self.device_order}"
                )
        return self

    def apply(self, items: list[WorkItem]) -> list[WorkItem]:
        """Re-device a timeline onto this placement (ticks untouched)."""
        return [
            dataclasses.replace(it, device=self.stage_to_device[it.stage])
            for it in items
        ]


def validate_timeline(
    items: list[WorkItem], num_stages: int, num_chunks: int
) -> None:
    """Raise AssertionError unless ``items`` is a correct pipeline timeline:

    * each (stage, chunk, phase) appears exactly once; every (stage, chunk)
      has its fwd plus EITHER a fused ``bwd`` OR both split halves
      (``bwd_b`` + ``bwd_w``), never a mix;
    * no device runs two items in the same tick, and a stage never moves
      between devices;
    * fwd(s, c) strictly after fwd(s-1, c);
    * the input-grad tick b(s, c) — the fused bwd or the split bwd_b —
      strictly after b(s+1, c), and after fwd(S-1, c) at the last stage;
    * b(s, c) strictly after fwd(s, c) at EVERY stage, and strictly after
      fwd(s+1, c) — a chunk's backward can only start once its forward has
      cleared the stage whose cotangent it consumes. (For a complete
      timeline these follow from the chained checks above, but they are
      asserted directly so a violation is reported at the offending item
      instead of surfacing as a far-away chain inconsistency.)
    * bwd_w(s, c) strictly after its matching bwd_b(s, c), on the SAME
      device — the residual it consumes never travels the wire.
    """
    S, C = num_stages, num_chunks
    seen: dict[tuple[int, int, str], int] = {}
    dev: dict[tuple[int, int, str], int] = {}
    for it in items:
        key = (it.stage, it.chunk, it.phase)
        assert key not in seen, f"duplicate work item {key}"
        assert 0 <= it.stage < S and 0 <= it.chunk < C, it
        assert it.phase in ("fwd", "bwd", "bwd_b", "bwd_w"), it
        seen[key] = it.tick
        dev[key] = it.device
    busy: dict[tuple[int, int], WorkItem] = {}
    for it in items:
        other = busy.setdefault((it.tick, it.device), it)
        assert other is it, (
            f"device {it.device} runs two items in tick {it.tick}: "
            f"(stage {other.stage}, chunk {other.chunk}, {other.phase}) and "
            f"(stage {it.stage}, chunk {it.chunk}, {it.phase})"
        )
    stage_dev: dict[int, int] = {}
    for it in items:
        assert stage_dev.setdefault(it.stage, it.device) == it.device, (
            f"stage {it.stage} placed on two devices: "
            f"{stage_dev[it.stage]} and {it.device}"
        )

    n_split = 0
    for c in range(C):
        for s in range(S):
            assert (s, c, "fwd") in seen, (s, c, "missing fwd")
            fused = (s, c, "bwd") in seen
            has_b = (s, c, "bwd_b") in seen
            has_w = (s, c, "bwd_w") in seen
            assert fused != (has_b or has_w), (
                s, c, "backward must be fused bwd XOR split bwd_b/bwd_w",
            )
            if not fused:
                assert has_b, (s, c, "bwd_w without a matching bwd_b")
                assert has_w, (s, c, "bwd_b without a matching bwd_w")
                assert seen[(s, c, "bwd_w")] > seen[(s, c, "bwd_b")], (
                    s, c, "W scheduled before its matching B",
                )
                assert dev[(s, c, "bwd_w")] == dev[(s, c, "bwd_b")], (
                    s, c, "W on a different device than its matching B",
                )
                n_split += 1
    assert len(seen) == 2 * S * C + n_split, (
        f"expected {2 * S * C + n_split} items, got {len(seen)}"
    )

    def t_b(s, c):  # the input-grad tick: fused bwd or split bwd_b
        return seen[(s, c, "bwd")] if (s, c, "bwd") in seen else seen[(s, c, "bwd_b")]

    for c in range(C):
        for s in range(1, S):
            assert seen[(s, c, "fwd")] > seen[(s - 1, c, "fwd")], (s, c, "fwd dep")
        assert t_b(S - 1, c) > seen[(S - 1, c, "fwd")], (c, "loss dep")
        for s in range(S - 1):
            assert t_b(s, c) > t_b(s + 1, c), (s, c, "bwd dep")
        # direct cross-phase checks: b(s, c) after its own fwd AND after
        # the downstream fwd whose cotangent it consumes
        for s in range(S):
            assert t_b(s, c) > seen[(s, c, "fwd")], (s, c, "bwd before own fwd")
        for s in range(S - 1):
            assert t_b(s, c) > seen[(s + 1, c, "fwd")], (
                s, c, "bwd before fwd of next stage",
            )


def validate_forward_timeline(
    items: list[WorkItem], num_stages: int, num_chunks: int
) -> None:
    """The inference/eval subset of ``validate_timeline``: forward items
    only, each (stage, chunk) exactly once, fwd chain respected, no device
    double-booked."""
    S, C = num_stages, num_chunks
    seen: dict[tuple[int, int], int] = {}
    for it in items:
        assert it.phase == "fwd", it
        key = (it.stage, it.chunk)
        assert key not in seen, f"duplicate forward item {key}"
        assert 0 <= it.stage < S and 0 <= it.chunk < C, it
        seen[key] = it.tick
    assert len(seen) == S * C, f"expected {S * C} items, got {len(seen)}"
    busy = {(it.tick, it.device) for it in items}
    assert len(busy) == len(items), "a device runs two items in one tick"
    for c in range(C):
        for s in range(1, S):
            assert seen[(s, c)] > seen[(s - 1, c)], (s, c, "fwd dep")


def peak_live_activations(items: list[WorkItem]) -> int:
    """Max simultaneous saved stage-inputs implied by the timeline: the input
    of stage s for chunk c is live from fwd(s, c) until bwd(s, c) consumes it
    (GPipe re-materializes stage internals, so only stage *inputs* persist).
    For split-backward timelines the input-grad half (``bwd_b``) is the
    consumer — it moves the input into the W residual, so the activation
    window matches 1F1B's; ``bwd_w`` touches only the residual stash,
    accounted separately (``LoweredTimeline.n_wslots``)."""
    live = 0
    peak = 0
    for it in sorted(items, key=_sort_key):
        if it.phase == "fwd":
            live += 1
            peak = max(peak, live)
        elif it.phase in ("bwd", "bwd_b"):
            live -= 1
    return peak


# ------------------------------------------ timeline -> index arrays --

PHASE_IDLE, PHASE_FWD, PHASE_BWD, PHASE_BWD_B, PHASE_BWD_W = 0, 1, 2, 3, 4

_PHASE_CODE = {
    "fwd": PHASE_FWD, "bwd": PHASE_BWD, "bwd_b": PHASE_BWD_B, "bwd_w": PHASE_BWD_W,
}


# eq=False: the auto-generated __eq__ would compare ndarray fields with
# bool(a == b) and raise the ambiguous-truth-value error on first use (and
# frozen+eq would try to hash arrays); identity semantics are the contract.
@dataclasses.dataclass(frozen=True, eq=False)
class LoweredTimeline:
    """A ``WorkItem`` timeline compiled to dense per-tick index arrays — the
    static program the schedule-aware compiled executor
    (``repro.core.spmd_pipe.spmd_pipeline_scheduled``) scans over.

    Every array is (num_ticks, num_devices) int32; device d reads its column
    each tick:

      * ``phase``      — PHASE_IDLE / PHASE_FWD / PHASE_BWD;
      * ``stage``      — the (virtual) stage the work item runs (0 when idle);
      * ``chunk``      — the item's microbatch (0 when idle);
      * ``work_fslot`` — activation-stash slot holding this item's *stage
        input*: a fwd reads its banked input there, a bwd re-materializes
        from it.  ``n_fslots`` (the sacrificial slot) for stage-0 items,
        whose input is read from the chunk's features instead, and when idle;
      * ``in_fslot``   — where to bank the forward-wire value arriving this
        tick (the upstream stage's output, one ``ppermute`` hop old);
        sacrificial when the arriving value is fill/drain garbage;
      * ``work_bslot`` — cotangent-stash slot a bwd/bwd_b reads; sacrificial
        for the last stage (its cotangent comes from the loss) and ticks
        that consume no cotangent;
      * ``in_bslot``   — where to bank the backward-wire value arriving this
        tick; sacrificial for garbage;
      * ``work_wslot`` — residual-stash slot a ``bwd_w`` reads (the
        (stage input, applied cotangent) pair its matching ``bwd_b``
        banked); sacrificial on every other tick;
      * ``store_wslot`` — residual-stash slot a ``bwd_b`` WRITES after its
        work (no wire hop: B and W run on the same device); sacrificial on
        every other tick.

    Slots are assigned by a free-list simulation over the timeline, so
    ``n_fslots`` is the schedule's real per-device activation window (1F1B's
    min(S-s, C) memory lever) rather than the fill-drain C — plus the wire
    slack between an activation's arrival and the tick its fwd consumes it.
    ``peak_live_stash`` is the max number of simultaneously banked stage
    inputs summed across devices (the compiled analogue of the host engine's
    measured ``len(saved)`` peak, minus stage-0 inputs which are never
    stashed — they are read from the replicated feature table by chunk id).

    ``wire_latency`` selects the executor's wire dataflow: 1 — a value put
    on the wire at tick t is banked at t+1 (the serialized ppermute-after-
    work executor); 2 — banked at t+2 through the parity-alternating double
    buffer (the ppermute for tick t's arrivals is issued before tick t-1's
    work, off the critical path). The index arrays already encode the
    latency (arrivals land ``wire_latency`` ticks after production), so the
    executors only branch on this field to pick the matching carry shape.
    """

    num_stages: int
    num_chunks: int
    num_devices: int
    num_ticks: int
    phase: np.ndarray
    stage: np.ndarray
    chunk: np.ndarray
    work_fslot: np.ndarray
    in_fslot: np.ndarray
    work_bslot: np.ndarray
    in_bslot: np.ndarray
    work_wslot: np.ndarray
    store_wslot: np.ndarray
    n_fslots: int
    n_bslots: int
    n_wslots: int
    peak_live_stash: int
    wire_latency: int = 1


def _alloc_slots(entries):
    """Free-list slot allocation for [arrival, release] tick intervals.

    ``entries`` is a list of (arrival, release, key); a slot freed at tick t
    is reusable from t + 1 (the executor banks arrivals *before* the tick's
    read, so same-tick reuse would clobber an unread value). Returns
    (slot_of_key, n_slots)."""
    slot_of: dict = {}
    n_slots = 0
    free: list[int] = []
    active: list[tuple[int, int]] = []  # (release, slot) min-heap
    for arrival, release, key in sorted(entries):
        while active and active[0][0] < arrival:
            heapq.heappush(free, heapq.heappop(active)[1])
        if free:
            slot = heapq.heappop(free)
        else:
            slot = n_slots
            n_slots += 1
        slot_of[key] = slot
        heapq.heappush(active, (release, slot))
    return slot_of, n_slots


def retime_timeline(
    items: list[WorkItem],
    num_stages: int,
    num_chunks: int,
    *,
    wire_latency: int = 2,
) -> list[WorkItem]:
    """Stretch a validated timeline so every wire edge has >= ``wire_latency``
    ticks between its producing and consuming items — the earliest-start
    retiming that makes a latency-1 schedule double-bufferable.

    A single longest-path pass over the items in canonical order (a valid
    topological order: every dependency's original tick is strictly smaller,
    and within a tick forwards precede backwards). Constraints:

      * per-device sequencing — each device's items keep their original
        relative order, one tick apart at minimum (this also covers the
        same-device dependencies: loss after the last stage's fwd, W after
        its matching B);
      * wire edges — fwd(s, c) at least ``wire_latency`` ticks after
        fwd(s-1, c), and the input-grad item of (s, c) at least
        ``wire_latency`` ticks after that of (s+1, c).

    Per-device order preservation keeps the arrival-collision property of
    the input timeline (one producer per direction per device per tick);
    the fill phase inflates by ~(wire_latency - 1)(S - 1) ticks while steady
    -state 1F1B/zb-h1 ticks mostly already carry the slack."""
    S, C = num_stages, num_chunks
    validate_timeline(items, S, C)
    L = wire_latency
    new_tick: dict[tuple[int, int, str], int] = {}
    last_on_dev: dict[int, int] = {}

    def b_key(s, c):
        return (s, c, "bwd") if (s, c, "bwd") in new_tick else (s, c, "bwd_b")

    out: list[WorkItem] = []
    for it in sorted(items, key=_sort_key):
        earliest = last_on_dev.get(it.device, -1) + 1
        if it.phase == "fwd" and it.stage > 0:
            earliest = max(earliest, new_tick[(it.stage - 1, it.chunk, "fwd")] + L)
        elif it.phase in ("bwd", "bwd_b") and it.stage < S - 1:
            earliest = max(earliest, new_tick[b_key(it.stage + 1, it.chunk)] + L)
        new_tick[(it.stage, it.chunk, it.phase)] = earliest
        last_on_dev[it.device] = earliest
        out.append(dataclasses.replace(it, tick=earliest))
    return sorted(out, key=_sort_key)


def lower_timeline(
    items: list[WorkItem],
    num_stages: int,
    num_chunks: int,
    *,
    forward_only: bool = False,
    wire_latency: int = 1,
    skip_chunks: tuple[int, ...] = (),
) -> LoweredTimeline:
    """Lower a validated timeline to the per-tick index arrays of
    ``LoweredTimeline``.

    Static validation beyond ``validate_timeline``: the device placement must
    be ring-compatible — stage s+1 must sit one ``ppermute`` hop downstream
    of stage s (device_of(s+1) == (device_of(s) + 1) % D) so a single
    forward ring (and its transpose for cotangents) carries every edge of
    the pipeline DAG. All shipped schedules (fill-drain, 1F1B, interleaved
    round-robin placement, zb-h1) satisfy this; a custom placement that does
    not raises ``ValueError`` here instead of silently mis-routing
    activations.

    Split-backward timelines additionally get a W residual-slot free-list:
    ``bwd_b(s, c)`` banks its residual into ``store_wslot`` and the matching
    ``bwd_w(s, c)`` reads it back through ``work_wslot``; slot reuse
    respects the residual's [B-tick, W-tick] live window exactly like the
    activation stash does, so ``n_wslots`` is the schedule's real deferred-W
    window, not C.

    ``forward_only=True`` lowers an inference/eval timeline (fwd items
    only, validated by ``validate_forward_timeline``): each banked stage
    input is released by its own forward, so the stash collapses to the
    wire-slack window (one slot per device for fill-drain forwards).

    ``wire_latency`` sets the production→arrival distance of every wire
    value (see the module docstring's wire-parity rule); a timeline whose
    wire edges are tighter than the latency raises ``ValueError`` pointing
    at ``retime_timeline``. ``skip_chunks`` drops the named chunks' items
    after validation — the lever for ragged plans whose empty chunks
    contribute exactly-zero gradients — and the all-idle ticks that leaves
    behind (plus any the input timeline already had) are deleted from the
    emitted arrays by a monotone tick remap that preserves every
    producer→arrival distance.
    """
    S, C = num_stages, num_chunks
    if forward_only:
        validate_forward_timeline(items, S, C)
    else:
        validate_timeline(items, S, C)
    if wire_latency < 1:
        raise ValueError(f"wire_latency must be >= 1, got {wire_latency}")

    dev_of: dict[int, int] = {}
    for it in items:
        if dev_of.setdefault(it.stage, it.device) != it.device:
            raise ValueError(f"stage {it.stage} placed on two devices")
    D = max(dev_of.values()) + 1
    for s in range(S - 1):
        if dev_of[s + 1] != (dev_of[s] + 1) % D:
            raise ValueError(
                f"placement is not ring-compatible: stage {s + 1} on device "
                f"{dev_of[s + 1]}, expected {(dev_of[s] + 1) % D} (one hop "
                f"after stage {s} on device {dev_of[s]})"
            )

    skip = set(skip_chunks)
    if skip - set(range(C)):
        raise ValueError(
            f"skip_chunks {sorted(skip)} outside the chunk range 0..{C - 1}"
        )
    if skip:
        items = [it for it in items if it.chunk not in skip]
        if not items:
            raise ValueError("skip_chunks removed every item in the timeline")
    live_chunks = [c for c in range(C) if c not in skip]

    t_f: dict[tuple[int, int], int] = {}
    t_b: dict[tuple[int, int], int] = {}  # input-grad tick: fused bwd or bwd_b
    t_w: dict[tuple[int, int], int] = {}
    for it in items:
        key = (it.stage, it.chunk)
        if it.phase == "fwd":
            t_f[key] = it.tick
        elif it.phase == "bwd_w":
            t_w[key] = it.tick
        else:  # "bwd" | "bwd_b"
            t_b[key] = it.tick

    # forward stash: stage s >= 1's input for chunk c is banked on arrival
    # (wire_latency ticks after fwd(s-1, c) put it on the wire) and freed
    # once the input-grad item — fused bwd or bwd_b — has re-materialized
    # from it (forward-only: freed by its own fwd read)
    f_entries: dict[int, list] = {d: [] for d in range(D)}
    b_entries: dict[int, list] = {d: [] for d in range(D)}
    w_entries: dict[int, list] = {d: [] for d in range(D)}
    for c in live_chunks:
        for s in range(1, S):
            release = t_f[(s, c)] if forward_only else t_b[(s, c)]
            arrival = t_f[(s - 1, c)] + wire_latency
            if arrival > t_f[(s, c)]:
                raise ValueError(
                    f"fwd({s}, {c}) at tick {t_f[(s, c)]} reads a wire value "
                    f"arriving at tick {arrival} (wire_latency="
                    f"{wire_latency}); retime the timeline first "
                    f"(retime_timeline)"
                )
            f_entries[dev_of[s]].append((arrival, release, (s, c)))
        if not forward_only:
            for s in range(S - 1):
                # cotangent of stage s's output: produced by the input-grad
                # item of (s+1, c), read (and freed) by that of (s, c)
                arrival = t_b[(s + 1, c)] + wire_latency
                if arrival > t_b[(s, c)]:
                    raise ValueError(
                        f"bwd({s}, {c}) at tick {t_b[(s, c)]} reads a wire "
                        f"value arriving at tick {arrival} (wire_latency="
                        f"{wire_latency}); retime the timeline first "
                        f"(retime_timeline)"
                    )
                b_entries[dev_of[s]].append((arrival, t_b[(s, c)], (s, c)))
            for s in range(S):
                if (s, c) in t_w:
                    # residual written at the B tick, consumed at the W tick
                    w_entries[dev_of[s]].append((t_b[(s, c)], t_w[(s, c)], (s, c)))

    # dead-tick elimination: keep a tick iff some device works it, a wire
    # value is banked at it, or a wire value is in flight across it (for
    # latency L, the L - 1 ticks between production and arrival — deleting
    # one would break the executor's fixed production→arrival distance).
    # The monotone remap therefore keeps every such distance exactly L.
    keep = {it.tick for it in items}
    for entries in (f_entries, b_entries):
        for d in range(D):
            for arrival, _, _ in entries[d]:
                keep.update(range(arrival - wire_latency + 1, arrival + 1))
    remap = {old: new for new, old in enumerate(sorted(keep))}
    T = len(remap)
    items = [dataclasses.replace(it, tick=remap[it.tick]) for it in items]
    for store in (f_entries, b_entries, w_entries):
        for d in range(D):
            store[d] = [(remap[a], remap[r], k) for a, r, k in store[d]]

    f_slot: dict[tuple[int, int], int] = {}
    b_slot: dict[tuple[int, int], int] = {}
    w_slot: dict[tuple[int, int], int] = {}
    n_fslots = n_bslots = n_wslots = 0
    for d in range(D):
        arrivals = {a for a, _, _ in f_entries[d]}
        if len(arrivals) != len(f_entries[d]):
            raise ValueError(f"two forward-wire values arrive at device {d} in one tick")
        arrivals = {a for a, _, _ in b_entries[d]}
        if len(arrivals) != len(b_entries[d]):
            raise ValueError(f"two backward-wire values arrive at device {d} in one tick")
        slots, n = _alloc_slots(f_entries[d])
        f_slot.update(slots)
        n_fslots = max(n_fslots, n)
        slots, n = _alloc_slots(b_entries[d])
        b_slot.update(slots)
        n_bslots = max(n_bslots, n)
        slots, n = _alloc_slots(w_entries[d])
        w_slot.update(slots)
        n_wslots = max(n_wslots, n)

    phase = np.full((T, D), PHASE_IDLE, dtype=np.int32)
    stage = np.zeros((T, D), dtype=np.int32)
    chunk = np.zeros((T, D), dtype=np.int32)
    work_fslot = np.full((T, D), n_fslots, dtype=np.int32)
    in_fslot = np.full((T, D), n_fslots, dtype=np.int32)
    work_bslot = np.full((T, D), n_bslots, dtype=np.int32)
    in_bslot = np.full((T, D), n_bslots, dtype=np.int32)
    work_wslot = np.full((T, D), n_wslots, dtype=np.int32)
    store_wslot = np.full((T, D), n_wslots, dtype=np.int32)

    for it in items:
        key = (it.stage, it.chunk)
        phase[it.tick, it.device] = _PHASE_CODE[it.phase]
        stage[it.tick, it.device] = it.stage
        chunk[it.tick, it.device] = it.chunk
        if it.stage > 0 and it.phase in ("fwd", "bwd", "bwd_b"):
            work_fslot[it.tick, it.device] = f_slot[key]
        if it.phase in ("bwd", "bwd_b") and it.stage < S - 1:
            work_bslot[it.tick, it.device] = b_slot[key]
        if it.phase == "bwd_b":
            store_wslot[it.tick, it.device] = w_slot[key]
        if it.phase == "bwd_w":
            work_wslot[it.tick, it.device] = w_slot[key]
    for d in range(D):
        for arrival, _, (s, c) in f_entries[d]:
            in_fslot[arrival, d] = f_slot[(s, c)]
        for arrival, _, (s, c) in b_entries[d]:
            in_bslot[arrival, d] = b_slot[(s, c)]

    # true peak: max simultaneously banked stage inputs across all devices
    delta = np.zeros(T + 2, dtype=np.int64)
    for d in range(D):
        for arrival, release, _ in f_entries[d]:
            delta[arrival] += 1
            delta[release + 1] -= 1
    peak = int(np.cumsum(delta).max()) if T else 0

    return LoweredTimeline(
        num_stages=S,
        num_chunks=C,
        num_devices=D,
        num_ticks=T,
        phase=phase,
        stage=stage,
        chunk=chunk,
        work_fslot=work_fslot,
        in_fslot=in_fslot,
        work_bslot=work_bslot,
        in_bslot=in_bslot,
        work_wslot=work_wslot,
        store_wslot=store_wslot,
        n_fslots=n_fslots,
        n_bslots=n_bslots,
        n_wslots=n_wslots,
        peak_live_stash=peak,
        wire_latency=wire_latency,
    )


# ------------------------------------------------------- list scheduler --


def _stage_cost_vector(cost, num_stages: int) -> list[float]:
    """Normalize a scalar-or-per-stage cost to a length-S list of floats."""
    if np.ndim(cost) == 0:
        out = [float(cost)] * num_stages
    else:
        out = [float(c) for c in cost]
        if len(out) != num_stages:
            raise ValueError(
                f"per-stage cost vector has {len(out)} entries for "
                f"{num_stages} stages"
            )
    if any(c < 0 for c in out):
        raise ValueError(f"per-stage costs must be >= 0, got {out}")
    return out


def _greedy_timeline(
    num_stages: int,
    num_chunks: int,
    *,
    device_of,
    fwd_window,
    fwd_cost=1.0,
    bwd_cost=1.0,
):
    """Greedy list scheduler over the pipeline DAG.

    Per-stage op streams are FIFO in chunk order (fwds in order, bwds in
    order); dependencies are

        fwd(s, c)  after  fwd(s-1, c)
        bwd(s, c)  after  bwd(s+1, c)        (loss grad at s = S-1: after
                                              fwd(S-1, c))
        fwd(s, c)  after  bwd(s, c - fwd_window(s))   # 1F1B memory window

    The window dependency caps in-flight activations at stage s to
    ``fwd_window(s)``; with window = S - s this greedy ASAP scheduler emits
    exactly the synchronous 1F1B schedule (a window >= C removes the memory
    cap). Backwards win ties so the drain starts as early as possible.
    ``fwd_cost``/``bwd_cost`` may be scalars (balanced partition) or
    per-stage vectors (heterogeneous stage costs). Returns (ops, makespan)
    where ops maps (stage, chunk, phase) -> (start, end) in cost units.
    """
    S, C = num_stages, num_chunks
    fwd_cost = _stage_cost_vector(fwd_cost, S)
    bwd_cost = _stage_cost_vector(bwd_cost, S)
    done: dict[tuple[int, int, str], tuple[float, float]] = {}
    fwd_next = [0] * S
    bwd_next = [0] * S
    free_by_dev: dict[int, float] = {}
    for s in range(S):
        free_by_dev.setdefault(device_of(s), 0.0)

    n_total = 2 * S * C
    while len(done) < n_total:
        best = None
        for s in range(S):
            dev = device_of(s)
            # candidate backward
            c = bwd_next[s]
            if c < C:
                dep = ((S - 1, c, "fwd") if s == S - 1 else (s + 1, c, "bwd"))
                if dep in done:
                    start = max(free_by_dev[dev], done[dep][1])
                    cand = (start, 0, s, c)
                    if best is None or cand < best[0]:
                        best = (cand, s, c, "bwd", dev)
            # candidate forward
            c = fwd_next[s]
            if c < C:
                ready = 0.0
                ok = True
                if s > 0:
                    dep = (s - 1, c, "fwd")
                    if dep not in done:
                        ok = False
                    else:
                        ready = done[dep][1]
                w = fwd_window(s)
                if ok and c - w >= 0:
                    dep = (s, c - w, "bwd")
                    if dep not in done:
                        ok = False
                    else:
                        ready = max(ready, done[dep][1])
                if ok:
                    start = max(free_by_dev[dev], ready)
                    cand = (start, 1, s, c)
                    if best is None or cand < best[0]:
                        best = (cand, s, c, "fwd", dev)
        assert best is not None, "scheduler stalled (dependency cycle?)"
        (start, _, _, _), s, c, phase, dev = best
        cost = fwd_cost[s] if phase == "fwd" else bwd_cost[s]
        done[(s, c, phase)] = (start, start + cost)
        free_by_dev[dev] = start + cost
        if phase == "fwd":
            fwd_next[s] += 1
        else:
            bwd_next[s] += 1

    makespan = max(end for _, end in done.values())
    return done, makespan


def _ordered_timeline(
    streams: dict[int, list[tuple[str, int, int]]],
    num_stages: int,
    *,
    fwd_cost=1.0,
    bwd_cost=1.0,
):
    """ASAP tick assignment for per-device *fixed* op streams.

    ``streams[d]`` is device d's op sequence as (phase, stage, chunk); data
    dependencies are the pipeline DAG (fwd chain, bwd chain, loss at the last
    stage). Each step schedules the earliest-startable stream head. Costs may
    be scalars or per-stage vectors. Returns (ops, makespan) like
    ``_greedy_timeline``."""
    S = num_stages
    fwd_cost = _stage_cost_vector(fwd_cost, S)
    bwd_cost = _stage_cost_vector(bwd_cost, S)
    done: dict[tuple[int, int, str], tuple[float, float]] = {}
    ptr = {d: 0 for d in streams}
    free = {d: 0.0 for d in streams}
    total = sum(len(v) for v in streams.values())
    while len(done) < total:
        best = None
        for d, ops in streams.items():
            if ptr[d] >= len(ops):
                continue
            phase, s, c = ops[ptr[d]]
            if phase == "fwd":
                dep = (s - 1, c, "fwd") if s > 0 else None
            else:
                dep = (S - 1, c, "fwd") if s == S - 1 else (s + 1, c, "bwd")
            if dep is not None and dep not in done:
                continue
            start = max(free[d], done[dep][1] if dep else 0.0)
            cand = (start, d)
            if best is None or cand < best[0]:
                best = (cand, d, phase, s, c)
        assert best is not None, "scheduler stalled: stream order deadlocks"
        (start, _), d, phase, s, c = best
        cost = fwd_cost[s] if phase == "fwd" else bwd_cost[s]
        done[(s, c, phase)] = (start, start + cost)
        free[d] = start + cost
        ptr[d] += 1
    makespan = max(end for _, end in done.values())
    return done, makespan


def _ops_to_items(ops, device_of) -> list[WorkItem]:
    items = [
        WorkItem(int(round(start)), s, c, phase, device_of(s))
        for (s, c, phase), (start, _) in ops.items()
    ]
    return sorted(items, key=_sort_key)


# ---------------------------------------------------------- the classes --


class Schedule(abc.ABC):
    """A pipeline schedule: emits a WorkItem timeline plus its accounting."""

    name: str = "abstract"

    @abc.abstractmethod
    def timeline(self, num_stages: int, num_chunks: int) -> list[WorkItem]:
        """Tick-accurate (unit fwd/bwd cost) timeline, sorted canonically."""

    def num_devices(self, num_stages: int) -> int:
        """Physical devices the schedule places ``num_stages`` stages on."""
        return num_stages

    def device_of(self, stage: int, num_stages: int) -> int:
        """Physical device hosting ``stage`` (identity by default)."""
        return stage

    def ticks(self, num_stages: int, num_chunks: int) -> int:
        """Makespan of the unit-cost timeline in ticks."""
        return max(it.tick for it in self.timeline(num_stages, num_chunks)) + 1

    def bubble_fraction(self, num_stages: int, num_chunks: int) -> float:
        """Idle fraction across devices for the unit-cost timeline: the
        timeline's unit work items (2·S·C fused, 3·S·C split-backward) out
        of D·T tick-slots."""
        tl = self.timeline(num_stages, num_chunks)
        T = max(it.tick for it in tl) + 1
        D = self.num_devices(num_stages)
        return 1.0 - len(tl) / (D * T)

    def peak_live_activations(self, num_stages: int, num_chunks: int) -> int:
        """Max simultaneously stashed chunk inputs across stages."""
        return peak_live_activations(self.timeline(num_stages, num_chunks))

    def predicted_step_time(
        self,
        num_stages: int,
        num_chunks: int,
        *,
        fwd_cost_per_chunk: float | None = None,
        bwd_cost_per_chunk: float | None = None,
        transfer_cost: float = 0.0,
        rebuild_cost_per_chunk: float = 0.0,
        stage_fwd_costs=None,
        stage_bwd_costs=None,
    ) -> float:
        """Analytic step time: the makespan of the schedule's DAG under
        per-stage per-chunk costs, plus the paper's host-side rebuild term.

        Costs come either from the balanced-partition model —
        ``fwd_cost_per_chunk / num_stages`` (+ transfer) per stage, the
        paper's Fig 3 assumption — or, when ``stage_fwd_costs`` /
        ``stage_bwd_costs`` are given, from an explicit per-stage cost
        vector (e.g. the profiler's measured stage sums): real GNN stacks
        are heterogeneous, the slowest stage sets the tick, and the
        balanced model silently diverges from measurement there."""
        f = self._stage_vec(
            stage_fwd_costs, fwd_cost_per_chunk, num_stages, transfer_cost, "fwd"
        )
        b = self._stage_vec(
            stage_bwd_costs, bwd_cost_per_chunk, num_stages, transfer_cost, "bwd"
        )
        _, makespan = self._weighted(num_stages, num_chunks, f, b)
        return makespan + num_chunks * rebuild_cost_per_chunk

    @staticmethod
    def _stage_vec(stage_costs, cost_per_chunk, S, transfer_cost, what):
        if stage_costs is None:
            if cost_per_chunk is None:
                raise ValueError(
                    f"need {what}_cost_per_chunk or stage_{what}_costs"
                )
            stage_costs = cost_per_chunk / S
        return [c + transfer_cost for c in _stage_cost_vector(stage_costs, S)]

    def _weighted(self, S, C, f, b):
        raise NotImplementedError

    def describe(self, num_stages: int, num_chunks: int) -> dict:
        """Name + derived stats bundle for logs and benchmark tables."""
        return {
            "schedule": self.name,
            "num_stages": num_stages,
            "num_chunks": num_chunks,
            "num_devices": self.num_devices(num_stages),
            "ticks": self.ticks(num_stages, num_chunks),
            "bubble_fraction": self.bubble_fraction(num_stages, num_chunks),
            "peak_live_activations": self.peak_live_activations(num_stages, num_chunks),
        }


class FillDrainSchedule(Schedule):
    """GPipe: C+S-1 forward ticks, then C+S-1 backward ticks (the paper)."""

    name = "fill_drain"

    def timeline(self, num_stages: int, num_chunks: int) -> list[WorkItem]:
        """All-forwards wave then all-backwards wave (GPipe's order)."""
        S, C = num_stages, num_chunks
        items: list[WorkItem] = []
        # forward: stage s handles chunk c at tick c + s
        for t in range(C + S - 1):
            for s in range(S):
                c = t - s
                if 0 <= c < C:
                    items.append(WorkItem(t, s, c, "fwd"))
        off = C + S - 1
        # backward: reverse stage order; stage s handles chunk c at tick
        # off + (C - 1 - c) + (S - 1 - s)
        for t in range(C + S - 1):
            for s in range(S):
                c = (C - 1) - (t - (S - 1 - s))
                if 0 <= c < C:
                    items.append(WorkItem(off + t, s, c, "bwd"))
        return sorted(items, key=_sort_key)

    def ticks(self, num_stages: int, num_chunks: int) -> int:
        """Closed form: 2 (C + S - 1)."""
        return 2 * (num_chunks + num_stages - 1)

    def bubble_fraction(self, num_stages: int, num_chunks: int) -> float:
        """GPipe's (S - 1) / (C + S - 1)."""
        return (num_stages - 1) / (num_chunks + num_stages - 1)

    def peak_live_activations(self, num_stages: int, num_chunks: int) -> int:
        """S * C: every stage holds all C inputs when the forward ends."""
        return num_stages * num_chunks

    def predicted_step_time(
        self,
        num_stages: int,
        num_chunks: int,
        *,
        fwd_cost_per_chunk: float | None = None,
        bwd_cost_per_chunk: float | None = None,
        transfer_cost: float = 0.0,
        rebuild_cost_per_chunk: float = 0.0,
        stage_fwd_costs=None,
        stage_bwd_costs=None,
    ) -> float:
        """Closed-form fill-drain makespan for uniform stages; falls back
        to the generic weighted makespan when per-stage costs differ."""
        if stage_fwd_costs is not None or stage_bwd_costs is not None:
            # heterogeneous stages: no closed form — the generic weighted
            # makespan over fill-drain's fixed per-device op streams
            return super().predicted_step_time(
                num_stages,
                num_chunks,
                fwd_cost_per_chunk=fwd_cost_per_chunk,
                bwd_cost_per_chunk=bwd_cost_per_chunk,
                transfer_cost=transfer_cost,
                rebuild_cost_per_chunk=rebuild_cost_per_chunk,
                stage_fwd_costs=stage_fwd_costs,
                stage_bwd_costs=stage_bwd_costs,
            )
        if fwd_cost_per_chunk is None or bwd_cost_per_chunk is None:
            raise ValueError("need fwd/bwd_cost_per_chunk or stage_fwd/bwd_costs")
        # closed form (the paper's model): critical path is C + S - 1 ticks
        # in each phase
        f = fwd_cost_per_chunk / num_stages + transfer_cost
        b = bwd_cost_per_chunk / num_stages + transfer_cost
        ticks = num_chunks + num_stages - 1
        return ticks * (f + b) + num_chunks * rebuild_cost_per_chunk

    def _weighted(self, S, C, f, b):
        # fill-drain's per-device streams: all C forwards in chunk order,
        # then all C backwards in drain (descending-chunk) order
        streams = {
            s: [("fwd", s, c) for c in range(C)]
            + [("bwd", s, c) for c in reversed(range(C))]
            for s in range(S)
        }
        return _ordered_timeline(streams, S, fwd_cost=f, bwd_cost=b)


class OneFOneBSchedule(Schedule):
    """Synchronous 1F1B (PipeDream-flush): stage s runs min(S-s, C) warmup
    forwards then strictly alternates bwd/fwd, capping live activations at
    min(S-s, C) instead of C. Same optimizer semantics as fill-drain (one
    flush per step); same bubble for equal tick costs; far less memory."""

    name = "1f1b"

    def _ops(self, S, C, f=1.0, b=1.0):
        return _greedy_timeline(
            S, C, device_of=lambda s: s, fwd_window=lambda s: S - s,
            fwd_cost=f, bwd_cost=b,
        )

    def timeline(self, num_stages: int, num_chunks: int) -> list[WorkItem]:
        """1F1B order: warmup forwards, strict alternation, drain."""
        ops, _ = self._ops(num_stages, num_chunks)
        return _ops_to_items(ops, lambda s: s)

    def _weighted(self, S, C, f, b):
        return self._ops(S, C, f, b)


class InterleavedSchedule(Schedule):
    """Interleaved 1F1B over virtual stages (Megatron-LM's interleaving):
    ``num_physical`` devices each host V = S/num_physical virtual stages,
    stage k on device k mod num_physical; activations hop circularly. Each
    device runs (D-d-1)·2 + (V-1)·D warmup forwards in D-microbatch groups
    round-robinned over its virtual stages, then strict 1F1B, then drains.
    Requires C >= D and C % D == 0 (Megatron's constraint) for a stall-free
    steady state; the unit-cost makespan is then exactly 2·(V·C + D - 1)
    ticks — bubble (D-1)/(V·C+D-1), the fill-drain bubble divided by ~V —
    while holding far fewer live activations than interleaved fill-drain."""

    name = "interleaved"

    def __init__(self, num_physical: int):
        if num_physical < 1:
            raise ValueError(f"num_physical must be >= 1, got {num_physical}")
        self.num_physical = num_physical

    def num_devices(self, num_stages: int) -> int:
        """The configured physical-device count (V stages share each)."""
        return self.num_physical

    def device_of(self, stage: int, num_stages: int) -> int:
        """Round-robin: virtual stage k lives on device k mod D."""
        return stage % self.num_physical

    def _check(self, S, C):
        D = self.num_physical
        if S % D != 0:
            raise ValueError(
                f"interleaved schedule needs num_stages ({S}) divisible by "
                f"num_physical devices ({D})"
            )
        if C < D or C % D != 0:
            raise ValueError(
                f"interleaved schedule needs num_chunks ({C}) a positive "
                f"multiple of num devices ({D})"
            )

    def _streams(self, S, C):
        """Per-device op sequences: microbatches advance in groups of D;
        within a group the device cycles its V virtual stages (fwd ascending,
        bwd descending), giving Megatron's interleaved order."""
        D = self.num_physical
        V = S // D
        n = C * V  # fwd (and bwd) ops per device
        streams: dict[int, list[tuple[str, int, int]]] = {}
        for d in range(D):
            seq_f = []
            seq_b = []
            for i in range(n):
                vf = (i // D) % V
                mb = (i // (D * V)) * D + (i % D)
                seq_f.append(("fwd", vf * D + d, mb))
                vb = V - 1 - vf
                seq_b.append(("bwd", vb * D + d, mb))
            warm = min((D - d - 1) * 2 + (V - 1) * D, n)
            ops = list(seq_f[:warm])
            for k in range(n - warm):
                ops.append(seq_f[warm + k])
                ops.append(seq_b[k])
            ops.extend(seq_b[n - warm:])
            streams[d] = ops
        return streams

    def _ops(self, S, C, f=1.0, b=1.0):
        self._check(S, C)
        return _ordered_timeline(self._streams(S, C), S, fwd_cost=f, bwd_cost=b)

    def timeline(self, num_stages: int, num_chunks: int) -> list[WorkItem]:
        """Megatron's interleaved 1F1B over V virtual stages per device."""
        ops, _ = self._ops(num_stages, num_chunks)
        D = self.num_physical
        return _ops_to_items(ops, lambda s: s % D)

    def _weighted(self, S, C, f, b):
        return self._ops(S, C, f, b)


class ZeroBubbleH1Schedule(Schedule):
    """Zero-bubble H1 (Qi et al., 2023): the backward splits into B
    (input-grad — the only half on the inter-stage critical path) and W
    (weight-grad — needed only by the end-of-step optimizer update). The B
    stream keeps 1F1B's ordering and its min(S-s, C) activation window (B
    frees the stage input into the W residual), while W items fill ticks
    their device would otherwise idle through — the drain bubble becomes W
    work. Emitted via a greedy list scheduler with per-device priority
    B > F > W; with unit costs the makespan is 3C + S - 1 ticks for 3C unit
    ops per device, so the bubble drops to (S-1)/(3C+S-1) — strictly below
    1F1B's (S-1)/(C+S-1) whenever there is a bubble at all."""

    name = "zb-h1"

    def _ops(self, S, C, f=1.0, b=1.0, w=1.0):
        done: dict[tuple[int, int, str], tuple[float, float]] = {}
        nxt = {"fwd": [0] * S, "bwd_b": [0] * S, "bwd_w": [0] * S}
        free = {s: 0.0 for s in range(S)}  # device == stage
        cost = {
            "fwd": _stage_cost_vector(f, S),
            "bwd_b": _stage_cost_vector(b, S),
            "bwd_w": _stage_cost_vector(w, S),
        }
        n_total = 3 * S * C
        while len(done) < n_total:
            best = None
            for s in range(S):
                # candidate B (priority 0: the drain's critical path)
                c = nxt["bwd_b"][s]
                if c < C:
                    deps = [(s, c, "fwd")]
                    deps.append((S - 1, c, "fwd") if s == S - 1 else (s + 1, c, "bwd_b"))
                    if all(d in done for d in deps):
                        start = max([free[s]] + [done[d][1] for d in deps])
                        cand = ((start, 0, s, c), s, c, "bwd_b")
                        if best is None or cand[0] < best[0]:
                            best = cand
                # candidate F (priority 1), 1F1B's S - s in-flight window —
                # the window dep is on B: the input-grad half frees the slot
                c = nxt["fwd"][s]
                if c < C:
                    deps = []
                    if s > 0:
                        deps.append((s - 1, c, "fwd"))
                    if c - (S - s) >= 0:
                        deps.append((s, c - (S - s), "bwd_b"))
                    if all(d in done for d in deps):
                        start = max([free[s]] + [done[d][1] for d in deps])
                        cand = ((start, 1, s, c), s, c, "fwd")
                        if best is None or cand[0] < best[0]:
                            best = cand
                # candidate W (priority 2: pure bubble filler)
                c = nxt["bwd_w"][s]
                if c < C and (s, c, "bwd_b") in done:
                    start = max(free[s], done[(s, c, "bwd_b")][1])
                    cand = ((start, 2, s, c), s, c, "bwd_w")
                    if best is None or cand[0] < best[0]:
                        best = cand
            assert best is not None, "zb-h1 scheduler stalled (dependency cycle?)"
            (start, _, _, _), s, c, phase = best
            done[(s, c, phase)] = (start, start + cost[phase][s])
            free[s] = start + cost[phase][s]
            nxt[phase][s] += 1
        makespan = max(end for _, end in done.values())
        return done, makespan

    def timeline(self, num_stages: int, num_chunks: int) -> list[WorkItem]:
        """1F1B's F/B order with every backward split into B then a
        bubble-filling deferred W (zero-bubble H1)."""
        ops, _ = self._ops(num_stages, num_chunks)
        return _ops_to_items(ops, lambda s: s)

    def predicted_step_time(
        self,
        num_stages: int,
        num_chunks: int,
        *,
        fwd_cost_per_chunk: float | None = None,
        bwd_cost_per_chunk: float | None = None,
        transfer_cost: float = 0.0,
        rebuild_cost_per_chunk: float = 0.0,
        stage_fwd_costs=None,
        stage_bwd_costs=None,
        stage_bwd_b_costs=None,
        stage_bwd_w_costs=None,
    ) -> float:
        """Weighted zb-h1 makespan with the B/W split costed separately."""
        # the wire hop belongs to B alone — W consumes a local residual and
        # sends nothing, so it carries no transfer term. The B/W split is
        # the MEASURED one when the caller provides both halves (the
        # profiler does); otherwise the fused backward's compute is assumed
        # to split evenly
        S, C = num_stages, num_chunks
        f = self._stage_vec(
            stage_fwd_costs, fwd_cost_per_chunk, S, transfer_cost, "fwd"
        )
        if stage_bwd_b_costs is not None or stage_bwd_w_costs is not None:
            if stage_bwd_b_costs is None or stage_bwd_w_costs is None:
                raise ValueError(
                    "stage_bwd_b_costs and stage_bwd_w_costs go together"
                )
            b = [c + transfer_cost for c in _stage_cost_vector(stage_bwd_b_costs, S)]
            w = _stage_cost_vector(stage_bwd_w_costs, S)
        else:
            bwd = self._stage_vec(stage_bwd_costs, bwd_cost_per_chunk, S, 0.0, "bwd")
            b = [c * 0.5 + transfer_cost for c in bwd]
            w = [c * 0.5 for c in bwd]
        _, makespan = self._ops(S, C, f, b, w)
        return makespan + C * rebuild_cost_per_chunk


class ZeroBubbleVSchedule(ZeroBubbleH1Schedule):
    """Zero-bubble V (after Qi et al.'s ZB-V shape): zb-h1's split backward
    COMPOSED with interleaving — ``num_physical`` devices each host
    V = S/num_physical virtual stages placed round-robin (stage k on device
    k mod D, the same circular hop ``lower_timeline`` routes for the
    interleaved schedule), and every backward splits into the critical-path
    B half and the bubble-filling deferred W half. Interleaving divides the
    warmup bubble by ~V while the W stream soaks up the drain bubble, so
    both ends of the step shrink at once.

    Note the honest departure from the paper's letter: ZB-V's literal
    placement folds the stage chain back on itself (device d hosts stages d
    and 2D-1-d), which is NOT ring-compatible — the compiled executors route
    exactly one ``ppermute`` ring, and ``lower_timeline`` rejects any
    placement where stage s+1 is not one hop downstream of stage s. The
    round-robin V-stage placement keeps the paper's two bubble levers
    (interleaving + B/W split) inside the ring contract, so zb-v runs
    unmodified through both engines, every ``Placement`` rotation, and the
    double-buffered overlap executors, bit-identical to host fill-drain.

    Scheduling is the same greedy list scheduler as zb-h1 (per-device
    priority B > F > W, 1F1B's S-s in-flight window on the B that frees each
    stage input), with the device free-time shared by all virtual stages a
    device hosts. Requires S % D == 0; unlike interleaved's fixed streams
    the greedy scheduler needs no chunk-count constraint."""

    name = "zb-v"

    def __init__(self, num_physical: int):
        if num_physical < 1:
            raise ValueError(f"num_physical must be >= 1, got {num_physical}")
        self.num_physical = num_physical

    def num_devices(self, num_stages: int) -> int:
        """The configured physical-device count (V stages share each)."""
        return self.num_physical

    def device_of(self, stage: int, num_stages: int) -> int:
        """Round-robin: virtual stage k lives on device k mod D."""
        return stage % self.num_physical

    def _check(self, S):
        D = self.num_physical
        if S % D != 0:
            raise ValueError(
                f"zb-v schedule needs num_stages ({S}) divisible by "
                f"num_physical devices ({D})"
            )

    def _ops(self, S, C, f=1.0, b=1.0, w=1.0):
        self._check(S)
        D = self.num_physical
        dev_of = lambda s: s % D  # noqa: E731
        done: dict[tuple[int, int, str], tuple[float, float]] = {}
        nxt = {"fwd": [0] * S, "bwd_b": [0] * S, "bwd_w": [0] * S}
        free = {d: 0.0 for d in range(D)}  # shared by the device's V stages
        cost = {
            "fwd": _stage_cost_vector(f, S),
            "bwd_b": _stage_cost_vector(b, S),
            "bwd_w": _stage_cost_vector(w, S),
        }
        n_total = 3 * S * C
        while len(done) < n_total:
            best = None
            for s in range(S):
                dev = dev_of(s)
                # candidate B (priority 0: the drain's critical path)
                c = nxt["bwd_b"][s]
                if c < C:
                    deps = [(s, c, "fwd")]
                    deps.append((S - 1, c, "fwd") if s == S - 1 else (s + 1, c, "bwd_b"))
                    if all(d in done for d in deps):
                        start = max([free[dev]] + [done[d][1] for d in deps])
                        cand = ((start, 0, s, c), s, c, "bwd_b")
                        if best is None or cand[0] < best[0]:
                            best = cand
                # candidate F (priority 1), 1F1B's S - s in-flight window on B
                c = nxt["fwd"][s]
                if c < C:
                    deps = []
                    if s > 0:
                        deps.append((s - 1, c, "fwd"))
                    if c - (S - s) >= 0:
                        deps.append((s, c - (S - s), "bwd_b"))
                    if all(d in done for d in deps):
                        start = max([free[dev]] + [done[d][1] for d in deps])
                        cand = ((start, 1, s, c), s, c, "fwd")
                        if best is None or cand[0] < best[0]:
                            best = cand
                # candidate W (priority 2: pure bubble filler)
                c = nxt["bwd_w"][s]
                if c < C and (s, c, "bwd_b") in done:
                    start = max(free[dev], done[(s, c, "bwd_b")][1])
                    cand = ((start, 2, s, c), s, c, "bwd_w")
                    if best is None or cand[0] < best[0]:
                        best = cand
            assert best is not None, "zb-v scheduler stalled (dependency cycle?)"
            (start, _, _, _), s, c, phase = best
            done[(s, c, phase)] = (start, start + cost[phase][s])
            free[dev_of(s)] = start + cost[phase][s]
            nxt[phase][s] += 1
        makespan = max(end for _, end in done.values())
        return done, makespan

    def timeline(self, num_stages: int, num_chunks: int) -> list[WorkItem]:
        """Interleaved round-robin placement with every backward split into
        B then a bubble-filling deferred W (the ring-compatible zb-v)."""
        ops, _ = self._ops(num_stages, num_chunks)
        D = self.num_physical
        return _ops_to_items(ops, lambda s: s % D)


# -------------------------------------------------------------- registry --

SCHEDULES = ("fill_drain", "gpipe", "1f1b", "interleaved", "zb-h1", "zb-v")


def get_schedule(name: str, *, num_devices: int | None = None) -> Schedule:
    """Schedule factory. ``num_devices`` is the physical device count for
    ``interleaved`` and ``zb-v`` (stages are placed round-robin on them);
    other schedules place one stage per device and ignore it."""
    if name in ("fill_drain", "gpipe"):
        return FillDrainSchedule()
    if name == "1f1b":
        return OneFOneBSchedule()
    if name in ("zb-h1", "zb_h1"):
        return ZeroBubbleH1Schedule()
    if name == "interleaved":
        if num_devices is None:
            raise ValueError("interleaved schedule requires num_devices")
        return InterleavedSchedule(num_devices)
    if name in ("zb-v", "zb_v"):
        if num_devices is None:
            raise ValueError("zb-v schedule requires num_devices")
        return ZeroBubbleVSchedule(num_devices)
    raise KeyError(f"unknown schedule {name!r}; valid registry: {SCHEDULES}")


# ------------------------------------------- fill-drain shorthand (paper) --


def fill_drain_timeline(num_stages: int, num_chunks: int) -> list[WorkItem]:
    """The paper's fill-drain timeline (module-level shorthand)."""
    return FillDrainSchedule().timeline(num_stages, num_chunks)


def forward_timeline(num_stages: int, num_chunks: int) -> list[WorkItem]:
    """Inference/eval timeline: the fill-drain forward wave only (stage s
    runs chunk c at tick c + s; C + S - 1 ticks, no backward). Lower with
    ``lower_timeline(..., forward_only=True)`` for the compiled eval path."""
    return [
        it
        for it in FillDrainSchedule().timeline(num_stages, num_chunks)
        if it.phase == "fwd"
    ]


def bubble_fraction(num_stages: int, num_chunks: int) -> float:
    """Idle fraction of the synchronous fill-drain schedule (per GPipe)."""
    return FillDrainSchedule().bubble_fraction(num_stages, num_chunks)


def predicted_step_time(
    num_stages: int,
    num_chunks: int,
    *,
    fwd_cost_per_chunk: float,
    bwd_cost_per_chunk: float,
    transfer_cost: float = 0.0,
    rebuild_cost_per_chunk: float = 0.0,
) -> float:
    """Analytic fill-drain step time with per-chunk stage costs.

    Per-stage per-chunk cost is cost/num_stages (balanced partition);
    the critical path runs (C + S - 1) ticks each phase. The paper's observed
    slowdown is the ``rebuild_cost_per_chunk * C`` term (host-side sub-graph
    rebuilds) dominating at small graph scale."""
    return FillDrainSchedule().predicted_step_time(
        num_stages,
        num_chunks,
        fwd_cost_per_chunk=fwd_cost_per_chunk,
        bwd_cost_per_chunk=bwd_cost_per_chunk,
        transfer_cost=transfer_cost,
        rebuild_cost_per_chunk=rebuild_cost_per_chunk,
    )
