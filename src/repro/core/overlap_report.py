"""Tick-level communication/compute overlap profiler for the pipeline.

The double-buffered wire dataflow (``GPipeConfig.overlap`` — see the
wire-parity rule in ``repro.core.spmd_pipe``) only removes the DATA
dependency that pins each tick's ``ppermute`` pair to the critical path;
whether the runtime actually runs the collective under the neighbouring
compute is XLA's call. This module builds the proof the ISSUE's tentpole
asks for: capture a ``jax.profiler`` trace of one step, attribute per-op
time to collective vs compute, and report the fraction of collective time
that was hidden under same-device compute — the way
``roofline.sparse_stage_report`` turns kernel timings into evidence.

``capture_overlap_report(step_fn)`` is the entry point (fig3's overlap
rows write its dict to ``overlap_report.json``). A traced fraction of ~0
is itself a finding — single-threaded device executors (host-platform CPU
rings) cannot overlap by construction — so ``apply_async_overlap_flags``
offers the documented fallback: best-effort XLA latency-hiding-scheduler
flags, applied through ``XLA_FLAGS`` before the backend initializes
(``--overlap async`` in the CLI).

Everything here is stdlib + jax: the profiler writes gzipped chrome traces
under ``<dir>/plugins/profile/<run>/``, which ``load_trace_events`` parses
directly — no tensorboard/tensorflow dependency.
"""

from __future__ import annotations

import glob
import gzip
import json
import os
import re
import tempfile
import warnings
from typing import Callable

# substrings identifying collective ops in XLA trace event names (HLO names
# like "collective-permute.1", "all-gather-start.2")
COLLECTIVE_MARKERS = (
    "collective-permute",
    "all-gather",
    "all-reduce",
    "all-to-all",
    "reduce-scatter",
)

# XLA_FLAGS requesting the latency-hiding / concurrency-optimized
# schedulers (both accepted by current jaxlib; unknown flags would abort
# backend init, so keep this list to verified spellings)
ASYNC_XLA_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_cpu_enable_concurrency_optimized_scheduler=true",
)


# HLO instruction names as they appear in device lanes: "dot.2", "tanh.1",
# "collective-permute.1". Python-frame events ("$module.py:123 fn"), runtime
# bookkeeping ("ThreadpoolListener::...", "DevicePut", "PjitFunction(step)")
# all fail this shape.
_HLO_NAME = re.compile(r"^[a-z][a-z0-9_.-]*\.\d+$")


def _is_xla_op(name: str) -> bool:
    """True for device-lane HLO-op trace events — filters the profiler's
    Python-frame events and host runtime bookkeeping out of the
    attribution."""
    return bool(_HLO_NAME.match(name))


def _is_collective(name: str) -> bool:
    """True when an XLA op name is one of the ring/mesh collectives."""
    low = name.lower()
    return any(m in low for m in COLLECTIVE_MARKERS)


def load_trace_events(trace_dir: str) -> list:
    """All chrome-trace events the profiler wrote under ``trace_dir``
    (searched recursively for ``*.trace.json.gz``; empty list when the
    profiler produced nothing — callers degrade, not crash)."""
    events: list = []
    for path in sorted(
        glob.glob(os.path.join(trace_dir, "**", "*.trace.json.gz"), recursive=True)
    ):
        try:
            with gzip.open(path, "rt") as f:
                events.extend(json.load(f).get("traceEvents", []))
        except (OSError, ValueError):  # truncated/foreign file: skip it
            continue
    return events


def _union(intervals: list) -> list:
    """Union of (start, end) intervals as a sorted disjoint list."""
    merged: list = []
    for s, e in sorted(intervals):
        if merged and s <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], e))
        else:
            merged.append((s, e))
    return merged


def _intersect_len(intervals: list, union: list) -> float:
    """Total length of ``intervals`` covered by the disjoint ``union``."""
    total = 0.0
    for s, e in intervals:
        for us, ue in union:
            if ue <= s:
                continue
            if us >= e:
                break
            total += min(e, ue) - max(s, us)
    return total


def overlap_from_events(events: list) -> dict:
    """Attribute trace time to collective vs compute ops and measure how
    much collective time ran UNDER same-device compute.

    Events are grouped per (pid, tid) — each device executor is one trace
    thread — because hiding a collective means that device doing its own
    useful work meanwhile; cross-device concurrency is just the pipeline
    running. Only LEAF events count as compute: chrome-trace lanes nest
    control-flow containers (a scan's ``while.N`` spans every tick
    including the collectives inside it) around the real ops, and counting
    a container would report its collectives as 100% hidden under
    themselves. Returns total microseconds per class, the overlapped
    microseconds, and ``overlap_fraction`` (0.0 when no collective ran —
    the gate never divides by zero)."""
    lanes: dict = {}
    for ev in events:
        if ev.get("ph") != "X" or not ev.get("dur"):
            continue
        name = ev.get("name", "")
        if not _is_xla_op(name):
            continue
        lane = lanes.setdefault((ev.get("pid"), ev.get("tid")), [])
        start = float(ev.get("ts", 0.0))
        lane.append((start, start + float(ev["dur"]), _is_collective(name)))

    coll_time = comp_time = hidden = 0.0
    n_coll = n_comp = 0
    for spans in lanes.values():
        # properly nested flame lanes: an event that starts before its
        # successor's start but ends after it contains it — drop such
        # containers, keep leaves
        spans.sort(key=lambda x: (x[0], -x[1]))
        coll, comp = [], []
        for i, (s, e, is_coll) in enumerate(spans):
            is_container = i + 1 < len(spans) and spans[i + 1][0] < e
            if is_coll:
                coll.append((s, e))  # a collective counts even as a parent
            elif not is_container:
                comp.append((s, e))
        n_coll += len(coll)
        n_comp += len(comp)
        coll_union = _union(coll)
        coll_time += sum(e - s for s, e in coll_union)
        comp_time += sum(e - s for s, e in _union(comp))
        hidden += _intersect_len(coll_union, _union(comp))
    return {
        "collective_time_us": coll_time,
        "compute_time_us": comp_time,
        "overlapped_time_us": hidden,
        "overlap_fraction": (hidden / coll_time) if coll_time > 0 else 0.0,
        "num_collective_events": n_coll,
        "num_compute_events": n_comp,
    }


def capture_overlap_report(step_fn: Callable[[], None], *, trace_dir: str | None = None) -> dict:
    """Trace ONE call of ``step_fn`` and return its overlap report.

    ``step_fn`` should run exactly one already-compiled step and block on
    the result (tracing a compile would attribute tracing-time Python to
    the step). The profiler's output stays on disk at ``trace_dir``
    (a fresh temp dir by default) — CI uploads it next to the JSON report.
    If the profiler is unavailable the report carries an ``error`` field
    and zeroed metrics instead of raising: the overlap gate compares step
    times either way."""
    import jax

    out_dir = trace_dir or tempfile.mkdtemp(prefix="overlap_trace_")
    try:
        jax.profiler.start_trace(out_dir)
        try:
            step_fn()
        finally:
            jax.profiler.stop_trace()
    except Exception as exc:  # profiler missing/busy: degrade, don't fail the run
        report = overlap_from_events([])
        report["error"] = f"{type(exc).__name__}: {exc}"
        report["trace_dir"] = out_dir
        return report
    report = overlap_from_events(load_trace_events(out_dir))
    report["trace_dir"] = out_dir
    return report


def apply_async_overlap_flags() -> bool:
    """Best-effort ``--overlap async`` fallback: append the latency-hiding
    scheduler flags to ``XLA_FLAGS`` so the compiler is ASKED to move
    collectives off the critical path even where the double-buffered
    dataflow alone is not enough. Returns True when the flags are in place
    before the backend initialized (they only take effect then); False —
    with a warning — when jax already built its backends, in which case the
    caller keeps the double-buffered dataflow and reports overlap as
    measured."""
    current = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in ASYNC_XLA_FLAGS if f not in current]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join([current] + missing).strip()
    import jax._src.xla_bridge as xb

    if xb.backends_are_initialized():
        warnings.warn(
            "overlap=async: XLA backends already initialized; latency-hiding "
            "flags will not apply to this process — running with the "
            "double-buffered dataflow only",
            RuntimeWarning,
            stacklevel=2,
        )
        return False
    return True
