"""Micro-batching strategies for graph GPipe (paper §6–7 + §8 fixes).

A strategy turns (graph, chunks) into a list of ``MicroBatch`` items, each a
self-contained sub-graph plus a ``core_mask`` selecting the nodes whose loss
contributes. Strategies:

  * ``sequential`` — the paper's behaviour (index split; cross-chunk edges
    silently dropped → Fig 4 accuracy collapse). FAITHFUL BASELINE.
  * ``random``     — permuted index split; same information loss, controls
    for index locality.
  * ``greedy``     — edge-cut-aware partitioner (METIS stand-in); fewer
    edges lost but still lossy. Beyond-paper.
  * ``halo``       — chunks carry their k-hop halo; aggregation exact, so the
    accumulated gradient EQUALS full-batch (property-tested). Beyond-paper
    (the paper's §8 "intelligent graph batching").
  * ``sign``       — SIGN precompute turns the model into an MLP over
    diffused features; chunking is trivially exact. Beyond-paper (§8).

Sub-graph construction cost is charged to ``rebuild_seconds`` so the Fig 3
overhead analogue can be reported honestly.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import partition as P
from repro.graphs.data import GraphBatch, pad_graph, subgraph

STRATEGIES = ("sequential", "random", "greedy", "halo", "sign")


# eq=False on the array-holding containers: the auto-generated __eq__ would
# compare jnp.ndarray fields with bool(a == b) — the ambiguous-truth-value
# error — the first time anything compares two of them (and frozen+eq would
# try to hash the arrays); identity semantics are the contract.
@dataclasses.dataclass(frozen=True, eq=False)
class MicroBatch:
    """One pipeline chunk: a sub-graph plus the mask of rows whose loss
    counts (halo rows ride along for exactness but never contribute)."""

    graph: GraphBatch
    core_mask: jnp.ndarray  # (n_chunk,) — True where loss counts

    @property
    def num_nodes(self) -> int:
        """Node count of this chunk's sub-graph (halo included)."""
        return self.graph.num_nodes


@dataclasses.dataclass(frozen=True, eq=False)
class StackedPlan:
    """A MicroBatchPlan as ONE uniform-shape pytree: every chunk padded to the
    same node count and neighbor width, then stacked on a leading chunk axis.
    This is the layout the compiled SPMD engine feeds to ``lax.scan`` — the
    subgraphs ride the pipeline with the activations."""

    graph: GraphBatch  # leaves (chunks, n_pad, ...)
    core_mask: jnp.ndarray  # (chunks, n_pad) bool
    chunks: int
    n_pad: int  # padded node count per chunk
    max_deg: int  # padded neighbor width per chunk


@dataclasses.dataclass
class MicroBatchPlan:
    """The partitioner's output: the ordered chunk list plus the accounting
    (rebuild cost, edge cut) fig3 reports, with a lazily built stacked
    uniform-shape view for the compiled engine (``stacked()``)."""

    strategy: str
    chunks: int
    batches: list[MicroBatch]
    rebuild_seconds: float  # host-side sub-graph construction cost (Fig 3)
    edge_cut: float  # fraction of edges lost (0 for halo/sign)
    # init=False keeps the cache out of dataclasses.replace(): a replaced
    # plan (new batches) starts with a FRESH empty cache instead of silently
    # carrying a _stacked built from the old batches; compare=False keeps it
    # out of __eq__ for the same staleness reason.
    _stacked: StackedPlan | None = dataclasses.field(
        default=None, repr=False, compare=False, init=False
    )

    def stacked(self) -> StackedPlan:
        """Emit (and cache) the stacked uniform-shape pytree: node counts and
        ``max_deg`` are padded to the per-plan maxima so all chunks share one
        shape and can ride a ``lax.scan``."""
        if self._stacked is None:
            n_pad = max(mb.num_nodes for mb in self.batches)
            max_deg = max(mb.graph.max_degree for mb in self.batches)
            graphs, cores = [], []
            for mb in self.batches:
                graphs.append(pad_graph(mb.graph, n_pad, max_deg))
                pad = n_pad - mb.core_mask.shape[0]
                cores.append(jnp.pad(mb.core_mask, (0, pad)) if pad else mb.core_mask)
            self._stacked = StackedPlan(
                graph=jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *graphs),
                core_mask=jnp.stack(cores),
                chunks=self.chunks,
                n_pad=n_pad,
                max_deg=max_deg,
            )
        return self._stacked


def make_plan(
    g: GraphBatch,
    chunks: int,
    *,
    strategy: str = "sequential",
    halo_hops: int = 2,
    seed: int = 0,
    pad_to_max: bool = True,
) -> MicroBatchPlan:
    """Build the micro-batch plan. ``pad_to_max`` pads every chunk to the
    largest chunk's node count so one jitted step serves all chunks."""
    if strategy not in STRATEGIES:
        raise KeyError(f"unknown strategy {strategy!r}; have {STRATEGIES}")
    if strategy == "sign":
        raise ValueError("sign microbatching is handled by repro.graphs.sign (dense rows)")

    t0 = time.perf_counter()
    if strategy == "sequential":
        parts = P.sequential_partition(g.num_nodes, chunks)
    elif strategy == "random":
        parts = P.random_partition(g.num_nodes, chunks, seed=seed)
    elif strategy == "greedy":
        parts = P.greedy_partition(g, chunks, seed=seed)
    elif strategy == "halo":
        parts = P.sequential_partition(g.num_nodes, chunks)
    else:  # pragma: no cover
        raise AssertionError(strategy)

    batches: list[MicroBatch] = []
    sizes: list[int] = []
    specs: list[tuple[np.ndarray, np.ndarray]] = []
    for part in parts:
        if strategy == "halo":
            nodes, core = P.expand_halo(g, part, halo_hops)
        else:
            nodes, core = part, np.ones(len(part), dtype=bool)
        specs.append((nodes, core))
        sizes.append(len(nodes))

    pad_n = max(sizes) if pad_to_max else None
    for nodes, core in specs:
        if pad_n is not None:
            nodes, core = P.pad_partition(nodes, core, pad_n)
        sub = subgraph(g, nodes)
        # padded duplicates of node 0 must not train/eval either
        batches.append(MicroBatch(graph=sub, core_mask=jnp.asarray(core)))
    rebuild_s = time.perf_counter() - t0

    cut = 0.0 if strategy == "halo" else P.edge_cut_fraction(g, parts)
    return MicroBatchPlan(
        strategy=strategy,
        chunks=chunks,
        batches=batches,
        rebuild_seconds=rebuild_s,
        edge_cut=cut,
    )
