"""JAX version compatibility.

The codebase targets the modern ``jax.shard_map`` API (jax >= 0.6, with vma
tracking from 0.8); older CPU wheels only ship
``jax.experimental.shard_map.shard_map`` whose replication checker predates
vma (``check_rep``) and rejects valid scan/ppermute pipelines. ``shard_map``
here resolves to the native API when present and otherwise falls back to the
experimental one with rep-checking off, so the compiled pipelines run
unchanged on both."""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` when the native API exists, else the experimental
    one with replication checking off (its checker predates vma)."""
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
