# The paper's primary contribution: GPipe-style pipeline parallelism for
# GNNs (and, generalized, for the assigned transformer pool).
from repro.core.microbatch import MicroBatch, MicroBatchPlan, make_plan, STRATEGIES
from repro.core.pipeline import GPipe, GPipeConfig
from repro.core.schedule import fill_drain_timeline, bubble_fraction

__all__ = [
    "MicroBatch",
    "MicroBatchPlan",
    "make_plan",
    "STRATEGIES",
    "GPipe",
    "GPipeConfig",
    "fill_drain_timeline",
    "bubble_fraction",
]
