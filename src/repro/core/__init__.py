"""The paper's primary contribution: GPipe-style pipeline parallelism for
GNNs (and, generalized, for the assigned transformer pool)."""

from repro.core.microbatch import MicroBatch, MicroBatchPlan, make_plan, STRATEGIES
from repro.core.pipeline import GPipe, GPipeConfig
from repro.core.schedule import (
    SCHEDULES,
    Schedule,
    WorkItem,
    bubble_fraction,
    fill_drain_timeline,
    get_schedule,
    validate_timeline,
)

__all__ = [
    "MicroBatch",
    "MicroBatchPlan",
    "make_plan",
    "STRATEGIES",
    "GPipe",
    "GPipeConfig",
    "SCHEDULES",
    "Schedule",
    "WorkItem",
    "get_schedule",
    "validate_timeline",
    "fill_drain_timeline",
    "bubble_fraction",
]
