"""Compiled SPMD pipeline parallelism via shard_map.

This is the production-mesh generalization of the paper's technique: the
host-driven torchgpipe queue schedule becomes a single compiled program —
one `lax.scan` tick per pipeline slot, `lax.ppermute` moving activations
stage→stage over the mesh's ``stage_axis``. Three executors ship:
``spmd_pipeline`` (GPipe fill-drain, one stage per device, AD through the
scan), ``spmd_pipeline_interleaved`` (circular placement, V virtual stages
per device — the bubble shrinks by ~V; see ``repro.core.schedule``), and
``spmd_pipeline_scheduled`` (any validated ``WorkItem`` timeline — 1F1B /
interleaved 1F1B / zero-bubble zb-h1 with its split B/W backward and
deferred-weight-grad residual stash — lowered to static per-tick index
arrays, mixed fwd/bwd ticks with explicit ``jax.vjp`` backward stages and
an activation stash sized to the schedule's live window instead of S·C).
``spmd_pipeline_scheduled_eval`` is the forward-only twin (compiled
inference/eval: no vjp, no gradient buffers); every scheduled executor has
a ``_lanes`` substrate for hosts with fewer devices than the placement.

Contract (everything below happens *inside* shard_map):

  * ``stage_fn(my_in, state_mb) -> (y, state_mb')`` — this device's whole
    stage (its layers_per_stage layers). Parameters/extras are closed over;
    build them with ``make_scanned_stage`` for the homogeneous case or
    hand-roll for heterogeneous stages (e.g. zamba2's 5 mamba slots + 1
    weight-shared attention slot).
  * ``x``: any pytree whose leaves are (num_micro, ...) — this device's data,
    already microbatched. A single array is the LM case; the GNN engine sends
    a whole pytree (activations + padded subgraph + chunk id) so the graph
    travels stage→stage with the activations, and ``y`` must mirror ``x``'s
    structure. Stage 0 consumes microbatch ``t`` at tick ``t``; the last
    stage emits it at tick ``t + S - 1``.
  * ``state``: optional per-microbatch persistent state (KV/SSM caches for
    decode), leaves shaped (num_micro, ...); the pipeline slices microbatch
    ``c`` in, writes the update back, and returns the final state.

GPipe's activation re-materialization is the ``remat`` flag (jax.checkpoint
around the per-tick stage body). Gradients flow through ``ppermute``/scan —
the backward pipeline — and FSDP all-gathers inside ``stage_fn`` transpose
into gradient reduce-scatters (ZeRO-3) automatically.

Scheduled-executor tick contract (see ``LoweredTimeline`` in
``repro.core.schedule`` for the slot-routing fields): every scan tick, on
every device, in this order —

  1. bank the arriving forward wire into activation-stash slot
     ``in_fslot[t, d]`` and the arriving backward wire into cotangent slot
     ``in_bslot[t, d]`` (idle devices bank into the sacrificial slot);
  2. read the tick's stage input from ``work_fslot`` / cotangent from
     ``work_bslot`` / deferred-W residual from ``work_wslot``, run the
     phase's work fn (fwd, fused bwd, or the zb-h1 split: ``bwd_b`` emits
     the upstream cotangent + banks a residual at ``store_wslot``,
     ``bwd_w`` turns a residual into parameter grads);
  3. accumulate grads into the per-(layer, chunk) slot of ``gbuf`` (slot C
     is sacrificial), then ``ppermute`` both wires one ring hop.

Wire-parity rule (``lowered.wire_latency``): with latency 1 (serialized,
the default) each tick's outputs ride the single wire pair issued AFTER the
work and are banked at tick t+1 — the collective sits on the critical path
of every tick. With latency 2 (double-buffered; timelines must be retimed
by ``repro.core.schedule.retime_timeline`` first) each direction holds TWO
buffers of alternating parity — ``wire`` (in flight since tick t-1, banked
now) and ``pending`` (this device's previous outputs, posted onto the ring
BEFORE the tick's work runs). A tick-t output is pending at t+1 and banked
at t+2, so consecutive ticks' transfers occupy opposite buffers and the
``ppermute`` for tick t+1's arrivals overlaps tick t's compute. The lanes
substrate mirrors the same two-buffer dataflow with tuple rotation. This is
pure retiming: banked values, stash traffic and gradient order are
unchanged, so updates stay bit-identical to the serialized path.

Stash sizes are the free-list results ``n_fslots``/``n_bslots``/
``n_wslots`` — the schedule's true live windows, NOT S*C — each +1 for the
sacrificial slot. After the scan, per-chunk gradients reduce in canonical
descending-chunk order (gathered over the optional ``data_axis`` first, in
descending replica order), then ``psum`` over the stage ring — which is why
every schedule, placement, and data-parallel width produces bit-identical
updates.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax


def spmd_pipeline(
    stage_fn: Callable[[Any, Any], tuple[Any, Any]],
    x: Any,
    *,
    stage_axis: str,
    num_stages: int,
    state: Any = None,
    remat: bool = False,
    scatter_dim: int | None = None,
    reduce: str = "psum",
    vma_refs: tuple = (),
):
    """Fill-drain pipeline. Returns (outputs, final_state); ``outputs`` is
    the last stage's per-microbatch output. With ``reduce="psum"`` (default)
    it is psum-broadcast across the stage axis (shaped like ``x``); with
    ``scatter_dim=d`` it is reduce-scattered along that output dim instead —
    cheaper on the wire and it leaves downstream work (LM head, loss)
    sharded over the stage axis instead of redundantly replicated.
    ``reduce="none"`` skips the collective entirely: outputs are zero on
    every stage but the last, so a caller differentiating *inside* the
    pipeline program can compute a local loss and psum only the gradients —
    keeping collectives out of the transposed path."""
    if reduce not in ("psum", "none"):
        raise ValueError(f"reduce must be 'psum' or 'none', got {reduce!r}")
    stage = lax.axis_index(stage_axis)
    is_first = stage == 0
    is_last = stage == num_stages - 1
    tree_map = jax.tree_util.tree_map
    num_micro = jax.tree_util.tree_leaves(x)[0].shape[0]

    fn = stage_fn
    if remat:
        fn = jax.checkpoint(stage_fn)

    def tick_body(body_carry, t):
        prev_in, st = body_carry
        c = t - stage  # microbatch this stage works on at tick t
        mb_idx = jnp.clip(c, 0, num_micro - 1)
        valid = (c >= 0) & (c < num_micro)

        fresh = tree_map(
            lambda a: lax.dynamic_index_in_dim(
                a, jnp.clip(t, 0, num_micro - 1), 0, keepdims=False
            ),
            x,
        )
        my_in = tree_map(lambda f, p: jnp.where(is_first, f, p), fresh, prev_in)

        st_mb = jax.tree_util.tree_map(
            lambda a: lax.dynamic_index_in_dim(a, mb_idx, 0, keepdims=False), st
        )
        y, st_mb_new = fn(my_in, st_mb)
        # fill/drain ticks compute garbage; route their state writes to the
        # sacrificial slot num_micro (slice-sized traffic per tick — a full
        # per-tick jnp.where over the buffer would read+write the whole
        # cache every tick).
        w_idx = jnp.where(valid, mb_idx, num_micro)
        st = jax.tree_util.tree_map(
            lambda a, u: lax.dynamic_update_index_in_dim(a, u, w_idx, 0),
            st,
            st_mb_new,
        )

        nxt = lax.ppermute(
            y, stage_axis, perm=[(i, (i + 1) % num_stages) for i in range(num_stages)]
        )
        # y is emitted as a scan output (ys), NOT carried in an accumulator:
        # a carried buffer would be saved per tick as an AD residual
        # (~ticks × buffer bytes); stacked ys cost one buffer total.
        return (nxt, st), y

    from repro.core.vma import match_vma

    prev0 = match_vma(
        tree_map(lambda a: jnp.zeros_like(a[0]), x), x, vma_refs, extra=(stage_axis,)
    )
    if state is None:
        state = ()
    # append the sacrificial garbage-tick slot (stripped after the scan)
    state = jax.tree_util.tree_map(
        lambda a: jnp.concatenate([a, jnp.zeros_like(a[:1])], axis=0), state
    )
    state = match_vma(state, x, state, vma_refs, extra=(stage_axis,))
    (_, state), ys = lax.scan(
        tick_body,
        (prev0, state),
        jnp.arange(num_micro + num_stages - 1),
    )
    state = jax.tree_util.tree_map(lambda a: a[:num_micro], state)
    # last stage emitted microbatch m at tick m + S - 1; drop the fill ticks
    outputs = tree_map(lambda a: a[num_stages - 1 :], ys)
    outputs = tree_map(lambda a: jnp.where(is_last, a, jnp.zeros_like(a)), outputs)
    if reduce == "none":
        return outputs, state
    if scatter_dim is None:
        outputs = lax.psum(outputs, stage_axis)
    else:
        outputs = tree_map(
            lambda a: lax.psum_scatter(
                a, stage_axis, scatter_dimension=scatter_dim, tiled=True
            ),
            outputs,
        )
    return outputs, state


def spmd_pipeline_interleaved(
    stage_fn: Callable[[jax.Array, Any], Any],
    x: jax.Array,
    *,
    stage_axis: str,
    num_devices: int,
    num_virtual: int,
    remat: bool = False,
    vma_refs: tuple = (),
):
    """Circular/interleaved pipeline: each of the D devices on ``stage_axis``
    hosts V virtual stages placed round-robin (virtual stage k = v·D + d on
    device d = k mod D), so one ``ppermute`` neighbour hop advances the
    model; microbatches circulate the ring V times. Fill is D - 1 ticks out
    of V·C + D - 1 total — the fill-drain bubble divided by ~V — at the cost
    of V smaller weight shards resident per device.

    ``stage_fn(v, h) -> y`` applies this device's v-th virtual stage
    (``v`` is a traced int32 scalar in [0, V); build it with
    ``make_interleaved_stage``). ``x`` is (num_micro, micro_batch, ...) with
    num_micro >= num_devices; outputs (same shape) are the last virtual
    stage's per-microbatch results, psum-broadcast over ``stage_axis``.

    Steady-state routing: device d's tick-t work is microbatch
    c = (t - d) mod C of round v = (t - d) // C. The wire value arriving at
    device d ≥ 1 each tick is exactly its current microbatch; device 0 banks
    arrivals from device D-1 in a C-slot rotating buffer until that
    microbatch's next round comes up (write precedes read inside a tick, so
    C = D also works). Gradients flow through ppermute/scan + the buffer —
    the backward pipeline — exactly as in ``spmd_pipeline``.
    """
    from repro.core.vma import match_vma

    D, V = num_devices, num_virtual
    C = x.shape[0]
    if C < D:
        raise ValueError(f"interleaved pipeline needs num_micro ({C}) >= devices ({D})")
    d = lax.axis_index(stage_axis)
    is_first = d == 0
    is_last = d == D - 1

    fn = jax.checkpoint(stage_fn) if remat else stage_fn

    def tick_body(carry, t):
        prev, buf = carry
        # bank the arriving wire value: it is the sender's tick-(t-1) output,
        # i.e. microbatch (t - 1 - sender) mod C. Garbage fill/drain ticks
        # route to the sacrificial slot C.
        sender = jnp.where(is_first, D - 1, d - 1)
        sender_rel = t - 1 - sender
        in_valid = (sender_rel >= 0) & (sender_rel < V * C)
        w_idx = jnp.where(in_valid, jnp.mod(sender_rel, C), C)
        buf = lax.dynamic_update_index_in_dim(buf, prev, w_idx, 0)

        # this device's work item
        rel = t - d
        c = jnp.mod(rel, C)
        v = jnp.clip(rel // C, 0, V - 1)
        first_round = is_first & (rel < C)
        fresh = lax.dynamic_index_in_dim(x, jnp.clip(c, 0, C - 1), 0, keepdims=False)
        stored = lax.dynamic_index_in_dim(buf, jnp.clip(c, 0, C - 1), 0, keepdims=False)
        my_in = jnp.where(first_round, fresh, stored)
        y = fn(v, my_in)

        nxt = lax.ppermute(
            y, stage_axis, perm=[(i, (i + 1) % D) for i in range(D)]
        )
        return (nxt, buf), y

    prev0 = match_vma(jnp.zeros_like(x[0]), x, vma_refs, extra=(stage_axis,))
    buf0 = match_vma(
        jnp.zeros((C + 1,) + x.shape[1:], x.dtype), x, vma_refs, extra=(stage_axis,)
    )
    T = V * C + D - 1
    (_, _), ys = lax.scan(tick_body, (prev0, buf0), jnp.arange(T))
    # device D-1 runs (v = V-1, chunk c) at tick (V-1)·C + c + D - 1
    outputs = ys[(V - 1) * C + D - 1 :]
    outputs = jnp.where(is_last, outputs, jnp.zeros_like(outputs))
    return lax.psum(outputs, stage_axis)


def spmd_pipeline_scheduled(
    work_fn: Callable[..., tuple],
    lowered,
    *,
    stage_axis: str,
    wire_like: jax.Array,
    grads_like: Any,
    vma_refs: tuple = (),
    data_axis: str | None = None,
):
    """Schedule-aware pipeline executor: runs an arbitrary (validated,
    ring-compatible) ``WorkItem`` timeline — 1F1B, interleaved 1F1B, or any
    mixed fwd/bwd order — as one ``lax.scan`` over ticks inside the compiled
    program, with explicit backward stages instead of AD through the scan.

    ``lowered`` is a ``repro.core.schedule.LoweredTimeline``: static per-tick
    ``(phase, stage, chunk, slot)`` index arrays baked into the program as
    constants; each device reads its column via ``lax.axis_index``. Device
    columns are RING POSITIONS, not physical device ids: a
    ``repro.core.schedule.Placement`` rotates stages around the ring by
    re-devicing the ``WorkItem`` timeline before lowering, and picks which
    physical device occupies which position through the mesh's device order
    — both leave this executor's hop pattern (i -> i + 1 and its transpose)
    untouched, which is exactly why only ring-compatible placements lower.

    ``work_fn(phase, stage, chunk, h_in, ct, w_res) -> (y, d_h, w_out,
    grads, loss_sum, count)`` executes one work item (all six args traced
    scalars/arrays; ``w_res``/``w_out`` are residual PAIRS of wire-shaped
    buffers — the banked stage input and the applied cotangent — stashed as
    two parallel single-wire stashes so no per-tick concat materializes):

      * fwd: ``y`` is the stage output (uniform wire shape); everything else
        must be zeros;
      * bwd (fused): ``d_h`` is the cotangent for the upstream stage's
        output and ``grads`` this item's parameter gradients (full-params
        pytree, zero outside the stage's layers — a ``jax.vjp`` of the stage
        wrt the full params gives exactly that). The LAST stage derives its
        own cotangent from the loss and reports (loss_sum, count); other
        stages consume the banked ``ct`` and report zeros;
      * bwd_b (zero-bubble input-grad half): like bwd but ``grads`` stays
        zero; instead ``w_out`` carries the residual — the banked stage
        input and the applied cotangent — for the matching deferred W item;
      * bwd_w (deferred weight-grad half): consumes ``w_res`` from the
        residual stash, emits only ``grads``;
      * idle: all-zeros.

    Dataflow per tick: bank the two arriving wire values (forward ring hop
    carries activations, its transpose carries cotangents) into the stash
    slots the lowering assigned, read the work item's input/cotangent/
    residual slots, run ``work_fn``, store ``w_out`` into the B item's
    residual slot (``store_wslot`` — no wire hop, B and W share a device),
    accumulate ``grads`` into the item's *per-chunk* slot, and ``ppermute``
    the outputs. Fill/drain garbage routes to sacrificial slots — the same
    trick as ``spmd_pipeline``'s state writes.

    The activation stash holds ``n_fslots`` slots — the schedule's real
    per-device live-activation window (1F1B's min(S-s, C) memory lever),
    not the fill-drain C — and backward runs *explicitly* (no AD through the
    scan), so no per-tick residuals accumulate either. The W residual stash
    (``n_wslots`` slots, empty for fused-backward schedules) is the
    zero-bubble schedule's deferred-W window.

    Gradients are accumulated per chunk and reduced AFTER the scan in the
    canonical descending-chunk order (the fill-drain drain order the host
    engine uses), so every schedule produces a bit-identical update; the
    returned ``(grads, loss_sum, count)`` are psum-replicated over
    ``stage_axis`` (each device contributes exactly its stages' layer
    gradients, zeros elsewhere).

    ``data_axis`` composes the ring with graph data parallelism on a 2-D
    ``(data, stage)`` mesh: each data replica runs this executor over its
    own contiguous shard of the chunks (replica ``r`` owns global chunks
    ``[r*C_local, (r+1)*C_local)``), and the per-chunk gradient buffers are
    ``all_gather``-ed over the axis so the post-scan reduction can walk ALL
    global chunks in the same canonical descending order. Each (layer,
    chunk) gradient is nonzero on exactly one replica and one stage, so the
    gather + ordered sum (and the stage psum after it) only ever add zeros
    to the single real addend — the data axis changes WHERE chunks run,
    never the float associativity of the update.

    ``lowered.wire_latency == 2`` selects the DOUBLE-BUFFERED wire dataflow
    (the module docstring's wire-parity rule): each direction carries a
    (wire, pending) buffer pair — the tick banks ``wire`` (outputs of tick
    t-2), issues the ``ppermute`` of ``pending`` (outputs of tick t-1)
    BEFORE running ``work_fn``, and parks its own outputs as the next
    pending. Nothing downstream of the early ppermute is read by the tick's
    work, so the collective has the whole tick of compute to hide behind;
    the dataflow is a pure retiming — the banked values, stash traffic and
    gradient accumulation order are identical, so updates stay bit-identical
    to the serialized latency-1 executor.
    """
    from repro.core.schedule import PHASE_BWD, PHASE_BWD_W
    from repro.core.vma import match_vma

    C = lowered.num_chunks
    T, D = lowered.num_ticks, lowered.num_devices
    if lowered.wire_latency not in (1, 2):
        raise ValueError(f"unsupported wire_latency {lowered.wire_latency}")
    double = lowered.wire_latency == 2
    d = lax.axis_index(stage_axis)
    tree_map = jax.tree_util.tree_map

    idx = {
        name: jnp.asarray(getattr(lowered, name))
        for name in ("phase", "stage", "chunk", "work_fslot", "in_fslot",
                     "work_bslot", "in_bslot", "work_wslot", "store_wslot")
    }

    def pick(name, t):
        row = lax.dynamic_index_in_dim(idx[name], t, 0, keepdims=False)
        return lax.dynamic_index_in_dim(row, d, 0, keepdims=False)

    zero_wire = jnp.zeros_like(wire_like)
    fstash0 = jnp.zeros((lowered.n_fslots + 1,) + wire_like.shape, wire_like.dtype)
    bstash0 = jnp.zeros((lowered.n_bslots + 1,) + wire_like.shape, wire_like.dtype)
    wstash0 = tuple(
        jnp.zeros((lowered.n_wslots + 1,) + wire_like.shape, wire_like.dtype)
        for _ in range(2)
    )
    gbuf0 = tree_map(lambda p: jnp.zeros((C + 1,) + p.shape, p.dtype), grads_like)
    fwd_perm = [(i, (i + 1) % D) for i in range(D)]
    bwd_perm = [(i, (i - 1) % D) for i in range(D)]

    def tick_body(carry, t):
        wires, fstash, bstash, wstash, gbuf, loss, count = carry
        wire_f, wire_b = wires[0], wires[1]
        # bank arrivals BEFORE the work reads (same-tick deliver-then-consume)
        fstash = lax.dynamic_update_index_in_dim(fstash, wire_f, pick("in_fslot", t), 0)
        bstash = lax.dynamic_update_index_in_dim(bstash, wire_b, pick("in_bslot", t), 0)
        if double:
            # post tick t+1's arrivals (tick t-1's outputs, parked in the
            # pending buffers) before this tick's work: no value below reads
            # next_f/next_b, so XLA may run the collective under the compute
            next_f = lax.ppermute(wires[2], stage_axis, perm=fwd_perm)
            next_b = lax.ppermute(wires[3], stage_axis, perm=bwd_perm)
        h_in = lax.dynamic_index_in_dim(fstash, pick("work_fslot", t), 0, keepdims=False)
        ct_in = lax.dynamic_index_in_dim(bstash, pick("work_bslot", t), 0, keepdims=False)
        # fused-backward schedules allocate no residual slots; skip the
        # wire-sized stash reads/writes entirely on their hot path
        if lowered.n_wslots:
            w_res = tuple(
                lax.dynamic_index_in_dim(w, pick("work_wslot", t), 0, keepdims=False)
                for w in wstash
            )
        else:
            w_res = (zero_wire, zero_wire)
        phase = pick("phase", t)
        y, d_h, w_out, grads, loss_sum, cnt = work_fn(
            phase, pick("stage", t), pick("chunk", t), h_in, ct_in, w_res
        )
        if lowered.n_wslots:
            # a B tick banks its residual for the matching deferred W (the
            # read above precedes this write, so slot reuse inside a tick is
            # safe)
            wstash = tuple(
                lax.dynamic_update_index_in_dim(w, v, pick("store_wslot", t), 0)
                for w, v in zip(wstash, w_out)
            )
        # per-chunk gradient slots (sacrificial slot C on ticks that produce
        # no parameter gradients — fwd, bwd_b, idle): slice-sized read+write
        # per tick, reduced canonically after the scan
        gc = jnp.where((phase == PHASE_BWD) | (phase == PHASE_BWD_W), pick("chunk", t), C)
        gslot = tree_map(
            lambda b: lax.dynamic_index_in_dim(b, gc, 0, keepdims=False), gbuf
        )
        gbuf = tree_map(
            lambda b, acc, g: lax.dynamic_update_index_in_dim(b, acc + g, gc, 0),
            gbuf, gslot, grads,
        )
        if double:
            wires = (next_f, next_b, y, d_h)
        else:
            wires = (
                lax.ppermute(y, stage_axis, perm=fwd_perm),
                lax.ppermute(d_h, stage_axis, perm=bwd_perm),
            )
        return (
            wires, fstash, bstash, wstash, gbuf,
            loss + loss_sum, count + cnt,
        ), None

    carry0 = (
        (zero_wire,) * (4 if double else 2), fstash0, bstash0, wstash0, gbuf0,
        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
    )
    carry0 = match_vma(carry0, grads_like, vma_refs, extra=(stage_axis,))
    (_, _, _, _, gbuf, loss, count), _ = lax.scan(tick_body, carry0, jnp.arange(T))

    # canonical reduction: per layer, chunks in DESCENDING order — the host
    # engine's fill-drain drain order — so floats accumulate identically no
    # matter which schedule produced the per-chunk gradients
    grads = tree_map(lambda b: jnp.zeros(b.shape[1:], b.dtype), gbuf)
    if data_axis is None:
        for c in reversed(range(C)):
            grads = tree_map(lambda g, b, c=c: g + b[c], grads, gbuf)
    else:
        # gather every replica's per-chunk slots (leaves (dp, C+1, ...)) and
        # reduce over GLOBAL chunks in the same descending order a single
        # replica would use: global chunk r*C + c descends as (r, c) descends
        # lexicographically. Exact, not just close — see the docstring.
        gall = tree_map(lambda b: lax.all_gather(b, data_axis), gbuf)
        dp = jax.tree_util.tree_leaves(gall)[0].shape[0]
        for r in reversed(range(dp)):
            for c in reversed(range(C)):
                grads = tree_map(lambda g, b, r=r, c=c: g + b[r, c], grads, gall)
        loss = jnp.sum(lax.all_gather(loss, data_axis))
        count = jnp.sum(lax.all_gather(count, data_axis))
    grads = lax.psum(grads, stage_axis)
    loss = lax.psum(loss, stage_axis)
    count = lax.psum(count, stage_axis)
    return grads, loss, count


def spmd_pipeline_scheduled_lanes(
    work_fn: Callable[..., tuple],
    lowered,
    *,
    wire_like: jax.Array,
    grads_like: Any,
):
    """Sub-device-count substrate of ``spmd_pipeline_scheduled``: the same
    per-tick dataflow with the device ring as per-LANE carries inside one
    program — ``ppermute`` becomes a static rotation of the lane tuple,
    psum a plain sum.

    The lane loop is a static Python loop, so each lane's ``lax.switch``
    dispatch stays a real XLA conditional executing ONE branch per tick.
    (Emulating the ring with ``vmap(axis_name=...)`` instead would batch the
    switch predicate and compute every branch in every lane — a ~(2S+1)×
    FLOP blow-up; this substrate does D single-branch dispatches per tick,
    the ring's aggregate work executed sequentially.) Numerics are identical
    to the shard_map substrate: same banking, same canonical descending-chunk
    gradient reduction — per (layer, chunk) slot exactly one lane ever
    contributes, so the shared gradient buffer accumulates the same floats
    the psum would.

    ``lowered.wire_latency == 2`` mirrors the double-buffered wire dataflow
    (module docstring wire-parity rule) with tuple rotation: the tick banks
    the in-flight ``wire`` tuples, rotates the ``pending`` tuples into the
    next wires, and parks its own lane outputs as pending — outputs reach
    the neighbour lane's stash exactly two ticks after production, matching
    the retimed index arrays and the shard_map substrate bit-for-bit."""
    from repro.core.schedule import PHASE_BWD, PHASE_BWD_W

    C = lowered.num_chunks
    T, D = lowered.num_ticks, lowered.num_devices
    if lowered.wire_latency not in (1, 2):
        raise ValueError(f"unsupported wire_latency {lowered.wire_latency}")
    double = lowered.wire_latency == 2
    tree_map = jax.tree_util.tree_map

    idx = {
        name: jnp.asarray(getattr(lowered, name))
        for name in ("phase", "stage", "chunk", "work_fslot", "in_fslot",
                     "work_bslot", "in_bslot", "work_wslot", "store_wslot")
    }

    def pick(name, t, d):  # d is a static lane index
        row = lax.dynamic_index_in_dim(idx[name], t, 0, keepdims=False)
        return row[d]

    # per-LANE stash tuples, not one (D, ...) stacked array: a stacked stash
    # would need a chained ``.at[d].set`` per lane per tick, which XLA
    # materializes as whole-stash copies — measured 1.6x step time on the
    # zb-h1 residual stash. Tuple carries keep every lane's update a single
    # in-place dynamic-update-slice.
    zero_wire = jnp.zeros_like(wire_like)
    wires0 = (zero_wire,) * D
    fstash0 = tuple(
        jnp.zeros((lowered.n_fslots + 1,) + wire_like.shape, wire_like.dtype)
        for _ in range(D)
    )
    bstash0 = tuple(
        jnp.zeros((lowered.n_bslots + 1,) + wire_like.shape, wire_like.dtype)
        for _ in range(D)
    )
    wstash0 = tuple(
        tuple(
            jnp.zeros((lowered.n_wslots + 1,) + wire_like.shape, wire_like.dtype)
            for _ in range(2)
        )
        for _ in range(D)
    )
    gbuf0 = tree_map(lambda p: jnp.zeros((C + 1,) + p.shape, p.dtype), grads_like)

    def tick_body(carry, t):
        wires, fstash, bstash, wstash, gbuf, loss, count = carry
        wire_f, wire_b = wires[0], wires[1]
        fstash, bstash, wstash = list(fstash), list(bstash), list(wstash)
        ys, dhs = [], []
        for d in range(D):  # static: one single-branch dispatch per lane
            fstash[d] = lax.dynamic_update_index_in_dim(
                fstash[d], wire_f[d], pick("in_fslot", t, d), 0
            )
            bstash[d] = lax.dynamic_update_index_in_dim(
                bstash[d], wire_b[d], pick("in_bslot", t, d), 0
            )
            h_in = lax.dynamic_index_in_dim(
                fstash[d], pick("work_fslot", t, d), 0, keepdims=False
            )
            ct_in = lax.dynamic_index_in_dim(
                bstash[d], pick("work_bslot", t, d), 0, keepdims=False
            )
            if lowered.n_wslots:
                w_res = tuple(
                    lax.dynamic_index_in_dim(
                        w, pick("work_wslot", t, d), 0, keepdims=False
                    )
                    for w in wstash[d]
                )
            else:  # fused-backward schedule: no residual traffic at all
                w_res = (zero_wire, zero_wire)
            phase = pick("phase", t, d)
            y, d_h, w_out, grads, loss_sum, cnt = work_fn(
                phase, pick("stage", t, d), pick("chunk", t, d), h_in, ct_in, w_res
            )
            if lowered.n_wslots:
                wstash[d] = tuple(
                    lax.dynamic_update_index_in_dim(
                        w, v, pick("store_wslot", t, d), 0
                    )
                    for w, v in zip(wstash[d], w_out)
                )
            gc = jnp.where(
                (phase == PHASE_BWD) | (phase == PHASE_BWD_W), pick("chunk", t, d), C
            )
            gslot = tree_map(
                lambda b: lax.dynamic_index_in_dim(b, gc, 0, keepdims=False), gbuf
            )
            gbuf = tree_map(
                lambda b, acc, g: lax.dynamic_update_index_in_dim(b, acc + g, gc, 0),
                gbuf, gslot, grads,
            )
            loss, count = loss + loss_sum, count + cnt
            ys.append(y)
            dhs.append(d_h)
        if double:
            # rotate last tick's parked outputs into the in-flight wires and
            # park this tick's outputs: two-tick producer→stash delay, the
            # lane image of the early-posted ppermute pair
            wires = (
                tuple(wires[2][(d - 1) % D] for d in range(D)),
                tuple(wires[3][(d + 1) % D] for d in range(D)),
                tuple(ys), tuple(dhs),
            )
        else:
            # the ring hops: lane d's activation to lane d+1, cotangent to d-1
            wires = (
                tuple(ys[(d - 1) % D] for d in range(D)),
                tuple(dhs[(d + 1) % D] for d in range(D)),
            )
        return (
            wires, tuple(fstash), tuple(bstash), tuple(wstash),
            gbuf, loss, count,
        ), None

    carry0 = (
        (wires0,) * (4 if double else 2), fstash0, bstash0, wstash0, gbuf0,
        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
    )
    (_, _, _, _, gbuf, loss, count), _ = lax.scan(tick_body, carry0, jnp.arange(T))
    grads = tree_map(lambda b: jnp.zeros(b.shape[1:], b.dtype), gbuf)
    for c in reversed(range(C)):  # canonical: the fill-drain drain order
        grads = tree_map(lambda g, b, c=c: g + b[c], grads, gbuf)
    return grads, loss, count


def _eval_out_slot(lowered):
    """Per-tick output-buffer slot: last-stage forward ticks write their
    chunk's result, everything else routes to the sacrificial slot C."""
    import numpy as np

    from repro.core.schedule import PHASE_FWD

    last = (lowered.phase == PHASE_FWD) & (lowered.stage == lowered.num_stages - 1)
    return np.where(last, lowered.chunk, lowered.num_chunks).astype(np.int32)


def spmd_pipeline_scheduled_eval(
    work_fn: Callable[..., jax.Array],
    lowered,
    *,
    stage_axis: str,
    wire_like: jax.Array,
    vma_refs: tuple = (),
):
    """Forward-only twin of ``spmd_pipeline_scheduled`` — the compiled
    eval/inference path. Runs a ``forward_only`` ``LoweredTimeline`` (see
    ``repro.core.schedule.forward_timeline``): no vjp, no cotangent wire, no
    gradient buffers — just the activation ring, a stash collapsed to the
    wire-slack window (one slot for fill-drain forwards), and an output
    buffer collecting the LAST stage's per-chunk results.

    ``work_fn(phase, stage, chunk, h_in) -> y`` runs one forward item (idle
    ticks must return zeros). Returns the (num_chunks, *wire) outputs,
    psum-replicated over ``stage_axis`` (exactly one device writes each
    chunk — the one hosting the last stage)."""
    from repro.core.vma import match_vma

    C = lowered.num_chunks
    T, D = lowered.num_ticks, lowered.num_devices
    d = lax.axis_index(stage_axis)

    idx = {
        name: jnp.asarray(getattr(lowered, name))
        for name in ("phase", "stage", "chunk", "work_fslot", "in_fslot")
    }
    idx["out_slot"] = jnp.asarray(_eval_out_slot(lowered))

    def pick(name, t):
        row = lax.dynamic_index_in_dim(idx[name], t, 0, keepdims=False)
        return lax.dynamic_index_in_dim(row, d, 0, keepdims=False)

    zero_wire = jnp.zeros_like(wire_like)
    fstash0 = jnp.zeros((lowered.n_fslots + 1,) + wire_like.shape, wire_like.dtype)
    out0 = jnp.zeros((C + 1,) + wire_like.shape, wire_like.dtype)
    fwd_perm = [(i, (i + 1) % D) for i in range(D)]

    def tick_body(carry, t):
        wire_f, fstash, out = carry
        fstash = lax.dynamic_update_index_in_dim(fstash, wire_f, pick("in_fslot", t), 0)
        h_in = lax.dynamic_index_in_dim(fstash, pick("work_fslot", t), 0, keepdims=False)
        y = work_fn(pick("phase", t), pick("stage", t), pick("chunk", t), h_in)
        out = lax.dynamic_update_index_in_dim(out, y, pick("out_slot", t), 0)
        wire_f = lax.ppermute(y, stage_axis, perm=fwd_perm)
        return (wire_f, fstash, out), None

    carry0 = match_vma((zero_wire, fstash0, out0), vma_refs, extra=(stage_axis,))
    (_, _, out), _ = lax.scan(tick_body, carry0, jnp.arange(T))
    return lax.psum(out[:C], stage_axis)


def spmd_pipeline_scheduled_eval_lanes(
    work_fn: Callable[..., jax.Array],
    lowered,
    *,
    wire_like: jax.Array,
):
    """Sub-device-count substrate of ``spmd_pipeline_scheduled_eval``: the
    ring as a static lane loop inside one program (same trade-offs as
    ``spmd_pipeline_scheduled_lanes`` — every ``lax.switch`` stays a
    single-branch conditional). The output buffer is shared across lanes;
    only the last-stage lane ever writes a real slot."""
    C = lowered.num_chunks
    T, D = lowered.num_ticks, lowered.num_devices

    idx = {
        name: jnp.asarray(getattr(lowered, name))
        for name in ("phase", "stage", "chunk", "work_fslot", "in_fslot")
    }
    idx["out_slot"] = jnp.asarray(_eval_out_slot(lowered))

    def pick(name, t, d):
        row = lax.dynamic_index_in_dim(idx[name], t, 0, keepdims=False)
        return row[d]

    zero_wire = jnp.zeros_like(wire_like)
    wires0 = (zero_wire,) * D
    fstash0 = tuple(
        jnp.zeros((lowered.n_fslots + 1,) + wire_like.shape, wire_like.dtype)
        for _ in range(D)
    )
    out0 = jnp.zeros((C + 1,) + wire_like.shape, wire_like.dtype)

    def tick_body(carry, t):
        wire_f, fstash, out = carry
        fstash = list(fstash)
        ys = []
        for d in range(D):
            fstash[d] = lax.dynamic_update_index_in_dim(
                fstash[d], wire_f[d], pick("in_fslot", t, d), 0
            )
            h_in = lax.dynamic_index_in_dim(
                fstash[d], pick("work_fslot", t, d), 0, keepdims=False
            )
            y = work_fn(pick("phase", t, d), pick("stage", t, d), pick("chunk", t, d), h_in)
            out = lax.dynamic_update_index_in_dim(out, y, pick("out_slot", t, d), 0)
            ys.append(y)
        wire_f = tuple(ys[(d - 1) % D] for d in range(D))
        return (wire_f, tuple(fstash), out), None

    (_, _, out), _ = lax.scan(tick_body, (wires0, fstash0, out0), jnp.arange(T))
    return out[:C]


# --------------------------------------------------- homogeneous helpers --


def make_gather_fn(gather_mask: Any, axis_name: str) -> Callable[[Any], Any]:
    """ZeRO-3 gather: all-gather each leaf whose (static, same-structure)
    ``gather_mask`` entry is True along its first dim. AD transposes the
    gather into a gradient reduce-scatter."""
    flat_mask = jax.tree_util.tree_leaves(
        gather_mask, is_leaf=lambda x: isinstance(x, bool)
    )

    def gather(params: Any) -> Any:
        flat, treedef = jax.tree_util.tree_flatten(params)
        assert len(flat) == len(flat_mask), (len(flat), len(flat_mask))
        out = [
            lax.all_gather(leaf, axis_name, axis=0, tiled=True) if m else leaf
            for leaf, m in zip(flat, flat_mask)
        ]
        return jax.tree_util.tree_unflatten(treedef, out)

    return gather


def make_scanned_stage(
    block_fn: Callable[[Any, Any, Any], Any],
    params_local: Any,  # leaves (layers_per_stage, ...)
    extras_local: Any,
    *,
    gather_fn: Callable[[Any], Any] | None = None,
) -> Callable:
    """Homogeneous stateless stage: scan ``block_fn`` over this stage's
    layers. ``block_fn(layer_params, layer_extras, h) -> h``."""

    def stage_fn(h, state_mb):
        from repro.core.vma import match_vma

        def one_layer(c, xs):
            lp, ex = xs
            if gather_fn is not None:
                lp = gather_fn(lp)
            return block_fn(lp, ex, c), None

        # params may vary over more mesh axes than h (e.g. fsdp gathers);
        # the layer-scan carry must match the body output's vma
        h = match_vma(h, params_local, extras_local, h)
        h, _ = lax.scan(one_layer, h, (params_local, extras_local))
        return h, state_mb

    return stage_fn


def make_interleaved_stage(
    block_fn: Callable[[Any, Any, Any], Any],
    params_local: Any,  # leaves (num_virtual, layers_per_stage, ...)
    extras_local: Any,
    *,
    gather_fn: Callable[[Any], Any] | None = None,
) -> Callable:
    """Homogeneous interleaved stage for ``spmd_pipeline_interleaved``:
    selects this device's v-th virtual-stage slice, then scans ``block_fn``
    over its layers_per_stage layers."""

    def stage_fn(v, h):
        from repro.core.vma import match_vma

        pv = jax.tree_util.tree_map(
            lambda a: lax.dynamic_index_in_dim(a, v, 0, keepdims=False), params_local
        )
        ev = jax.tree_util.tree_map(
            lambda a: lax.dynamic_index_in_dim(a, v, 0, keepdims=False), extras_local
        )

        def one_layer(c, xs):
            lp, ex = xs
            if gather_fn is not None:
                lp = gather_fn(lp)
            return block_fn(lp, ex, c), None

        h = match_vma(h, pv, ev, h)
        h, _ = lax.scan(one_layer, h, (pv, ev))
        return h

    return stage_fn


def make_scanned_stage_stateful(
    block_fn: Callable[[Any, Any, Any, Any], tuple[Any, Any]],
    params_local: Any,
    extras_local: Any,
    *,
    gather_fn: Callable[[Any], Any] | None = None,
) -> Callable:
    """Homogeneous stateful stage (decode/prefill-cache): state_mb leaves are
    (layers_per_stage, ...) and ride the layer scan as xs/ys.
    ``block_fn(layer_params, layer_extras, h, cache_i) -> (h, cache_i')``."""

    def stage_fn(h, state_mb):
        from repro.core.vma import match_vma

        def one_layer(c, xs):
            lp, ex, cache_i = xs
            if gather_fn is not None:
                lp = gather_fn(lp)
            c, cache_out = block_fn(lp, ex, c, cache_i)
            return c, cache_out

        h = match_vma(h, params_local, extras_local, state_mb, h)
        h, new_cache = lax.scan(one_layer, h, (params_local, extras_local, state_mb))
        return h, new_cache

    return stage_fn
