"""GPipe for GNNs — the paper's §6 implementation, JAX-native.

Faithful semantics:

  * the sequential model is partitioned into stages by a ``balance`` array
    (same contract as ``torchgpipe.GPipe(model, balance, chunks)``);
  * the input is micro-batched into ``chunks`` (strategy pluggable — the
    paper's index-sequential split is the default and reproduces its
    accuracy collapse);
  * forward runs the synchronous fill-drain schedule; backward re-computes
    each stage's internals from its saved input (GPipe's activation
    re-materialization) and accumulates gradients across micro-batches;
  * a single synchronous optimizer update closes the step, so the number of
    chunks never changes the *intended* gradient — only lossy micro-batching
    of the graph does (measured by ``plan.edge_cut``).

The schedule is driven at Python level with per-stage jitted kernels (and
optional per-stage device placement), mirroring torchgpipe's host-driven
queues; the compiled SPMD pipeline for the production mesh lives in
``repro.core.spmd_pipe``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.microbatch import MicroBatch, MicroBatchPlan
from repro.core.schedule import bubble_fraction
from repro.models.gnn.net import GNNModel
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class GPipeConfig:
    balance: tuple[int, ...]  # layers per stage; sums to len(model.layers)
    chunks: int
    devices: tuple | None = None  # optional per-stage device placement

    @property
    def num_stages(self) -> int:
        return len(self.balance)


class GPipe:
    """Pipeline-parallel wrapper around a sequential ``GNNModel``."""

    def __init__(self, model: GNNModel, config: GPipeConfig):
        if sum(config.balance) != len(model.layers):
            raise ValueError(
                f"balance {config.balance} must sum to {len(model.layers)} layers"
            )
        self.model = model
        self.config = config
        self._bounds: list[tuple[int, int]] = []
        lo = 0
        for b in config.balance:
            self._bounds.append((lo, lo + b))
            lo += b

        self._fwd_fns = [self._make_fwd(s) for s in range(config.num_stages)]
        self._bwd_fns = [self._make_bwd(s) for s in range(config.num_stages)]
        self._loss_grad = jax.jit(jax.value_and_grad(_chunk_loss_sum, argnums=0, has_aux=True))

    # ------------------------------------------------------------ stages --

    def stage_params(self, params: list, s: int) -> list:
        lo, hi = self._bounds[s]
        return params[lo:hi]

    def _stage_apply(self, s: int, stage_params: list, mb_graph, h, rngs, train: bool):
        lo, hi = self._bounds[s]
        for i, layer in enumerate(self.model.layers[lo:hi]):
            h = layer.apply(stage_params[i], mb_graph, h, rngs[i], train)
        return h

    def _make_fwd(self, s: int):
        def fwd(stage_params, mb_graph, h, rngs):
            return self._stage_apply(s, stage_params, mb_graph, h, rngs, True)

        return jax.jit(fwd)

    def _make_bwd(self, s: int):
        """Recompute-backward: GPipe re-materializes the stage forward from
        its saved input, then pulls the cotangent back."""

        def bwd(stage_params, mb_graph, h_in, rngs, ct):
            def f(p, h):
                return self._stage_apply(s, p, mb_graph, h, rngs, True)

            _, vjp = jax.vjp(f, stage_params, h_in)
            d_params, d_h = vjp(ct)
            return d_params, d_h

        return jax.jit(bwd)

    def _place(self, tree, s: int):
        devs = self.config.devices
        if not devs:
            return tree
        return jax.device_put(tree, devs[s % len(devs)])

    # -------------------------------------------------------------- step --

    def init_params(self, key: jax.Array) -> list:
        params = self.model.init_params(key)
        if self.config.devices:
            params = [
                self._place(p, self._stage_of_layer(i)) for i, p in enumerate(params)
            ]
        return params

    def _stage_of_layer(self, layer_idx: int) -> int:
        for s, (lo, hi) in enumerate(self._bounds):
            if lo <= layer_idx < hi:
                return s
        raise IndexError(layer_idx)

    def _layer_rngs(self, rng: jax.Array, chunk: int):
        n_layers = len(self.model.layers)
        chunk_key = jax.random.fold_in(rng, chunk)
        return jax.random.split(chunk_key, n_layers)

    def forward_plan(
        self, params: list, plan: MicroBatchPlan, rng: jax.Array, *, record=None
    ) -> tuple[list[jax.Array], list[list[jax.Array]]]:
        """Fill-drain forward over all chunks. Returns (final activations per
        chunk, saved stage inputs [stage][chunk] for recompute-backward)."""
        S, C = self.config.num_stages, plan.chunks
        saved: list[list[Any]] = [[None] * C for _ in range(S)]
        outs: list[Any] = [None] * C
        # tick loop is explicit so work executes in true fill-drain order
        for t in range(C + S - 1):
            for s in range(S - 1, -1, -1):
                c = t - s
                if not (0 <= c < C):
                    continue
                mb = plan.batches[c]
                h = mb.graph.features if s == 0 else saved[s][c]
                t0 = time.perf_counter()
                rngs = self._layer_rngs(rng, c)
                lo, _ = self._bounds[s]
                h_out = self._fwd_fns[s](
                    self.stage_params(params, s),
                    mb.graph,
                    self._place(h, s),
                    rngs[lo : lo + self.config.balance[s]],
                )
                if record is not None:
                    jax.block_until_ready(h_out)
                    record.append(("fwd", t, s, c, time.perf_counter() - t0))
                if s == 0:
                    saved[0][c] = mb.graph.features
                if s + 1 < S:
                    saved[s + 1][c] = h_out
                else:
                    outs[c] = h_out
        return outs, saved

    def train_step(
        self,
        params: list,
        opt_state,
        plan: MicroBatchPlan,
        rng: jax.Array,
        optimizer: opt_lib.Optimizer,
        *,
        record: list | None = None,
    ):
        """One synchronous GPipe step: fill-drain fwd, recompute bwd with
        gradient accumulation over chunks, one optimizer update."""
        S, C = self.config.num_stages, plan.chunks
        outs, saved = self.forward_plan(params, plan, rng, record=record)

        grads = [jax.tree_util.tree_map(jnp.zeros_like, p) for p in params]
        cts: list[Any] = [None] * C
        total_loss = jnp.zeros((), jnp.float32)
        total_count = jnp.zeros((), jnp.float32)
        for c, mb in enumerate(plan.batches):
            (loss_sum, count), d_h = self._loss_grad(
                outs[c], mb.graph.labels, mb.graph.train_mask & mb.core_mask
            )
            cts[c] = d_h
            total_loss = total_loss + loss_sum
            total_count = total_count + count

        # drain backward in reverse fill-drain order
        for t in range(C + S - 1):
            for s in range(S):
                c = (C - 1) - (t - (S - 1 - s))
                if not (0 <= c < C):
                    continue
                mb = plan.batches[c]
                rngs = self._layer_rngs(rng, c)
                lo, hi = self._bounds[s]
                t0 = time.perf_counter()
                d_params, d_h = self._bwd_fns[s](
                    self.stage_params(params, s),
                    mb.graph,
                    saved[s][c],
                    rngs[lo:hi],
                    cts[c],
                )
                if record is not None:
                    jax.block_until_ready(d_h)
                    record.append(("bwd", t, s, c, time.perf_counter() - t0))
                cts[c] = d_h
                for i, g in enumerate(d_params):
                    grads[lo + i] = jax.tree_util.tree_map(jnp.add, grads[lo + i], g)

        scale = 1.0 / jnp.maximum(total_count, 1.0)
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        loss = total_loss / jnp.maximum(total_count, 1.0)
        return params, opt_state, loss

    # ------------------------------------------------------------ report --

    def describe(self) -> dict:
        return {
            "num_stages": self.config.num_stages,
            "balance": list(self.config.balance),
            "chunks": self.config.chunks,
            "bubble_fraction": bubble_fraction(self.config.num_stages, self.config.chunks),
            "layers": [l.name for l in self.model.layers],
        }


def _chunk_loss_sum(log_probs, labels, mask):
    """(Σ nll·mask, Σ mask) — summed form so cross-chunk accumulation equals
    the full-batch masked mean exactly."""
    nll = -jnp.take_along_axis(log_probs, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m), jnp.sum(m)
