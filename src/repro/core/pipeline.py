"""GPipe for GNNs — the paper's §6 implementation, JAX-native.

Faithful semantics:

  * the sequential model is partitioned into stages by a ``balance`` array
    (same contract as ``torchgpipe.GPipe(model, balance, chunks)``);
  * the input is micro-batched into ``chunks`` (strategy pluggable — the
    paper's index-sequential split is the default and reproduces its
    accuracy collapse);
  * work executes in the order a pluggable ``Schedule`` timeline dictates —
    fill-drain (GPipe, the paper), 1F1B, or interleaved 1F1B over virtual
    stages (``repro.core.schedule``); backward re-computes each stage's
    internals from its saved input (GPipe's activation re-materialization)
    and accumulates gradients across micro-batches;
  * a single synchronous optimizer update closes the step, so neither the
    number of chunks nor the schedule ever changes the *intended* gradient —
    per-chunk gradients are reduced in a canonical order, making every
    schedule's update bit-identical to the fill-drain baseline. Only lossy
    micro-batching of the graph moves the numbers (measured by
    ``plan.edge_cut``).

The schedule is driven at Python level with per-stage jitted kernels (and
optional per-stage device placement), mirroring torchgpipe's host-driven
queues; the compiled SPMD pipeline for the production mesh lives in
``repro.core.spmd_pipe``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.microbatch import MicroBatchPlan
from repro.core.schedule import get_schedule
from repro.models.gnn.net import GNNModel
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class GPipeConfig:
    balance: tuple[int, ...]  # layers per stage; sums to len(model.layers)
    chunks: int
    devices: tuple | None = None  # optional per-stage device placement
    schedule: str = "fill_drain"  # "fill_drain" | "gpipe" | "1f1b" | "interleaved"
    num_devices: int | None = None  # interleaved: physical devices (V = stages/devices)

    @property
    def num_stages(self) -> int:
        return len(self.balance)


class GPipe:
    """Pipeline-parallel wrapper around a sequential ``GNNModel``."""

    def __init__(self, model: GNNModel, config: GPipeConfig):
        if sum(config.balance) != len(model.layers):
            raise ValueError(
                f"balance {config.balance} must sum to {len(model.layers)} layers"
            )
        self.model = model
        self.config = config
        self.schedule = get_schedule(config.schedule, num_devices=config.num_devices)
        self._bounds: list[tuple[int, int]] = []
        lo = 0
        for b in config.balance:
            self._bounds.append((lo, lo + b))
            lo += b

        self._fwd_fns = [self._make_fwd(s) for s in range(config.num_stages)]
        self._bwd_fns = [self._make_bwd(s) for s in range(config.num_stages)]
        self._loss_grad = jax.jit(jax.value_and_grad(_chunk_loss_sum, argnums=0, has_aux=True))

    # ------------------------------------------------------------ stages --

    def stage_params(self, params: list, s: int) -> list:
        lo, hi = self._bounds[s]
        return params[lo:hi]

    def _stage_apply(self, s: int, stage_params: list, mb_graph, h, rngs, train: bool):
        lo, hi = self._bounds[s]
        for i, layer in enumerate(self.model.layers[lo:hi]):
            h = layer.apply(stage_params[i], mb_graph, h, rngs[i], train)
        return h

    def _make_fwd(self, s: int):
        def fwd(stage_params, mb_graph, h, rngs):
            return self._stage_apply(s, stage_params, mb_graph, h, rngs, True)

        return jax.jit(fwd)

    def _make_bwd(self, s: int):
        """Recompute-backward: GPipe re-materializes the stage forward from
        its saved input, then pulls the cotangent back."""

        def bwd(stage_params, mb_graph, h_in, rngs, ct):
            def f(p, h):
                return self._stage_apply(s, p, mb_graph, h, rngs, True)

            _, vjp = jax.vjp(f, stage_params, h_in)
            d_params, d_h = vjp(ct)
            return d_params, d_h

        return jax.jit(bwd)

    def _place(self, tree, s: int):
        devs = self.config.devices
        if not devs:
            return tree
        phys = self.schedule.device_of(s, self.config.num_stages)
        return jax.device_put(tree, devs[phys % len(devs)])

    # -------------------------------------------------------------- step --

    def init_params(self, key: jax.Array) -> list:
        params = self.model.init_params(key)
        if self.config.devices:
            params = [
                self._place(p, self._stage_of_layer(i)) for i, p in enumerate(params)
            ]
        return params

    def _stage_of_layer(self, layer_idx: int) -> int:
        for s, (lo, hi) in enumerate(self._bounds):
            if lo <= layer_idx < hi:
                return s
        raise IndexError(layer_idx)

    def _layer_rngs(self, rng: jax.Array, chunk: int):
        n_layers = len(self.model.layers)
        chunk_key = jax.random.fold_in(rng, chunk)
        return jax.random.split(chunk_key, n_layers)

    def _run_fwd_item(self, params, plan, rng, it, saved, outs, record):
        """Execute one forward work item: consume the saved stage input,
        produce (and route) the stage output."""
        s, c = it.stage, it.chunk
        mb = plan.batches[c]
        h = mb.graph.features if s == 0 else saved[(s, c)]
        t0 = time.perf_counter()
        rngs = self._layer_rngs(rng, c)
        lo, _ = self._bounds[s]
        h_out = self._fwd_fns[s](
            self.stage_params(params, s),
            mb.graph,
            self._place(h, s),
            rngs[lo : lo + self.config.balance[s]],
        )
        if record is not None:
            jax.block_until_ready(h_out)
            record.append(("fwd", it.tick, s, c, time.perf_counter() - t0))
        if s == 0:
            saved[(0, c)] = mb.graph.features
        if s + 1 < self.config.num_stages:
            saved[(s + 1, c)] = h_out
        else:
            outs[c] = h_out

    def train_step(
        self,
        params: list,
        opt_state,
        plan: MicroBatchPlan,
        rng: jax.Array,
        optimizer: opt_lib.Optimizer,
        *,
        record: list | None = None,
        stats: dict | None = None,
    ):
        """One synchronous pipeline step under ``config.schedule``: the
        timeline's work items execute in order (fwd saves its stage input,
        bwd recomputes + frees it, accumulating per-chunk gradients), then
        one optimizer update closes the step. Gradients are reduced in a
        canonical chunk order so every schedule produces a bit-identical
        update. ``stats`` (if given) receives measured peak live activations
        and the schedule's bubble accounting."""
        S, C = self.config.num_stages, plan.chunks
        timeline = self.schedule.timeline(S, C)

        saved: dict[tuple[int, int], Any] = {}
        outs: dict[int, Any] = {}
        cts: dict[int, Any] = {}
        chunk_losses: list[Any] = [None] * C
        chunk_grads: list[list[Any]] = [[None] * C for _ in range(S)]
        peak_live = 0

        for it in timeline:
            if it.phase == "fwd":
                self._run_fwd_item(params, plan, rng, it, saved, outs, record)
                peak_live = max(peak_live, len(saved))
                continue
            s, c = it.stage, it.chunk
            mb = plan.batches[c]
            if s == S - 1:
                # the chunk's loss cotangent, computed once its fwd completes
                (loss_sum, count), d_h = self._loss_grad(
                    outs.pop(c), mb.graph.labels, mb.graph.train_mask & mb.core_mask
                )
                chunk_losses[c] = (loss_sum, count)
                cts[c] = d_h
            rngs = self._layer_rngs(rng, c)
            lo, hi = self._bounds[s]
            t0 = time.perf_counter()
            d_params, d_h = self._bwd_fns[s](
                self.stage_params(params, s),
                mb.graph,
                saved.pop((s, c)),
                rngs[lo:hi],
                cts[c],
            )
            if record is not None:
                jax.block_until_ready(d_h)
                record.append(("bwd", it.tick, s, c, time.perf_counter() - t0))
            cts[c] = d_h
            chunk_grads[s][c] = d_params

        # canonical reduction — per stage, chunks in descending order (the
        # fill-drain drain order), so the accumulated floats are identical
        # no matter which schedule produced the per-chunk gradients
        grads = [jax.tree_util.tree_map(jnp.zeros_like, p) for p in params]
        total_loss = jnp.zeros((), jnp.float32)
        total_count = jnp.zeros((), jnp.float32)
        for s in range(S):
            lo, _ = self._bounds[s]
            for c in reversed(range(C)):
                for i, g in enumerate(chunk_grads[s][c]):
                    grads[lo + i] = jax.tree_util.tree_map(jnp.add, grads[lo + i], g)
        for c in range(C):
            loss_sum, count = chunk_losses[c]
            total_loss = total_loss + loss_sum
            total_count = total_count + count

        if stats is not None:
            stats.update(self.schedule.describe(S, C))
            stats["measured_peak_live_activations"] = peak_live

        scale = 1.0 / jnp.maximum(total_count, 1.0)
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        loss = total_loss / jnp.maximum(total_count, 1.0)
        return params, opt_state, loss

    # ------------------------------------------------------------ report --

    def describe(self) -> dict:
        d = self.schedule.describe(self.config.num_stages, self.config.chunks)
        d.update(
            {
                "balance": list(self.config.balance),
                "chunks": self.config.chunks,
                "layers": [l.name for l in self.model.layers],
            }
        )
        return d


def _chunk_loss_sum(log_probs, labels, mask):
    """(Σ nll·mask, Σ mask) — summed form so cross-chunk accumulation equals
    the full-batch masked mean exactly."""
    nll = -jnp.take_along_axis(log_probs, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m), jnp.sum(m)
