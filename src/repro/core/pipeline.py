"""Pipeline engines for GNNs — one interface, two executors.

``PipelineEngine`` is the contract (init_params / train_step / describe);
two implementations ship:

  * ``GPipe`` — the paper's §6 implementation, JAX-native and host-driven:
    the pluggable ``Schedule`` timeline executes at Python level with
    per-stage jitted kernels, mirroring torchgpipe's queues. Paper-faithful;
    schedules (fill-drain / 1F1B / interleaved) untouched.
  * ``CompiledGNNPipeline`` — the whole train step (forward pipeline over
    ``lax.scan`` + ``lax.ppermute``, loss over core masks, backward through
    the same collectives, canonical gradient reduction, optimizer update) is
    ONE jitted SPMD program over a ``("stage",)`` mesh axis. The micro-batch
    plan rides as a stacked uniform-shape pytree (``MicroBatchPlan.stacked``)
    so the subgraphs travel with the activations. With fewer devices than
    stages the same program body runs under ``jax.vmap(axis_name="stage")``
    — identical collective semantics, still one fused XLA program.

``make_engine(model, config)`` picks one via ``config.engine``; ``config``
may also be a planner ``PipelinePlan`` (``repro.core.autotune``), so an
``--auto`` pick replays directly. Both engines expose
``compile_eval(params, graph) -> EvalProgram`` — a per-shape forward-only
program handle with the params bound once — which ``evaluate`` and the
serving frontend (``repro.launch.serve_gnn``) share.

GPipe's faithful semantics:

  * the sequential model is partitioned into stages by a ``balance`` array
    (same contract as ``torchgpipe.GPipe(model, balance, chunks)``);
  * the input is micro-batched into ``chunks`` (strategy pluggable — the
    paper's index-sequential split is the default and reproduces its
    accuracy collapse);
  * work executes in the order a pluggable ``Schedule`` timeline dictates —
    fill-drain (GPipe, the paper), 1F1B, or interleaved 1F1B over virtual
    stages (``repro.core.schedule``); backward re-computes each stage's
    internals from its saved input (GPipe's activation re-materialization)
    and accumulates gradients across micro-batches;
  * a single synchronous optimizer update closes the step, so neither the
    number of chunks nor the schedule ever changes the *intended* gradient —
    per-chunk gradients are reduced in a canonical order, making every
    schedule's update bit-identical to the fill-drain baseline. Only lossy
    micro-batching of the graph moves the numbers (measured by
    ``plan.edge_cut``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.microbatch import MicroBatchPlan
from repro.graphs.data import BucketedGraphBatch
from repro.graphs.partition import bucketize_stacked
from repro.core.schedule import (
    PHASE_BWD,
    PHASE_BWD_B,
    PHASE_BWD_W,
    PHASE_FWD,
    Placement,
    forward_timeline,
    get_schedule,
    lower_timeline,
    retime_timeline,
)
from repro.core.spmd_pipe import (
    spmd_pipeline,
    spmd_pipeline_scheduled,
    spmd_pipeline_scheduled_eval,
    spmd_pipeline_scheduled_eval_lanes,
    spmd_pipeline_scheduled_lanes,
)
from repro.models.gnn.net import (
    GNNModel,
    activation_widths,
    make_gnn_stage,
    make_gnn_stage_slices,
    make_gnn_stage_slices_bw,
    travel_width,
)
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class GPipeConfig:
    """Everything that selects a pipeline: stage balance, chunking, the
    schedule and its device placement, the engine that executes it, the
    aggregation backend and the data-parallel width."""

    balance: tuple[int, ...]  # layers per stage; sums to len(model.layers)
    chunks: int
    devices: tuple | None = None  # optional per-stage device placement
    schedule: str = "fill_drain"  # any repro.core.schedule.SCHEDULES name
    num_devices: int | None = None  # interleaved/zb-v: physical devices (V = stages/devices)
    remat: bool = True  # compiled engine: GPipe-style activation re-materialization
    # stage -> device assignment overriding the schedule's default (ring
    # rotations + a physical device order); validated against the lowering's
    # ring check at engine construction
    placement: Placement | None = None
    engine: str = "host"  # "host" | "compiled"; consumed by make_engine
    # aggregation backend: "padded" | "dense" | "pallas". Must match the
    # backend the model's layers were built with; under "pallas" both
    # engines additionally feed the stage programs the degree-bucketed
    # layout (graphs.partition.bucketize_stacked) instead of the raw
    # padded batch, so aggregation work tracks the degree distribution.
    backend: str = "padded"
    # graph data parallelism (compiled engine): replicas on the "data" axis
    # of a (data, stage) mesh, each running the pipeline over its contiguous
    # shard of the chunks. Gradients are gathered over the axis and reduced
    # in the canonical global chunk order, so the update stays bit-identical
    # to a single replica. Requires chunks % data_parallel == 0.
    data_parallel: int = 1
    # communication/compute overlap (compiled engine): "off" keeps the
    # serialized ppermute-after-work tick; "double-buffer" retimes the
    # timeline to wire_latency 2 so each tick posts the NEXT tick's
    # transfers before its work (parity-alternating wire buffers — see
    # spmd_pipe's wire-parity rule); "async" is double-buffer plus
    # best-effort XLA latency-hiding-scheduler flags (core.overlap_report).
    # Pure retiming: updates stay bit-identical to "off" for every
    # schedule × placement × data-parallel combo.
    overlap: str = "off"

    @property
    def num_stages(self) -> int:
        """Pipeline stages (= entries in ``balance``)."""
        return len(self.balance)


@jax.jit
def _eval_metric_head(logp, labels, masks):
    """Shared metric head for both engines' eval programs: masked means over
    the (chunks, n_pad) grid — padding rows and halo ghosts carry zero mask,
    so on a lossless plan these equal the full-batch numbers bit for bit."""
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    hit = (jnp.argmax(logp, axis=-1) == labels).astype(jnp.float32)

    def masked_mean(x, mask):
        m = mask.astype(jnp.float32)
        return jnp.sum(x * m) / jnp.maximum(jnp.sum(m), 1.0)

    return {
        "train_loss": masked_mean(nll, masks["train"]),
        "train_acc": masked_mean(hit, masks["train"]),
        "val_acc": masked_mean(hit, masks["val"]),
        "test_acc": masked_mean(hit, masks["test"]),
    }


class EvalProgram:
    """Handle for ONE compiled forward-only inference program at a fixed
    stacked-batch shape ``(chunks, n_pad, max_deg)`` — the unit of the
    serving engine's shape bucketing.

    ``engine.compile_eval(params, graph)`` compiles (or fetches the cached)
    program for the graph's shape and ``bind``s the params: replication onto
    the program's eval mesh happens ONCE here, not per call — the old
    ``evaluate`` re-issued a ``device_put`` of the full param tree on every
    call, allocation churn that dominates small-batch serving.
    ``__call__(graph)`` runs one stacked batch and returns per-chunk
    log-probabilities ``(chunks, n_pad, out_dim)``; ``metrics`` is the fused
    metric head ``evaluate`` layers on top."""

    def __init__(self, forward, mesh, out_dim: int, key: tuple):
        self._forward = forward
        self.mesh = mesh  # None on the host / lane substrates
        self.out_dim = out_dim
        self.key = key  # (chunks, n_pad, max_deg)
        self._bound = None  # (params as handed in, params placed on the mesh)

    @property
    def chunks(self) -> int:
        """Chunk count this program was compiled for."""
        return self.key[0]

    @property
    def n_pad(self) -> int:
        """Padded per-chunk node count this program was compiled for."""
        return self.key[1]

    def bind(self, params) -> "EvalProgram":
        """Place ``params`` for this program — replicated over the eval mesh
        when there is one — unless the same tree object is already bound.
        Serving binds once at warmup; every batch reuses the resident copy.
        (Training naturally re-binds each epoch: new step, new param tree.)"""
        if self._bound is None or self._bound[0] is not params:
            placed = params
            if self.mesh is not None:
                # the eval ring places one stage per device; params coming out
                # of a train step whose mesh spans a different device set
                # (e.g. interleaved's 2-device ring on a 4-device host) must
                # be re-replicated onto the eval mesh or jit rejects the mix
                placed = jax.device_put(
                    params, jax.sharding.NamedSharding(self.mesh, P())
                )
            self._bound = (params, placed)
        return self

    def __call__(self, graph):
        """Run one stacked batch -> logp ``(chunks, n_pad, out_dim)``."""
        if self._bound is None:
            raise ValueError("EvalProgram: call bind(params) before __call__")
        return self._forward(self._bound[1], graph)

    def metrics(self, graph, core_mask) -> dict:
        """The classic ``evaluate`` metric dict over the batch's core nodes."""
        masks = {
            "train": graph.train_mask & core_mask,
            "val": graph.val_mask & core_mask,
            "test": graph.test_mask & core_mask,
        }
        return _eval_metric_head(self(graph), graph.labels, masks)


class PipelineEngine:
    """Contract both engines implement: partition a sequential ``GNNModel``
    by a ``balance`` array, then run synchronous pipeline train steps over a
    ``MicroBatchPlan``. Subclasses provide ``train_step``."""

    name = "base"

    def __init__(self, model: GNNModel, config: GPipeConfig):
        if sum(config.balance) != len(model.layers):
            raise ValueError(
                f"balance {config.balance} must sum to {len(model.layers)} layers"
            )
        if config.data_parallel < 1:
            raise ValueError(f"data_parallel must be >= 1, got {config.data_parallel}")
        if config.overlap not in ("off", "double-buffer", "async"):
            raise ValueError(
                f"overlap must be 'off', 'double-buffer' or 'async', got "
                f"{config.overlap!r}"
            )
        self.model = model
        self.config = config
        # flipped by the compiled engine's step builder when the 2-D
        # (data, stage) mesh actually runs (enough devices for dp * ring)
        self._data_parallel_active = False
        self.schedule = get_schedule(config.schedule, num_devices=config.num_devices)
        self.placement = config.placement
        if self.placement is not None:
            self.placement.validate(config.num_stages)
            want = self.schedule.num_devices(config.num_stages)
            if self.placement.num_devices != want:
                raise ValueError(
                    f"placement spans {self.placement.num_devices} devices "
                    f"but schedule {config.schedule!r} places "
                    f"{config.num_stages} stages on {want}"
                )
        self._bounds: list[tuple[int, int]] = []
        lo = 0
        for b in config.balance:
            self._bounds.append((lo, lo + b))
            lo += b
        # graph -> backend layout, keyed by id(); entries retain the graph
        # so a recycled id() can never serve a stale layout
        self._layout_cache: dict = {}

    # ------------------------------------------------------------ stages --

    def stage_params(self, params: list, s: int) -> list:
        """The slice of per-layer params owned by stage ``s``."""
        lo, hi = self._bounds[s]
        return params[lo:hi]

    def _stage_of_layer(self, layer_idx: int) -> int:
        for s, (lo, hi) in enumerate(self._bounds):
            if lo <= layer_idx < hi:
                return s
        raise IndexError(layer_idx)

    # ---------------------------------------------------------- contract --

    def init_params(self, key: jax.Array) -> list:
        """Fresh per-layer params from the wrapped model."""
        return self.model.init_params(key)

    def train_step(
        self,
        params: list,
        opt_state,
        plan: MicroBatchPlan,
        rng: jax.Array,
        optimizer: opt_lib.Optimizer,
        *,
        record: list | None = None,
        stats: dict | None = None,
    ):
        """One optimizer step over the plan's chunks; returns
        ``(params, opt_state, mean_loss)``."""
        raise NotImplementedError

    def compile_eval(self, params: list, graph) -> EvalProgram:
        """Compile (or fetch the cached) forward-only eval program for the
        shape of ``graph`` — a stacked pytree with leaves ``(chunks, n_pad,
        ...)`` such as ``StackedPlan.graph`` or a serving bucket batch — and
        bind ``params`` to it (replicated once, reused across calls). Both
        engines implement this, so ``--engine host|compiled`` stays symmetric
        all the way into the serving frontend."""
        raise NotImplementedError

    def layout(self, graph):
        """The aggregation layout this engine's programs consume for a
        chunk-stacked ``graph``: the padded batch itself for the padded and
        dense backends, its degree-bucketed wrapper
        (``graphs.partition.bucketize_stacked``) under ``backend="pallas"``.
        The wrapper delegates every padded-batch attribute, so downstream
        plumbing (loss masks, metric heads, shape keys) is layout-blind."""
        if self.config.backend != "pallas" or isinstance(graph, BucketedGraphBatch):
            return graph
        cached = self._layout_cache.get(id(graph))
        if cached is not None and cached[0] is graph:
            return cached[1]
        wrapped = bucketize_stacked(graph)
        self._layout_cache[id(graph)] = (graph, wrapped)
        return wrapped

    def evaluate(self, params: list, plan: MicroBatchPlan) -> dict:
        """Forward-only inference over the plan's chunks: the same metric
        dict as ``repro.train.loop.make_eval``, produced by this engine's
        compiled eval program. Metrics cover each chunk's core nodes; with a
        lossless plan (halo, hops >= model depth) they equal the full-batch
        numbers, with the paper's sequential split they reflect its dropped
        edges."""
        stacked = plan.stacked()
        graph = self.layout(stacked.graph)
        prog = self.compile_eval(params, graph)
        return prog.metrics(graph, stacked.core_mask)

    def describe(self) -> dict:
        """Engine + schedule metadata for logs and benchmark tables."""
        d = self.schedule.describe(self.config.num_stages, self.config.chunks)
        d.update(
            {
                "engine": self.name,
                "balance": list(self.config.balance),
                "chunks": self.config.chunks,
                "layers": [l.name for l in self.model.layers],
            }
        )
        if self.placement is not None:
            d["placement"] = list(self.placement.stage_to_device)
        if self.config.data_parallel > 1:
            d["data_parallel"] = self.config.data_parallel
        return d


class GPipe(PipelineEngine):
    """Host-driven pipeline-parallel wrapper around a sequential ``GNNModel``
    (the paper's §6 torchgpipe analogue; schedules are pluggable)."""

    name = "host"

    def __init__(self, model: GNNModel, config: GPipeConfig):
        super().__init__(model, config)
        if config.data_parallel > 1:
            raise ValueError(
                "data_parallel > 1 needs the compiled engine's (data, stage) "
                "mesh; the host queue loop has no data axis"
            )
        if config.overlap != "off":
            raise ValueError(
                "overlap needs the compiled engine's wire buffers; the host "
                "queue loop has no wires to double-buffer"
            )
        self._fwd_fns = [self._make_fwd(s) for s in range(config.num_stages)]
        self._bwd_fns = [self._make_bwd(s) for s in range(config.num_stages)]
        # split-backward halves (zb-h1); jit is lazy, so unused schedules
        # never pay for them
        self._bwd_b_fns = [self._make_bwd_b(s) for s in range(config.num_stages)]
        self._bwd_w_fns = [self._make_bwd_w(s) for s in range(config.num_stages)]
        self._loss_grad = jax.jit(jax.value_and_grad(_chunk_loss_sum, argnums=0, has_aux=True))
        self._evals: dict = {}  # (chunks, n_pad, max_deg) -> EvalProgram

    def compile_eval(self, params: list, graph) -> EvalProgram:
        """Host twin of the compiled engine's eval program: one jitted
        ``lax.scan`` over the stacked chunks applying the full layer stack
        (eval needs no pipelining — there is no queue to keep busy)."""
        key = (
            graph.features.shape[0],
            graph.features.shape[1],
            graph.neighbors.shape[2],
        )
        prog = self._evals.get(key)
        if prog is None:
            model = self.model

            def forward(params, g):
                def body(_, chunk):
                    return None, model.apply(params, chunk, train=False)

                _, logp = lax.scan(body, None, g)
                return logp

            prog = EvalProgram(jax.jit(forward), None, model.out_dim, key)
            self._evals[key] = prog
        return prog.bind(params)

    def _stage_apply(self, s: int, stage_params: list, mb_graph, h, rngs, train: bool):
        lo, hi = self._bounds[s]
        for i, layer in enumerate(self.model.layers[lo:hi]):
            h = layer.apply(stage_params[i], mb_graph, h, rngs[i], train)
        return h

    def _make_fwd(self, s: int):
        def fwd(stage_params, mb_graph, h, rngs):
            return self._stage_apply(s, stage_params, mb_graph, h, rngs, True)

        return jax.jit(fwd)

    def _make_bwd(self, s: int):
        """Recompute-backward: GPipe re-materializes the stage forward from
        its saved input, then pulls the cotangent back."""

        def bwd(stage_params, mb_graph, h_in, rngs, ct):
            def f(p, h):
                return self._stage_apply(s, p, mb_graph, h, rngs, True)

            _, vjp = jax.vjp(f, stage_params, h_in)
            d_params, d_h = vjp(ct)
            return d_params, d_h

        return jax.jit(bwd)

    def _make_bwd_b(self, s: int):
        """Zero-bubble B half: input-grad only (vjp wrt the stage input, so
        the weight-grad work is dead code) — the critical-path product."""

        def bwd_b(stage_params, mb_graph, h_in, rngs, ct):
            def f(h):
                return self._stage_apply(s, stage_params, mb_graph, h, rngs, True)

            _, vjp = jax.vjp(f, h_in)
            (d_h,) = vjp(ct)
            return d_h

        return jax.jit(bwd_b)

    def _make_bwd_w(self, s: int):
        """Zero-bubble W half: weight-grad only, re-materialized from the
        residual its B half banked (the saved stage input + applied
        cotangent) — runs whenever the schedule finds an idle tick."""

        def bwd_w(stage_params, mb_graph, h_in, rngs, ct):
            def f(p):
                return self._stage_apply(s, p, mb_graph, h_in, rngs, True)

            _, vjp = jax.vjp(f, stage_params)
            (d_params,) = vjp(ct)
            return d_params

        return jax.jit(bwd_w)

    def _place(self, tree, s: int):
        devs = self.config.devices
        if not devs:
            return tree
        if self.placement is not None:
            pos = self.placement.stage_to_device[s]
            order = self.placement.device_order
            phys = order[pos] if order is not None else pos
        else:
            phys = self.schedule.device_of(s, self.config.num_stages)
        return jax.device_put(tree, devs[phys % len(devs)])

    # -------------------------------------------------------------- step --

    def init_params(self, key: jax.Array) -> list:
        """Fresh per-layer params, placed on the configured stage devices
        when the config carries an explicit device list."""
        params = self.model.init_params(key)
        if self.config.devices:
            params = [
                self._place(p, self._stage_of_layer(i)) for i, p in enumerate(params)
            ]
        return params

    def _layer_rngs(self, rng: jax.Array, chunk: int):
        n_layers = len(self.model.layers)
        chunk_key = jax.random.fold_in(rng, chunk)
        return jax.random.split(chunk_key, n_layers)

    def _chunk_graphs(self, plan: MicroBatchPlan) -> list:
        """Per-chunk graphs the stage fns consume: the plan's padded batches
        as-is, or (pallas) their degree-bucketed layouts. All chunks share
        one set of bucket capacities (``bucketize_stacked`` on the stacked
        plan, sliced back per chunk), so each per-stage jitted fn compiles
        once and serves every chunk."""
        if self.config.backend != "pallas":
            return [mb.graph for mb in plan.batches]
        cached = self._layout_cache.get(id(plan))
        if cached is not None and cached[0] is plan:
            return cached[1]
        stacked = self.layout(plan.stacked().graph)
        graphs = [
            jax.tree_util.tree_map(lambda a, c=c: a[c], stacked)
            for c in range(plan.chunks)
        ]
        self._layout_cache[id(plan)] = (plan, graphs)
        return graphs

    def _run_fwd_item(self, params, plan, graphs, rng, it, saved, outs, record):
        """Execute one forward work item: consume the saved stage input,
        produce (and route) the stage output."""
        s, c = it.stage, it.chunk
        g = graphs[c]
        h = g.features if s == 0 else saved[(s, c)]
        t0 = time.perf_counter()
        rngs = self._layer_rngs(rng, c)
        lo, _ = self._bounds[s]
        h_out = self._fwd_fns[s](
            self.stage_params(params, s),
            g,
            self._place(h, s),
            rngs[lo : lo + self.config.balance[s]],
        )
        if record is not None:
            jax.block_until_ready(h_out)
            record.append(("fwd", it.tick, s, c, time.perf_counter() - t0))
        if s == 0:
            saved[(0, c)] = g.features
        if s + 1 < self.config.num_stages:
            saved[(s + 1, c)] = h_out
        else:
            outs[c] = h_out

    def train_step(
        self,
        params: list,
        opt_state,
        plan: MicroBatchPlan,
        rng: jax.Array,
        optimizer: opt_lib.Optimizer,
        *,
        record: list | None = None,
        stats: dict | None = None,
    ):
        """One synchronous pipeline step under ``config.schedule``: the
        timeline's work items execute in order (fwd saves its stage input,
        bwd recomputes + frees it, accumulating per-chunk gradients), then
        one optimizer update closes the step. Gradients are reduced in a
        canonical chunk order so every schedule produces a bit-identical
        update. ``stats`` (if given) receives measured peak live activations
        and the schedule's bubble accounting."""
        S, C = self.config.num_stages, plan.chunks
        timeline = self.schedule.timeline(S, C)
        if self.placement is not None:
            # re-device the items (ticks/order untouched): recorded items and
            # _place() then reflect the configured stage->device assignment
            timeline = self.placement.apply(timeline)
        graphs = self._chunk_graphs(plan)

        saved: dict[tuple[int, int], Any] = {}
        outs: dict[int, Any] = {}
        cts: dict[int, Any] = {}
        residuals: dict[tuple[int, int], Any] = {}  # zb-h1: (h_in, ct) per B
        chunk_losses: list[Any] = [None] * C
        chunk_grads: list[list[Any]] = [[None] * C for _ in range(S)]
        peak_live = 0
        peak_residuals = 0

        for it in timeline:
            if it.phase == "fwd":
                self._run_fwd_item(params, plan, graphs, rng, it, saved, outs, record)
                peak_live = max(peak_live, len(saved))
                continue
            s, c = it.stage, it.chunk
            mb = plan.batches[c]
            g = graphs[c]
            if s == S - 1 and it.phase in ("bwd", "bwd_b"):
                # the chunk's loss cotangent, computed once its fwd completes
                (loss_sum, count), d_h = self._loss_grad(
                    outs.pop(c), mb.graph.labels, mb.graph.train_mask & mb.core_mask
                )
                chunk_losses[c] = (loss_sum, count)
                cts[c] = d_h
            rngs = self._layer_rngs(rng, c)
            lo, hi = self._bounds[s]
            t0 = time.perf_counter()
            # route the saved stage input and the arriving cotangent onto
            # this stage's device, exactly like the forward path does for its
            # input — with per-stage placement they arrive committed to the
            # NEIGHBOR stage's device and the jitted backward rejects the mix
            if it.phase == "bwd":
                d_params, d_h = self._bwd_fns[s](
                    self.stage_params(params, s),
                    g,
                    self._place(saved.pop((s, c)), s),
                    rngs[lo:hi],
                    self._place(cts[c], s),
                )
                cts[c] = d_h
                chunk_grads[s][c] = d_params
                produced = d_h
            elif it.phase == "bwd_b":
                # B: emit the upstream cotangent now, defer the weight grad
                # — the stage input moves from `saved` into the W residual
                h_in = self._place(saved.pop((s, c)), s)
                ct = self._place(cts[c], s)
                d_h = self._bwd_b_fns[s](
                    self.stage_params(params, s), g, h_in, rngs[lo:hi], ct
                )
                residuals[(s, c)] = (h_in, ct)
                peak_residuals = max(peak_residuals, len(residuals))
                cts[c] = d_h
                produced = d_h
            else:  # "bwd_w": consume the residual, produce the weight grad
                h_in, ct = residuals.pop((s, c))
                chunk_grads[s][c] = self._bwd_w_fns[s](
                    self.stage_params(params, s), g, h_in, rngs[lo:hi], ct
                )
                produced = chunk_grads[s][c]  # W emits no cotangent
            if record is not None:
                jax.block_until_ready(produced)
                record.append((it.phase, it.tick, s, c, time.perf_counter() - t0))

        # canonical reduction — per stage, chunks in descending order (the
        # fill-drain drain order), so the accumulated floats are identical
        # no matter which schedule produced the per-chunk gradients
        grads = [jax.tree_util.tree_map(jnp.zeros_like, p) for p in params]
        total_loss = jnp.zeros((), jnp.float32)
        total_count = jnp.zeros((), jnp.float32)
        for s in range(S):
            lo, _ = self._bounds[s]
            for c in reversed(range(C)):
                for i, g in enumerate(chunk_grads[s][c]):
                    grads[lo + i] = jax.tree_util.tree_map(jnp.add, grads[lo + i], g)
        for c in range(C):
            loss_sum, count = chunk_losses[c]
            total_loss = total_loss + loss_sum
            total_count = total_count + count

        if stats is not None:
            stats.update(self.schedule.describe(S, C))
            stats["measured_peak_live_activations"] = peak_live
            stats["measured_peak_w_residuals"] = peak_residuals

        scale = 1.0 / jnp.maximum(total_count, 1.0)
        # scale is committed to the LAST stage's device (it came from the
        # loss); each layer's gradients live on their own stage's device, so
        # ship the scalar to each stage before multiplying (no-op placement
        # when no device list is configured)
        grads = [
            jax.tree_util.tree_map(
                lambda g, sc=self._place(scale, self._stage_of_layer(i)): g * sc,
                layer_grads,
            )
            for i, layer_grads in enumerate(grads)
        ]
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        loss = total_loss / jnp.maximum(total_count, 1.0)
        return params, opt_state, loss

def _chunk_loss_sum(log_probs, labels, mask):
    """(Σ nll·mask, Σ mask) — summed form so cross-chunk accumulation equals
    the full-batch masked mean exactly."""
    nll = -jnp.take_along_axis(log_probs, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m), jnp.sum(m)


class CompiledGNNPipeline(PipelineEngine):
    """Compiled SPMD engine: the whole train step is one jitted program.

    The stacked micro-batch plan (``MicroBatchPlan.stacked()``) feeds
    ``repro.core.spmd_pipe.spmd_pipeline`` with a pytree of per-chunk leaves
    — padded subgraph + activation + chunk id — so the graph travels
    stage→stage through ``lax.ppermute`` exactly like the activations, and
    ``lax.scan`` ticks replace the host-driven queue. The loss is computed
    from the last stage's outputs (zeros elsewhere, ``reduce="none"``) and
    psum-assembled; differentiation happens *outside* the stage-axis map —
    the same structure as the transformer train step — so backward runs
    through the transposed ``ppermute``/scan and each stage's device
    contributes exactly its layers' gradients: the canonical cross-stage
    reduction. One synchronous optimizer update closes the step, fused into
    the same jitted program.

    Executor substrates (chosen at build time, same update either way):

      * ``jax.device_count() >= num_stages`` — ``shard_map`` over a
        ``("stage",)`` mesh: true SPMD, one stage per device, activations
        hopping the ring through ``ppermute``.
      * fewer devices — the chunk-sequential *specialization*: one fused
        ``lax.scan`` over chunks applying the whole layer stack. Pipelining
        only reorders execution, never the math (the engine's
        schedule-invariance), so on a single device the fastest valid order
        is no interleaving at all — emulating the ring there (e.g. via
        ``vmap(axis_name="stage")``) computes every stage's ``switch``
        branch in every lane, an S× FLOP blow-up for zero parallelism. This
        is what makes ``--engine compiled`` meaningful on a laptop: one jit
        dispatch per step instead of 2·S·C.

    The engine is schedule-aware (``config.schedule``): fill-drain routes to
    the executors above (AD through scan/ppermute, unchanged numerics);
    1F1B and interleaved-1F1B lower their ``WorkItem`` timeline to static
    per-tick index arrays (``repro.core.schedule.lower_timeline``) and run
    through ``spmd_pipeline_scheduled`` — mixed fwd/bwd ticks with explicit
    ``jax.vjp`` backward stages (no AD through the scan, so no per-tick
    residual buffers) and an activation stash sized to the schedule's live
    window (1F1B's min(S-s, C)) instead of the fill-drain S·C. Per-chunk
    gradients are reduced in the canonical descending-chunk order after the
    scan, so every schedule×engine combination stays bit-identical to the
    host fill-drain baseline. With fewer devices than the schedule's
    placement needs, the same work dispatcher runs through
    ``spmd_pipeline_scheduled_lanes`` — the ring as a lane axis inside one
    program, a static lane loop keeping every ``lax.switch`` a real
    single-branch conditional (a ``vmap(axis_name=...)`` emulation would
    batch the predicate and compute all 2S+1 branches per lane).
    """

    name = "compiled"

    def __init__(self, model: GNNModel, config: GPipeConfig):
        super().__init__(model, config)
        self._widths: list[int] | None = None
        self._steps: dict = {}
        self._evals: dict = {}  # (chunks, n_pad, max_deg) -> EvalProgram
        self._travel_cache: dict = {}
        self._lowered: dict = {}  # chunks -> LoweredTimeline (scheduled path)

    @property
    def _identity_ring(self) -> bool:
        p = self.placement
        return p is None or p.stage_to_device == tuple(range(self.config.num_stages))

    @property
    def _fill_drain(self) -> bool:
        # a rotated placement re-devices the timeline, which only the
        # scheduled executor understands — fill-drain under a non-identity
        # ring routes through it instead of the fused axis_index scan; the
        # same goes for data parallelism, whose chunk sharding and gathered
        # gradient reduction live in the scheduled executor only
        # overlap also routes through the scheduled executor: the fused scan
        # has no retimed index arrays to double-buffer against
        return (
            self.config.schedule in ("fill_drain", "gpipe")
            and self._identity_ring
            and self.config.data_parallel == 1
            and self.config.overlap == "off"
        )

    def _mesh_devices(self, num_devices: int):
        """The mesh's device array: position d of the ring is
        ``device_order[d]`` of the host's devices when the placement picks an
        order FOR THIS RING SIZE, devices 0..D-1 otherwise. The size check
        matters: the eval path rings S devices even when an interleaved
        placement trains on D < S, and applying the train ring's (shorter)
        device_order there would hand the S-hop ppermute a D-device mesh."""
        devs = jax.devices()
        p = self.placement
        if p is not None and p.device_order is not None and len(p.device_order) == num_devices:
            if max(p.device_order) >= len(devs):
                raise ValueError(
                    f"placement device_order {p.device_order} references "
                    f"device indices beyond the host's {len(devs)} devices"
                )
            return np.array([devs[i] for i in p.device_order])
        return np.array(devs[:num_devices])

    # ------------------------------------------------------------ program --

    def _make_local_loss(self, widths: list[int]):
        """Per-device masked-NLL mean over every chunk's core nodes. Runs
        inside the stage-axis map; the psum assembles the last stage's local
        sum on every device, so the scalar is replicated."""
        S = self.config.num_stages
        model, bounds, remat = self.model, self._bounds, self.config.remat

        def local_loss(params, travel, graph, labels, m, count, rng):
            stage_fn = make_gnn_stage(
                model, params, bounds, widths, graph, rng, stage_axis="stage", train=True
            )
            out, _ = spmd_pipeline(
                stage_fn, travel, stage_axis="stage", num_stages=S,
                remat=remat, reduce="none",
            )
            logp = out["h"][..., : model.out_dim]
            nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
            return lax.psum(jnp.sum(nll * m), "stage") / jnp.maximum(count, 1.0)

        return local_loss

    def _make_scan_loss(self):
        """Single-device specialization: one ``lax.scan`` over chunks, each
        applying the full layer stack (no activation-width padding needed —
        nothing rides a wire). Same per-(chunk, layer) rng derivation and
        same masked-NLL accumulation as the pipelined program, so the update
        matches the ring substrate (and the host engine) exactly."""
        model = self.model
        n_layers = len(model.layers)
        remat = self.config.remat

        def scan_loss(params, travel, graph, labels, m, count, rng):
            def chunk_nll(c):
                g = jax.tree_util.tree_map(
                    lambda a: lax.dynamic_index_in_dim(a, c, 0, keepdims=False), graph
                )
                rngs = jax.random.split(jax.random.fold_in(rng, c), n_layers)
                h = g.features
                for i, layer in enumerate(model.layers):
                    h = layer.apply(params[i], g, h, rngs[i], True)
                nll = -jnp.take_along_axis(h, labels[c][:, None], axis=-1)[:, 0]
                return jnp.sum(nll * m[c])

            body = jax.checkpoint(chunk_nll) if remat else chunk_nll

            def tick(acc, c):
                return acc + body(c), None

            lsum, _ = lax.scan(tick, jnp.zeros(()), travel["chunk"])
            return lsum / jnp.maximum(count, 1.0)

        return scan_loss

    def _build_step(self, widths: list[int], optimizer: opt_lib.Optimizer):
        S = self.config.num_stages
        if jax.device_count() >= S:
            mesh = jax.sharding.Mesh(self._mesh_devices(S), ("stage",))
            loss_fn = compat.shard_map(
                self._make_local_loss(widths), mesh=mesh,
                in_specs=(P(),) * 7, out_specs=P(),
            )
        else:
            loss_fn = self._make_scan_loss()

        def step(params, opt_state, travel, graph, labels, loss_mask, rng):
            m = loss_mask.astype(jnp.float32)
            count = jnp.sum(m)
            # differentiate OUTSIDE the stage-axis map (transformer-style):
            # backward runs through the transposed ppermute/scan and each
            # device contributes exactly its stage's layer gradients
            loss, grads = jax.value_and_grad(loss_fn)(
                params, travel, graph, labels, m, count, rng
            )
            updates, opt_state = optimizer.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return jax.jit(step)

    def _make_work_fn(
        self, widths: list[int], params, graph, labels, m, rng, *, phases, chunk_offset=0
    ):
        """The per-tick work dispatcher for ``spmd_pipeline_scheduled``: one
        ``lax.switch`` over 1 + 4·S branches (idle, then fwd / fused bwd /
        split B / split W per stage; phases the timeline never emits —
        ``phases`` is the set it does — compile to the trivial idle branch).
        Backward branches are explicit ``jax.vjp``s of the params-explicit
        stage slices — differentiating wrt the FULL params list yields a
        full-shaped gradient pytree with zeros outside the stage's layers,
        which is exactly what the canonical cross-stage psum reduction
        needs. The last stage derives its cotangent from the same summed
        masked-NLL the host engine differentiates (``_chunk_loss_sum``) —
        in the fused bwd branch or, under zb-h1, in the B half — so the
        loss trajectory matches chunk for chunk. Split B/W branches come
        from ``make_gnn_stage_slices_bw``: B emits the upstream cotangent
        plus the (input, cotangent) residual; W re-materializes from the
        residual and emits the deferred weight grad."""
        S = self.config.num_stages
        model = self.model
        slices = make_gnn_stage_slices(
            model, self._bounds, widths, graph, rng, train=True,
            chunk_offset=chunk_offset,
        )
        d_travel = travel_width(self._bounds, widths)
        n_pad = graph.features.shape[1]
        zero_wire = jnp.zeros((n_pad, d_travel), graph.features.dtype)
        zero_wres = (zero_wire, zero_wire)
        zero = jnp.zeros((), jnp.float32)

        def loss_ct(y, chunk):
            logp = y[:, : model.out_dim]
            (loss_sum, count), d_logp = jax.value_and_grad(
                _chunk_loss_sum, argnums=0, has_aux=True
            )(logp, labels[chunk], m[chunk])
            ct = jnp.pad(d_logp, ((0, 0), (0, d_travel - d_logp.shape[-1])))
            return ct, loss_sum, count

        b_fns, w_fns = make_gnn_stage_slices_bw(
            model, self._bounds, widths, graph, rng, train=True, loss_ct=loss_ct,
            chunk_offset=chunk_offset,
        )

        def zeros_grads():
            return jax.tree_util.tree_map(jnp.zeros_like, params)

        def idle(operand):
            return zero_wire, zero_wire, zero_wres, zeros_grads(), zero, zero

        def fwd(s):
            def branch(operand):
                chunk, h_in, _ct, _w = operand
                y = slices[s](params, chunk, h_in)
                return y, zero_wire, zero_wres, zeros_grads(), zero, zero

            return branch

        def bwd(s):
            last = s == S - 1

            def branch(operand):
                chunk, h_in, ct, _w = operand

                def f(p, h):
                    return slices[s](p, chunk, h)

                y, vjp = jax.vjp(f, params, h_in)
                if last:
                    ct, loss_sum, count = loss_ct(y, chunk)
                else:
                    loss_sum = count = zero
                d_params, d_h = vjp(ct)
                return zero_wire, d_h, zero_wres, d_params, loss_sum, count

            return branch

        def bwd_b(s):
            def branch(operand):
                chunk, h_in, ct, _w = operand
                d_h, w_out, loss_sum, count = b_fns[s](params, chunk, h_in, ct)
                return zero_wire, d_h, w_out, zeros_grads(), loss_sum, count

            return branch

        def bwd_w(s):
            def branch(operand):
                chunk, _h, _ct, w_res = operand
                d_params = w_fns[s](params, chunk, w_res)
                return zero_wire, zero_wire, zero_wres, d_params, zero, zero

            return branch

        def used(phase, make):
            return [make(s) if phase in phases else idle for s in range(S)]

        branches = (
            [idle]
            + used(PHASE_FWD, fwd)
            + used(PHASE_BWD, bwd)
            + used(PHASE_BWD_B, bwd_b)
            + used(PHASE_BWD_W, bwd_w)
        )

        def work_fn(phase, stage, chunk, h_in, ct, w_res):
            # idle -> 0, fwd(s) -> 1 + s, bwd(s) -> 1 + S + s,
            # bwd_b(s) -> 1 + 2S + s, bwd_w(s) -> 1 + 3S + s
            index = jnp.where(phase == 0, 0, (phase - 1) * S + stage + 1)
            return lax.switch(index, branches, (chunk, h_in, ct, w_res))

        return work_fn

    def _lower_for(self, chunks: int, skip_chunks: tuple = ()):
        """Lower the configured schedule's timeline for ``chunks`` chunks
        (placement re-deviced; the lowering's ring check rejects anything
        the executors could not route). Under ``config.overlap != "off"``
        the timeline is first retimed to wire latency 2 so the lowering can
        emit the double-buffered (send, compute) index arrays;
        ``skip_chunks`` drops loss-free chunks and their dead ticks."""
        S = self.config.num_stages
        timeline = self.schedule.timeline(S, chunks)  # raises on bad (S, C)
        if self.placement is not None:
            timeline = self.placement.apply(timeline)
        latency = 1 if self.config.overlap == "off" else 2
        if latency != 1:
            timeline = retime_timeline(timeline, S, chunks, wire_latency=latency)
        return lower_timeline(
            timeline, S, chunks, wire_latency=latency, skip_chunks=skip_chunks
        )

    def _build_step_scheduled(
        self, widths: list[int], chunks: int, optimizer: opt_lib.Optimizer,
        skip_chunks: tuple = (),
    ):
        """One jitted train step executing the configured 1F1B/interleaved
        timeline: shard_map over the schedule's device count when the host
        has enough devices, else the lane-stacked substrate of the same
        dataflow (``spmd_pipeline_scheduled_lanes``).

        ``config.data_parallel`` (dp) > 1 widens the mesh to 2-D ``(data,
        stage)`` — the fsdp×stage composition the transformer ``Topology``
        runs, with graph-partition shards in place of batch shards: the
        stacked plan's leading chunk axis is sharded dp ways, replica ``r``
        pipelines its contiguous local chunks ``[r·C/dp, (r+1)·C/dp)``
        through the per-replica timeline, and the executor gathers the
        per-chunk gradient slots over the data axis to reduce them in the
        canonical GLOBAL chunk order — bit-identical to one replica (each
        (layer, chunk) gradient lives on exactly one replica and stage; see
        ``spmd_pipeline_scheduled``). Dropout keys stay global through the
        ``chunk_offset`` fold in the stage slices. With fewer than dp·ring
        devices the step falls back to the single-replica substrate over
        all chunks — the identical update, just not data-distributed."""
        S = self.config.num_stages
        dp = self.config.data_parallel
        if dp > 1 and chunks % dp:
            raise ValueError(
                f"chunks {chunks} must split evenly across data_parallel={dp} "
                f"replicas"
            )
        lowered = self._lower_for(
            chunks // dp if dp > 1 else chunks,
            skip_chunks if dp == 1 else (),
        )
        D = lowered.num_devices
        dp_active = dp > 1 and jax.device_count() >= dp * D
        if dp > 1 and not dp_active:
            lowered = self._lower_for(chunks)
            D = lowered.num_devices
        self._lowered[chunks] = lowered
        self._data_parallel_active = dp_active
        d_travel = travel_width(self._bounds, widths)

        spmd = dp_active or jax.device_count() >= D
        phases = set(np.unique(lowered.phase).tolist())

        def local(params, graph, labels, m, rng):
            offset = 0
            if dp_active:
                # graph/labels/m arrive as this replica's chunk shard and are
                # indexed by LOCAL chunk id; only the dropout-key fold needs
                # the global id (host-engine bitwise compatibility)
                offset = lax.axis_index("data") * (chunks // dp)
            work_fn = self._make_work_fn(
                widths, params, graph, labels, m, rng, phases=phases,
                chunk_offset=offset,
            )
            wire_like = jnp.zeros(
                (graph.features.shape[1], d_travel), graph.features.dtype
            )
            if spmd:
                return spmd_pipeline_scheduled(
                    work_fn, lowered, stage_axis="stage",
                    wire_like=wire_like, grads_like=params,
                    data_axis="data" if dp_active else None,
                )
            return spmd_pipeline_scheduled_lanes(
                work_fn, lowered, wire_like=wire_like, grads_like=params
            )

        if dp_active:
            grid = np.array(jax.devices()[: dp * D]).reshape(dp, D)
            p = self.placement
            if p is not None and p.device_order is not None and len(p.device_order) == D:
                # the ring's device order picks which column of each replica
                # row occupies which ring position
                grid = grid[:, list(p.device_order)]
            mesh = jax.sharding.Mesh(grid, ("data", "stage"))
            # check_vma=False: the executor's post-scan all_gather leaves the
            # gathered slots marked varying over "data" even though every
            # replica then reduces them to the same value; the old-API
            # shard_map (check_rep=False) never tracked this at all
            mapped = compat.shard_map(
                local, mesh=mesh,
                in_specs=(P(), P("data"), P("data"), P("data"), P()),
                out_specs=P(), check_vma=False,
            )
        elif spmd:
            mesh = jax.sharding.Mesh(self._mesh_devices(D), ("stage",))
            mapped = compat.shard_map(
                local, mesh=mesh, in_specs=(P(),) * 5, out_specs=P()
            )
        else:
            mapped = local

        def step(params, opt_state, graph, labels, loss_mask, rng):
            m = loss_mask.astype(jnp.float32)
            grads, loss_sum, count = mapped(params, graph, labels, m, rng)
            scale = 1.0 / jnp.maximum(count, 1.0)
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss_sum / jnp.maximum(count, 1.0)

        return jax.jit(step)

    def _build_eval_forward(self, widths: list[int], chunks: int):
        """One jitted forward-only program (no vjp, no optimizer): the
        fill-drain forward wave lowered through the same machinery as the
        train schedules (``forward_timeline`` + ``lower_timeline(...,
        forward_only=True)``) and executed by the scheduled executor's eval
        twin — the shard_map ring with enough devices, the lane-stacked
        substrate below it. Returns ``(jitted (params, graph) -> logp,
        mesh)``; the metric head lives on ``EvalProgram`` so the raw
        log-probabilities are directly servable."""
        S = self.config.num_stages
        items = forward_timeline(S, chunks)
        if self.placement is not None and self.placement.num_devices == S:
            # one-stage-per-device rings re-device the eval wave too; an
            # interleaved round-robin placement (D < S) would double-book
            # devices on the fill-drain forward wave, so eval keeps its own
            # S-device identity ring there (as it always has)
            items = self.placement.apply(items)
        lowered = lower_timeline(items, S, chunks, forward_only=True)
        D = lowered.num_devices
        d_travel = travel_width(self._bounds, widths)
        model, bounds = self.model, self._bounds
        spmd = jax.device_count() >= D

        def local(params, graph):
            # train=False: dropout is the identity, the rng is never consumed
            slices = make_gnn_stage_slices(
                model, bounds, widths, graph, jax.random.PRNGKey(0), train=False
            )
            zero_wire = jnp.zeros(
                (graph.features.shape[1], d_travel), graph.features.dtype
            )

            def idle(operand):
                return zero_wire

            def fwd(s):
                def branch(operand):
                    chunk, h_in = operand
                    return slices[s](params, chunk, h_in)

                return branch

            branches = [idle] + [fwd(s) for s in range(S)]

            def work_fn(phase, stage, chunk, h_in):
                index = jnp.where(phase == 0, 0, stage + 1)
                return lax.switch(index, branches, (chunk, h_in))

            if spmd:
                return spmd_pipeline_scheduled_eval(
                    work_fn, lowered, stage_axis="stage", wire_like=zero_wire
                )
            return spmd_pipeline_scheduled_eval_lanes(
                work_fn, lowered, wire_like=zero_wire
            )

        mesh = None
        if spmd:
            devs = self._mesh_devices(D) if D == S else np.array(jax.devices()[:D])
            mesh = jax.sharding.Mesh(devs, ("stage",))
            mapped = compat.shard_map(
                local, mesh=mesh, in_specs=(P(), P()), out_specs=P()
            )
        else:
            mapped = local

        def forward(params, graph):
            return mapped(params, graph)[..., : model.out_dim]

        return jax.jit(forward), mesh

    def compile_eval(self, params: list, graph) -> EvalProgram:
        """Compiled-eval handle for the shape of ``graph`` (a stacked pytree,
        leaves ``(chunks, n_pad, ...)``): one scheduled pipeline program per
        ``(chunks, n_pad, max_deg)`` bucket, cached for the engine's
        lifetime, with params bound (replicated once) on the handle."""
        if self._widths is None:
            chunk0 = jax.tree_util.tree_map(lambda a: a[0], graph)
            self._widths = activation_widths(self.model, params, chunk0)
        key = (
            graph.features.shape[0],
            graph.features.shape[1],
            graph.neighbors.shape[2],
        )
        prog = self._evals.get(key)
        if prog is None:
            fwd, mesh = self._build_eval_forward(self._widths, key[0])
            prog = EvalProgram(fwd, mesh, self.model.out_dim, key)
            self._evals[key] = prog
        return prog.bind(params)

    def _travel_inputs(self, stacked):
        """(travel pytree, loss_mask) for one stacked plan, cached. Only the
        activation buffer and the chunk id travel the wire; the stacked
        subgraphs enter the program as a replicated constant that branches
        dynamic-slice by chunk id (see ``make_gnn_stage``). The cache entry
        retains the StackedPlan itself — an id() key alone could be reused
        by a new same-shape plan after the old one is garbage-collected and
        silently serve the stale loss mask."""
        cached = self._travel_cache.get(id(stacked))
        if cached is not None and cached[0] is stacked:
            return cached[1], cached[2]
        C, n_pad = stacked.chunks, stacked.n_pad
        travel = {
            "h": jnp.zeros(
                (C, n_pad, travel_width(self._bounds, self._widths)),
                stacked.graph.features.dtype,
            ),
            "chunk": jnp.arange(C, dtype=jnp.int32),
        }
        loss_mask = stacked.graph.train_mask & stacked.core_mask
        self._travel_cache[id(stacked)] = (stacked, travel, loss_mask)
        return travel, loss_mask

    # -------------------------------------------------------------- step --

    def train_step(
        self,
        params: list,
        opt_state,
        plan: MicroBatchPlan,
        rng: jax.Array,
        optimizer: opt_lib.Optimizer,
        *,
        record: list | None = None,  # per-item timings don't exist in a fused program
        stats: dict | None = None,
    ):
        """One fused SPMD step over the stacked plan (compiled per
        ``(chunks, n_pad, max_deg, optimizer)`` shape key and cached)."""
        stacked = plan.stacked()
        graph = self.layout(stacked.graph)
        if self._widths is None:
            chunk0 = jax.tree_util.tree_map(lambda a: a[0], stacked.graph)
            self._widths = activation_widths(self.model, params, chunk0)
        skip: tuple = ()
        if not self._fill_drain:
            loss_mask = stacked.graph.train_mask & stacked.core_mask
            if self.config.data_parallel == 1:
                # chunks with no loss rows (ragged plans pad with empty
                # microbatches) contribute exactly-zero gradients and loss —
                # drop them so the lowering can eliminate their dead ticks.
                # dp > 1 keeps the full grid: one SPMD program cannot carry
                # per-replica tick counts.
                live = np.asarray(loss_mask.any(axis=tuple(range(1, loss_mask.ndim))))
                skip = tuple(int(c) for c in np.flatnonzero(~live))
        # the cache entry retains the optimizer: an id() key alone could be
        # reused by a new optimizer after the old one is garbage-collected,
        # silently serving a step jitted around stale hyperparameters
        key = (stacked.chunks, stacked.n_pad, stacked.max_deg, id(optimizer), skip)
        entry = self._steps.get(key)
        if entry is not None and entry[0] is optimizer:
            step = entry[1]
        elif self._fill_drain:
            step = self._build_step(self._widths, optimizer)
            self._steps[key] = (optimizer, step)
        else:
            step = self._build_step_scheduled(
                self._widths, stacked.chunks, optimizer, skip
            )
            self._steps[key] = (optimizer, step)
        if self._fill_drain:
            travel, loss_mask = self._travel_inputs(stacked)
        if stats is not None:
            stats.update(self.describe())
            if self._fill_drain:
                # fused fill-drain scan: every stage banks all C outputs
                stats["measured_peak_live_activations"] = None  # not observable
            else:
                lowered = self._lowered[stacked.chunks]
                # static accounting of the scheduled executor's stash: max
                # simultaneously banked stage inputs (stage-0 inputs are read
                # from the replicated feature table, never stashed)
                stats["measured_peak_live_activations"] = lowered.peak_live_stash
                stats["stash_slots_per_device"] = lowered.n_fslots
                stats["w_slots_per_device"] = lowered.n_wslots
                stats["num_ticks"] = lowered.num_ticks
                stats["wire_latency"] = lowered.wire_latency
        if self._fill_drain:
            return step(
                params, opt_state, travel, graph, stacked.graph.labels,
                loss_mask, rng,
            )
        return step(
            params, opt_state, graph, stacked.graph.labels, loss_mask, rng
        )


ENGINES = {"host": GPipe, "compiled": CompiledGNNPipeline}


def make_engine(model, config) -> PipelineEngine:
    """Engine factory: ``host`` (paper-faithful GPipe queue loop) or
    ``compiled`` (one jitted SPMD program), selected by ``config.engine``:

        make_engine(model, GPipeConfig(engine="compiled", balance=..., ...))

    ``config`` is either an assembled ``GPipeConfig`` or a planner
    ``PipelinePlan`` (``repro.core.autotune``) — a plan converts through its
    own ``to_config()``, so an ``--auto`` pick is directly replayable on
    either engine. Anything else is a ``TypeError``. (The pre-PR-6
    name-first ``make_engine(name, model, config)`` shim is gone; spell the
    engine via ``config.engine``.)"""
    from repro.core.autotune import PipelinePlan  # local: autotune imports us

    if isinstance(config, PipelinePlan):
        config = config.to_config()
    if not isinstance(config, GPipeConfig):
        raise TypeError(
            f"make_engine(model, config) expects a GPipeConfig or a "
            f"PipelinePlan, got {type(config).__name__}"
        )
    try:
        cls = ENGINES[config.engine]
    except KeyError:
        raise KeyError(
            f"unknown engine {config.engine!r}; have {tuple(ENGINES)}"
        ) from None
    return cls(model, config)
