"""One definition of the pipeline CLI surface.

The five engine/schedule flags (``--engine/--schedule/--chunks/--partition/
--placement``, plus their ``--stages`` / ``--pipe-devices`` companions) used
to be re-declared by every driver and benchmark — ``launch/train.py``,
``launch/serve_gnn.py``, ``benchmarks/fig3.py``, ``benchmarks/fig4.py`` and
the example each carried their own copy, free to drift. ``add_pipeline_args``
puts them on a parser once; ``PipelineCLIConfig`` is the parsed bundle, with
the two translations every caller was hand-rolling:

  * ``gpipe_config(balance)`` — the assembled ``GPipeConfig`` that
    ``make_engine`` consumes (placement string parsed, interleaved's
    default 2-device ring applied, engine name riding along);
  * ``namespace(**extra)`` — an argparse-shaped namespace for drivers such
    as ``run_gnn`` that are invoked programmatically (the benchmarks build
    their sweep cells this way instead of via ``types.SimpleNamespace``
    literals).

``benchmarks/common.py`` re-exports both names for the benchmark scripts.
"""

from __future__ import annotations

import dataclasses
import types

from repro.core.pipeline import GPipeConfig
from repro.core.schedule import Placement

ENGINE_CHOICES = ("host", "compiled")
SCHEDULE_CHOICES = ("fill_drain", "gpipe", "1f1b", "interleaved", "zb-h1", "zb-v")
PARTITION_CHOICES = ("uniform", "profiled")
BACKEND_CHOICES = ("padded", "dense", "pallas")
OVERLAP_CHOICES = ("off", "double-buffer", "async")

# layer-count split of the 6-layer sequential paper model
UNIFORM_BALANCES = {2: (3, 3), 3: (2, 2, 2), 4: (2, 1, 1, 2), 6: (1,) * 6}


def add_pipeline_args(
    ap,
    *,
    engine: str = "host",
    schedule: str = "fill_drain",
    chunks: int = 1,
    stages: int = 1,
    backend: str = "padded",
):
    """Declare the pipeline flag set on ``ap`` (an ``argparse`` parser or
    group). Keyword defaults let each driver keep its own entry point
    defaults (training starts on the host engine, serving on compiled)."""
    ap.add_argument("--engine", default=engine, choices=list(ENGINE_CHOICES),
                    help="pipeline engine: host-driven GPipe queue loop or "
                         "one compiled SPMD program (shard_map/ppermute); both "
                         "accept any --schedule")
    ap.add_argument("--schedule", default=schedule, choices=list(SCHEDULE_CHOICES))
    ap.add_argument("--stages", type=int, default=stages)
    ap.add_argument("--chunks", type=int, default=chunks)
    ap.add_argument("--pipe-devices", type=int, default=None,
                    help="interleaved: physical devices (virtual stages = stages/devices)")
    ap.add_argument("--partition", default="uniform", choices=list(PARTITION_CHOICES),
                    help="stage balance: layer-count split or the cost-model "
                         "partitioner (profiles per-layer fwd/B/W on a padded chunk, "
                         "minimizes the schedule's weighted makespan)")
    ap.add_argument("--placement", default=None,
                    help="stage->device ring placement as comma ints, e.g. "
                         "'1,2,3,0' (validated against the lowering's ring check)")
    ap.add_argument("--backend", default=backend, choices=list(BACKEND_CHOICES),
                    help="aggregation backend for the GNN layers: padded "
                         "neighbor gathers (default), dense masked adjacency, "
                         "or the Pallas kernels over the degree-bucketed "
                         "layout")
    ap.add_argument("--data-parallel", type=int, default=1,
                    help="graph-partition replicas on the data axis of a "
                         "(data, stage) mesh (compiled engine): chunks are "
                         "sharded data_parallel ways, gradients reduced over "
                         "the axis in the canonical chunk order, so the "
                         "update stays bit-identical to 1 replica")
    ap.add_argument("--overlap", default="off", choices=list(OVERLAP_CHOICES),
                    help="communication/compute overlap (compiled engine): "
                         "double-buffer retimes the tick arrays so each "
                         "ppermute pair is posted one tick before its "
                         "arrivals are consumed (bit-identical updates); "
                         "async additionally requests XLA's latency-hiding "
                         "scheduler (core.overlap_report)")
    ap.add_argument("--auto", action="store_true",
                    help="self-tuning planner (core.autotune.plan_pipeline): "
                         "profile per-layer costs once, enumerate schedule x "
                         "chunks x balance x placement, pick the argmin "
                         "predicted step time — overrides --schedule/--chunks/"
                         "--partition/--placement")
    ap.add_argument("--auto-budget", type=int, default=None,
                    help="cap on the number of candidate configurations the "
                         "--auto planner evaluates (ranked enumeration order; "
                         "default: exhaustive)")
    ap.add_argument("--dry-run", action="store_true",
                    help="with --auto: print the ranked candidate table and "
                         "exit without training")
    return ap


@dataclasses.dataclass
class PipelineCLIConfig:
    """The parsed pipeline flag bundle — every driver/benchmark's single
    route from CLI-level knobs to an assembled ``GPipeConfig``."""

    engine: str = "host"
    schedule: str = "fill_drain"
    chunks: int = 1
    stages: int = 1
    partition: str = "uniform"
    placement: str | None = None
    pipe_devices: int | None = None
    backend: str = "padded"
    data_parallel: int = 1
    overlap: str = "off"
    auto: bool = False
    auto_budget: int | None = None
    dry_run: bool = False

    @classmethod
    def from_args(cls, args) -> "PipelineCLIConfig":
        """Lift the flag set off an argparse namespace (missing attributes
        fall back to the flag defaults, so programmatic namespaces may stay
        minimal)."""
        d = {f.name: getattr(args, f.name, f.default) for f in dataclasses.fields(cls)}
        return cls(**d)

    @property
    def resolved_pipe_devices(self) -> int | None:
        """--pipe-devices with the round-robin default applied: interleaved
        and zb-v place V = stages/2 virtual stages on 2 physical devices
        unless told otherwise."""
        if self.schedule in ("interleaved", "zb-v") and self.pipe_devices is None:
            return 2
        return self.pipe_devices

    def parsed_placement(self) -> Placement | None:
        """The --placement comma string as a validated ``Placement``."""
        if not self.placement:
            return None
        return Placement(tuple(int(x) for x in self.placement.split(",")))

    def uniform_balance(self) -> tuple[int, ...]:
        """The layer-count split of the 6-layer paper model for --stages."""
        try:
            return UNIFORM_BALANCES[self.stages]
        except KeyError:
            raise ValueError(
                f"--stages {self.stages} has no uniform split of the 6-layer "
                f"paper model; supported: {sorted(UNIFORM_BALANCES)}"
            ) from None

    def gpipe_config(self, balance=None) -> GPipeConfig:
        """The assembled engine config. ``balance`` defaults to the uniform
        layer-count split; the profiled partitioner passes its own."""
        return GPipeConfig(
            balance=tuple(balance if balance is not None else self.uniform_balance()),
            chunks=self.chunks,
            schedule=self.schedule,
            num_devices=self.resolved_pipe_devices,
            placement=self.parsed_placement(),
            engine=self.engine,
            backend=self.backend,
            data_parallel=self.data_parallel,
            overlap=self.overlap,
        )

    def namespace(self, **extra) -> types.SimpleNamespace:
        """An argparse-shaped namespace carrying this flag set plus
        driver-specific extras — how the benchmarks invoke ``run_gnn``."""
        d = dataclasses.asdict(self)
        d.update(extra)
        return types.SimpleNamespace(**d)
