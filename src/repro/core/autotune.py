"""Self-tuning pipeline planner: one ``--auto`` knob resolves the rest.

The repo exposes four interacting pipeline knobs — schedule, chunk count,
stage balance (partition) and placement — and PR 5's cost model already
predicts every fig3 cell from a per-layer profile. This module closes the
loop (the ROADMAP's named frontier item): ``plan_pipeline`` enumerates the
(schedule x chunk-count x balance x placement) space through
``profile_layer_costs`` / ``predicted_balance_time``, prunes candidates
whose peak live activations exceed the memory constraint, and picks the
argmin predicted step time. GraphPipe (Jeon et al., 2024) and GNNPipe
(Chen et al., 2023) both show the search over pipeline configurations —
not any single hand-written one — is where the remaining throughput lives.

Planner dataflow (see docs/ARCHITECTURE.md "Autotuning"):

    profile   — per-layer fwd/B/W costs on one representative padded chunk
                per candidate chunk count (the exact shape the engines
                dispatch per tick), via the costmodel's sidecar-cached
                profiler so a sweep never re-measures a shape;
    enumerate — schedule x chunk-count x balance x placement-rotation, in a
                deterministic order, capped by ``budget``;
    predict   — each candidate's weighted makespan through the schedule's
                own ``predicted_step_time`` (zero-bubble schedules get the
                measured B/W split);
    pick      — argmin predicted step time over the feasible candidates,
                ties broken by the documented total order (see
                ``plan_pipeline``);
    verify    — the fig3 ``auto/*`` rows measure the pick against the best
                hand-picked config and gate the prediction error in CI
                (``benchmarks/check_perf.py``).

The resolved choice is a ``PipelinePlan`` — inspectable (``table`` /
``format_table`` print the ranked candidates, the ``--auto --dry-run``
surface) and replayable (``make_engine(model, plan)`` accepts it directly,
or ``plan.to_config()`` yields the plain ``GPipeConfig``).
"""

from __future__ import annotations

import dataclasses
import math

import jax

from repro.core.costmodel import (
    LayerCosts,
    cached_profile_layer_costs,
    enumerate_balances,
    predicted_balance_time,
    uniform_balance,
)
from repro.core.pipeline import GPipeConfig
from repro.core.schedule import Placement, get_schedule

# the planner's search space: every trainable schedule in the registry
# ("gpipe" is an alias of fill_drain, so it is not enumerated twice)
PLAN_SCHEDULES = ("fill_drain", "1f1b", "interleaved", "zb-h1", "zb-v")

#: chunk counts enumerated by default (a power-of-two ladder around the
#: paper's 4-chunk operating point)
DEFAULT_CHUNK_COUNTS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class PlanConstraints:
    """The search-space bounds ``plan_pipeline`` enumerates under.

    ``num_stages`` fixes the balance length (the paper's 6-layer model has
    no uniform split for arbitrary stage counts, but the planner enumerates
    ALL contiguous balances, so any 1 <= S <= n_layers works).
    ``max_devices`` prunes candidates needing a wider ring than the host
    has; ``max_live_activations`` prunes by the schedule's peak-live
    accounting (the memory gate); ``budget`` caps how many candidate
    configurations are enumerated (deterministic order, so a truncated
    search is still reproducible); ``rotations`` adds the ring-rotation
    placement axis (predicted time is placement-invariant in the model, so
    rotations only ever lose ties to the schedule's default placement —
    they are enumerated to keep the axis inspectable, and prunable via
    ``budget``)."""

    num_stages: int = 4
    chunk_counts: tuple[int, ...] = DEFAULT_CHUNK_COUNTS
    schedules: tuple[str, ...] = PLAN_SCHEDULES
    max_devices: int | None = None
    max_live_activations: int | None = None
    budget: int | None = None
    transfer_cost: float = 0.0
    rotations: bool = True


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One enumerated configuration with its prediction (or prune reason).

    ``pruned`` is ``None`` for feasible candidates; otherwise the
    human-readable reason the candidate was excluded from the argmin
    (illegal schedule combo, memory bound, device bound). Pruned candidates
    carry ``predicted_step_s = inf`` and rank after every feasible one."""

    schedule: str
    chunks: int
    balance: tuple[int, ...]
    num_devices: int | None  # pipe devices for round-robin schedules
    rotation: int  # ring rotation; 0 = the schedule's default placement
    predicted_step_s: float
    peak_live: int
    pruned: str | None = None

    def row(self) -> dict:
        """The candidate as a flat dict (benchmark artifact / JSON)."""
        return {
            "schedule": self.schedule,
            "chunks": self.chunks,
            "balance": list(self.balance),
            "num_devices": self.num_devices,
            "rotation": self.rotation,
            "predicted_step_s": self.predicted_step_s,
            "peak_live": self.peak_live,
            "pruned": self.pruned,
        }


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A fully-resolved pipeline configuration: the planner's pick plus the
    ranked candidate table it was chosen from.

    Both engines accept a plan directly (``make_engine(model, plan)``);
    ``to_config`` assembles the equivalent ``GPipeConfig`` with any field
    overridden — the replay path for a pick logged by an earlier run."""

    schedule: str
    chunks: int
    balance: tuple[int, ...]
    num_devices: int | None
    placement: Placement | None
    predicted_step_s: float
    costs: LayerCosts | None
    candidates: tuple[PlanCandidate, ...]  # ranked: best first, pruned last
    evaluated: int  # candidates actually evaluated (budget may truncate)
    truncated: bool  # True when the budget cut enumeration short
    engine: str = "compiled"
    backend: str = "padded"
    data_parallel: int = 1
    overlap: str = "off"

    @property
    def num_stages(self) -> int:
        """Pipeline stages (= entries in ``balance``)."""
        return len(self.balance)

    def to_config(self, **overrides) -> GPipeConfig:
        """The plan as a plain ``GPipeConfig`` (``overrides`` win — e.g.
        ``to_config(engine="host")`` replays the pick on the other
        engine)."""
        kw = dict(
            balance=self.balance,
            chunks=self.chunks,
            schedule=self.schedule,
            num_devices=self.num_devices,
            placement=self.placement,
            engine=self.engine,
            backend=self.backend,
            data_parallel=self.data_parallel,
            overlap=self.overlap,
        )
        kw.update(overrides)
        return GPipeConfig(**kw)

    def table(self, limit: int | None = None) -> list[dict]:
        """The ranked candidate rows (``limit`` trims to the head)."""
        cands = self.candidates if limit is None else self.candidates[:limit]
        return [dict(c.row(), rank=i) for i, c in enumerate(cands)]

    def format_table(self, limit: int | None = 10) -> str:
        """The ranked candidate table as aligned text — what ``--auto
        --dry-run`` prints (mirrors the ``--partition profiled`` table)."""
        lines = [
            f"[auto] evaluated {self.evaluated} candidates"
            + (" (budget-truncated)" if self.truncated else "")
            + f"; pick: schedule={self.schedule} chunks={self.chunks} "
            f"balance={self.balance} devices={self.num_devices or len(self.balance)} "
            f"rotation={0 if self.placement is None else '-'.join(map(str, self.placement.stage_to_device))} "
            f"predicted_step={self.predicted_step_s * 1e3:.3f}ms",
            f"  {'rank':>4} {'schedule':<12} {'chunks':>6} {'devices':>7} "
            f"{'balance':<12} {'rot':>3} {'pred_ms':>9} {'peak_live':>9}  note",
        ]
        for row in self.table(limit):
            bal = "-".join(map(str, row["balance"]))
            pred = (
                f"{row['predicted_step_s'] * 1e3:9.3f}"
                if math.isfinite(row["predicted_step_s"])
                else f"{'-':>9}"
            )
            note = row["pruned"] or ""
            devices = row["num_devices"] or len(row["balance"])
            lines.append(
                f"  {row['rank']:>4} {row['schedule']:<12} {row['chunks']:>6} "
                f"{devices:>7} {bal:<12} {row['rotation']:>3} {pred} "
                f"{row['peak_live']:>9}  {note}"
            )
        if limit is not None and len(self.candidates) > limit:
            lines.append(f"  ... {len(self.candidates) - limit} more candidates")
        return "\n".join(lines)


def _device_options(name: str, num_stages: int):
    """The pipe-device counts a schedule can place ``num_stages`` on:
    round-robin schedules (interleaved, zb-v) take any proper divisor of S
    (V >= 2 virtual stages per device); the rest place one stage per
    device."""
    if name in ("interleaved", "zb-v"):
        return [d for d in range(1, num_stages) if num_stages % d == 0]
    return [None]


def plan_pipeline(
    model,
    graph,
    constraints: PlanConstraints | None = None,
    *,
    params=None,
    rng=None,
    strategy: str = "sequential",
    halo_hops: int = 2,
    seed: int = 0,
    costs_by_chunks: dict[int, LayerCosts] | None = None,
    cache_path: str | None = None,
    engine: str = "compiled",
    backend: str = "padded",
    data_parallel: int = 1,
    overlap: str = "off",
    profile_repeats: int = 3,
    profile_warmup: int = 1,
) -> PipelinePlan:
    """Resolve (schedule x chunks x balance x placement) by prediction.

    For each candidate chunk count a representative padded chunk of THIS
    graph is profiled (``cached_profile_layer_costs`` — the sidecar cache
    means a sweep profiles each (model, chunk shape, backend) once), every
    contiguous balance is priced through the schedule's own weighted
    makespan (``predicted_balance_time``: zero-bubble schedules get the
    measured B/W split), candidates over the memory bound are pruned, and
    the argmin predicted step time wins.

    The tie-break is a documented total order, so the argmin is stable
    under tied candidates: lower predicted time, then lower peak-live
    activations, then fewer chunks, then the caller's schedule order, then
    the uniform balance before any other, then lexicographic balance, then
    fewer pipe devices, then the schedule's default placement (rotation 0)
    before any rotation.

    ``costs_by_chunks`` injects pre-measured ``LayerCosts`` per chunk count
    (tests and replay skip profiling entirely); ``graph`` may then be
    ``None``.
    """
    cons = constraints or PlanConstraints()
    S = cons.num_stages
    n_layers = len(model.layers)
    if not 1 <= S <= n_layers:
        raise ValueError(
            f"num_stages must satisfy 1 <= S <= {n_layers} layers, got {S}"
        )
    uniform = uniform_balance(n_layers, S)
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))
    if rng is None:
        rng = jax.random.PRNGKey(seed)

    costs_cache: dict[int, LayerCosts] = dict(costs_by_chunks or {})

    def costs_for(C: int) -> LayerCosts:
        if C not in costs_cache:
            if graph is None:
                raise ValueError(
                    f"no costs_by_chunks entry for chunks={C} and no graph "
                    f"to profile on"
                )
            from repro.core.microbatch import make_plan

            plan = make_plan(graph, C, strategy=strategy, halo_hops=halo_hops, seed=seed)
            chunk0 = jax.tree_util.tree_map(lambda a: a[0], plan.stacked().graph)
            costs_cache[C] = cached_profile_layer_costs(
                model, params, chunk0, backend=backend, cache_path=cache_path,
                rng=rng, repeats=profile_repeats, warmup=profile_warmup,
            )
        return costs_cache[C]

    candidates: list[tuple[tuple, PlanCandidate]] = []
    evaluated = 0
    truncated = False

    def budget_left() -> bool:
        return cons.budget is None or evaluated < cons.budget

    for sched_idx, name in enumerate(cons.schedules):
        if truncated:
            break
        for C in cons.chunk_counts:
            if truncated:
                break
            if C % data_parallel:
                candidates.append((
                    (math.inf, 0, C, sched_idx, False, (), 0, 0),
                    PlanCandidate(name, C, uniform, None, 0, math.inf, 0,
                                  pruned=f"chunks {C} not divisible by "
                                         f"data_parallel {data_parallel}"),
                ))
                continue
            for nd in _device_options(name, S):
                D = nd if nd is not None else S
                if cons.max_devices is not None and D > cons.max_devices:
                    candidates.append((
                        (math.inf, 0, C, sched_idx, False, (), D, 0),
                        PlanCandidate(name, C, uniform, nd, 0, math.inf, 0,
                                      pruned=f"needs {D} devices > "
                                             f"max_devices {cons.max_devices}"),
                    ))
                    continue
                try:
                    sched = get_schedule(name, num_devices=nd)
                    peak = sched.peak_live_activations(S, C)
                except ValueError as e:
                    candidates.append((
                        (math.inf, 0, C, sched_idx, False, (), D, 0),
                        PlanCandidate(name, C, uniform, nd, 0, math.inf, 0,
                                      pruned=str(e)),
                    ))
                    continue
                if (
                    cons.max_live_activations is not None
                    and peak > cons.max_live_activations
                ):
                    candidates.append((
                        (math.inf, peak, C, sched_idx, False, (), D, 0),
                        PlanCandidate(name, C, uniform, nd, 0, math.inf, peak,
                                      pruned=f"peak_live {peak} > "
                                             f"max {cons.max_live_activations}"),
                    ))
                    continue
                rotations = range(D) if cons.rotations else (0,)
                for bal in enumerate_balances(n_layers, S):
                    if not budget_left():
                        truncated = True
                        break
                    t = predicted_balance_time(
                        costs_for(C), bal, sched, C,
                        transfer_cost=cons.transfer_cost,
                    )
                    for rot in rotations:
                        if not budget_left():
                            truncated = True
                            break
                        evaluated += 1
                        key = (t, peak, C, sched_idx, bal != uniform, bal, D, rot)
                        candidates.append((
                            key,
                            PlanCandidate(name, C, bal, nd, rot, t, peak),
                        ))
                    if truncated:
                        break
                if truncated:
                    break

    candidates.sort(key=lambda kc: kc[0])
    ranked = tuple(c for _, c in candidates)
    feasible = [c for c in ranked if c.pruned is None]
    if not feasible:
        raise ValueError(
            "plan_pipeline: every candidate was pruned or illegal — relax "
            "the constraints (see PipelinePlan candidates for reasons): "
            + "; ".join(sorted({c.pruned for c in ranked if c.pruned}))
        )
    best = feasible[0]
    D = best.num_devices if best.num_devices is not None else S
    placement = (
        None
        if best.rotation == 0
        else Placement.ring(S, best.num_devices, rotation=best.rotation)
    )
    return PipelinePlan(
        schedule=best.schedule,
        chunks=best.chunks,
        balance=best.balance,
        num_devices=best.num_devices,
        placement=placement,
        predicted_step_s=best.predicted_step_s,
        costs=costs_cache.get(best.chunks),
        candidates=ranked,
        evaluated=evaluated,
        truncated=truncated,
        engine=engine,
        backend=backend,
        data_parallel=data_parallel,
        overlap=overlap,
    )


def plan_for_cli(
    model,
    graph,
    cli,
    *,
    params=None,
    rng=None,
    strategy: str = "sequential",
    seed: int = 0,
    cache_path: str | None = None,
    costs_by_chunks: dict[int, LayerCosts] | None = None,
) -> PipelinePlan:
    """``plan_pipeline`` parameterized by a ``PipelineCLIConfig`` — the one
    translation every ``--auto`` entry point (train / fig3 / fig4 / example
    / serve) shares. ``--stages`` fixes the balance length (default: the
    paper's 4-stage pipeline when the flag is at its single-device
    default); ``--auto-budget`` caps the enumeration; the engine / backend /
    data-parallel / overlap flags ride into the plan untouched — the
    planner resolves schedule, chunks, balance and placement, nothing
    else."""
    stages = cli.stages if cli.stages > 1 else 4
    chunk_counts = tuple(sorted(set(DEFAULT_CHUNK_COUNTS) | {cli.chunks}))
    cons = PlanConstraints(
        num_stages=stages,
        chunk_counts=chunk_counts,
        budget=cli.auto_budget,
    )
    return plan_pipeline(
        model,
        graph,
        cons,
        params=params,
        rng=rng,
        strategy=strategy,
        seed=seed,
        costs_by_chunks=costs_by_chunks,
        cache_path=cache_path,
        engine=cli.engine,
        backend=cli.backend,
        data_parallel=cli.data_parallel,
        overlap=cli.overlap,
    )
