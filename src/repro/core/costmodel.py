"""Profiling-driven stage partitioning for the GNN pipeline.

The paper's Fig 3 runtime model (and ``Schedule.predicted_step_time``'s
default) assumes every stage costs ``total / num_stages`` — but real GNN
stacks are heterogeneous (a 1433-wide input conv next to an 8-wide hidden
conv, attention next to dropout), so the slowest stage sets the pipeline
tick and the balanced model silently diverges from measurement. GNNPipe
(Chen et al. 2023) and GraphPipe (Jeon et al. 2024) both show cost-aware
partitioning — not just a better tick order — is where pipelined GNN
training wins. This module supplies that layer:

  * ``profile_layer_costs`` — measure each ``SeqLayer``'s forward,
    input-grad (B) and weight-grad (W) cost over the REAL jitted slices on
    a representative padded chunk, exactly the work the engines dispatch;
  * ``choose_balance`` — enumerate contiguous layer->stage groupings and
    pick the one minimizing the target schedule's *weighted* makespan
    (``predicted_step_time(stage_fwd_costs=..., stage_bwd_costs=...)``, the
    ``_weighted`` hooks that previously only ever saw uniform costs);
  * ``uniform_balance`` — the layer-count split the profiled partition is
    benchmarked against.

The output is an ordinary ``balance`` tuple, so the partitioner composes
with every engine, schedule and ``Placement`` unchanged — partitioning
moves layer boundaries, never the math (property-tested: any balance
produces updates bit-identical to the host fill-drain baseline).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.graphs.data import GraphBatch
from repro.models.gnn.net import GNNModel


# eq=False: float tuples would compare fine, but cost tables are measurement
# artifacts — identity semantics keep accidental == out of test assertions.
@dataclasses.dataclass(frozen=True, eq=False)
class LayerCosts:
    """Measured per-layer per-chunk costs (seconds) on one padded chunk.

    ``bwd`` is the fused backward — ONE vjp producing both grads, exactly
    what the fused-``bwd`` schedules execute. It is measured directly, not
    summed from the halves: each split half replays the layer's forward
    primal, so ``bwd_b + bwd_w`` carries two primals where the fused vjp
    carries one (the halves match the real zb-h1 execution, which does
    re-materialize per half; the fused number matches everything else).
    """

    names: tuple[str, ...]
    fwd: tuple[float, ...]
    bwd: tuple[float, ...]  # fused backward: one vjp, both grads
    bwd_b: tuple[float, ...]  # input-grad half (the pipeline's critical path)
    bwd_w: tuple[float, ...]  # weight-grad half (deferred by zb-h1)

    def _check_balance(self, balance: tuple[int, ...]):
        if sum(balance) != len(self.names):
            raise ValueError(
                f"balance {balance} must sum to {len(self.names)} layers"
            )

    def stage_costs(self, balance: tuple[int, ...]):
        """(stage_fwd_costs, stage_bwd_costs) for a contiguous ``balance``
        grouping — each stage's cost is the sum of its member layers'
        (``bwd`` = the measured fused backward)."""
        self._check_balance(balance)
        f, b, lo = [], [], 0
        for n in balance:
            f.append(sum(self.fwd[lo : lo + n]))
            b.append(sum(self.bwd[lo : lo + n]))
            lo += n
        return f, b

    def stage_costs_split(self, balance: tuple[int, ...]):
        """(fwd, bwd_b, bwd_w) per-stage sums — the measured B/W halves the
        zero-bubble makespan weights separately (B is critical-path, W is
        bubble filler; a 50/50 assumption misprices e.g. a wide input conv
        whose weight grad dominates its input grad)."""
        self._check_balance(balance)
        f, b, w, lo = [], [], [], 0
        for n in balance:
            f.append(sum(self.fwd[lo : lo + n]))
            b.append(sum(self.bwd_b[lo : lo + n]))
            w.append(sum(self.bwd_w[lo : lo + n]))
            lo += n
        return f, b, w

    def table(self) -> list[dict]:
        """The per-layer cost table (benchmark artifact / CLI printout)."""
        return [
            {
                "layer": i,
                "name": self.names[i],
                "fwd_s": self.fwd[i],
                "bwd_s": self.bwd[i],
                "bwd_b_s": self.bwd_b[i],
                "bwd_w_s": self.bwd_w[i],
            }
            for i in range(len(self.names))
        ]


def _time_best_of(fn, args, *, repeats: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def profile_layer_costs(
    model: GNNModel,
    params: list,
    graph: GraphBatch,
    *,
    rng: jax.Array | None = None,
    train: bool = True,
    repeats: int = 3,
    warmup: int = 1,
) -> LayerCosts:
    """Measure fwd / input-grad / weight-grad cost of every ``SeqLayer`` on
    ``graph`` (one representative padded chunk — the same shape the engines
    dispatch per tick, so stage sums predict per-tick stage costs).

    Each layer is timed through its own jitted callable: forward is the
    layer's ``apply``; the halves are explicit ``jax.vjp``s wrt the input
    and wrt the params — precisely the slices the scheduled executor's
    ``bwd_b`` / ``bwd_w`` branches differentiate. Best-of-``repeats`` with
    ``warmup`` discarded compile runs.
    """
    if rng is None:
        rng = jax.random.PRNGKey(0)
    fwd_s, bwd_s, b_s, w_s = [], [], [], []
    h = graph.features
    for layer, p in zip(model.layers, params):
        def fwd(p_, h_, L=layer):
            return L.apply(p_, graph, h_, rng, train)

        def bwd(p_, h_, ct, L=layer):
            # the fused backward: ONE vjp, one primal, both grads
            _, vjp = jax.vjp(lambda pp, hh: L.apply(pp, graph, hh, rng, train), p_, h_)
            return vjp(ct)

        def bwd_b(p_, h_, ct, L=layer):
            _, vjp = jax.vjp(lambda hh: L.apply(p_, graph, hh, rng, train), h_)
            return vjp(ct)[0]

        def bwd_w(p_, h_, ct, L=layer):
            _, vjp = jax.vjp(lambda pp: L.apply(pp, graph, h_, rng, train), p_)
            return vjp(ct)[0]

        fwd_j = jax.jit(fwd)
        y = jax.block_until_ready(fwd_j(p, h))
        ct = jnp.ones_like(y)
        fwd_s.append(_time_best_of(fwd_j, (p, h), repeats=repeats, warmup=warmup))
        bwd_s.append(
            _time_best_of(jax.jit(bwd), (p, h, ct), repeats=repeats, warmup=warmup)
        )
        b_s.append(
            _time_best_of(jax.jit(bwd_b), (p, h, ct), repeats=repeats, warmup=warmup)
        )
        w_s.append(
            _time_best_of(jax.jit(bwd_w), (p, h, ct), repeats=repeats, warmup=warmup)
        )
        h = y
    return LayerCosts(
        names=tuple(layer.name for layer in model.layers),
        fwd=tuple(fwd_s),
        bwd=tuple(bwd_s),
        bwd_b=tuple(b_s),
        bwd_w=tuple(w_s),
    )


def profile_fingerprint(model, params, graph, backend: str = "padded") -> str:
    """The cache key a profile is stored under: a digest of the model's
    layer stack (names + every param leaf's shape/dtype), the chunk shape
    the engines dispatch per tick, and the aggregation backend. Two runs
    measuring the same (model, chunk shape, backend) triple re-measure the
    same jitted programs, so their costs are interchangeable — anything
    else (different widths, padding, backend lowering) is a different
    key."""
    spec = {
        "layers": [layer.name for layer in model.layers],
        "params": [
            [(list(a.shape), str(a.dtype)) for a in jax.tree_util.tree_leaves(p)]
            for p in params
        ],
        "chunk": [
            list(graph.features.shape),
            list(graph.neighbors.shape),
        ],
        "backend": backend,
    }
    return hashlib.sha1(json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]


# in-process profile cache: fingerprint -> LayerCosts. One sweep (fig3's
# matrix, the --auto planner's chunk ladder) profiles each shape once.
_PROFILE_CACHE: dict[str, LayerCosts] = {}


def cached_profile_layer_costs(
    model,
    params,
    graph,
    *,
    backend: str = "padded",
    cache_path: str | None = None,
    refresh: bool = False,
    **profile_kwargs,
) -> LayerCosts:
    """``profile_layer_costs`` behind a two-level cache keyed by
    ``profile_fingerprint`` (model layer stack + chunk shape + backend):

      * an in-process dict, so ``--auto`` and ``--partition profiled``
        never re-profile the same shape within a run;
      * an optional JSON sidecar at ``cache_path``, so a benchmark sweep
        (fig3's ``args.layer_costs`` pass-through) reuses measurements
        across processes — and ships them as an artifact.

    ``refresh=True`` bypasses both reads (the write still lands, replacing
    the stale entry). Corrupt or unreadable sidecars are ignored, never
    fatal: the profiler is the fallback."""
    key = profile_fingerprint(model, params, graph, backend)
    if not refresh:
        hit = _PROFILE_CACHE.get(key)
        if hit is not None:
            return hit
        if cache_path and os.path.exists(cache_path):
            try:
                with open(cache_path) as f:
                    entry = json.load(f).get(key)
            except (OSError, json.JSONDecodeError):
                entry = None
            if entry is not None:
                costs = LayerCosts(
                    names=tuple(entry["names"]),
                    fwd=tuple(entry["fwd"]),
                    bwd=tuple(entry["bwd"]),
                    bwd_b=tuple(entry["bwd_b"]),
                    bwd_w=tuple(entry["bwd_w"]),
                )
                _PROFILE_CACHE[key] = costs
                return costs
    costs = profile_layer_costs(model, params, graph, **profile_kwargs)
    _PROFILE_CACHE[key] = costs
    if cache_path:
        store: dict = {}
        if os.path.exists(cache_path):
            try:
                with open(cache_path) as f:
                    store = json.load(f)
            except (OSError, json.JSONDecodeError):
                store = {}
        store[key] = {
            "names": list(costs.names),
            "fwd": list(costs.fwd),
            "bwd": list(costs.bwd),
            "bwd_b": list(costs.bwd_b),
            "bwd_w": list(costs.bwd_w),
        }
        parent = os.path.dirname(cache_path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        tmp = f"{cache_path}.tmp"
        with open(tmp, "w") as f:
            json.dump(store, f, indent=1, sort_keys=True)
        os.replace(tmp, cache_path)
    return costs


def uniform_balance(n_layers: int, num_stages: int) -> tuple[int, ...]:
    """The layer-COUNT-balanced contiguous split (earlier stages take the
    remainder) — the baseline the profiled partition is measured against."""
    if not 1 <= num_stages <= n_layers:
        raise ValueError(f"need 1 <= num_stages <= {n_layers}, got {num_stages}")
    base, rem = divmod(n_layers, num_stages)
    return tuple(base + (1 if s < rem else 0) for s in range(num_stages))


def enumerate_balances(n_layers: int, num_stages: int):
    """All contiguous groupings of ``n_layers`` into ``num_stages`` non-empty
    stages, as balance tuples (C(n-1, S-1) of them)."""
    for cuts in itertools.combinations(range(1, n_layers), num_stages - 1):
        bounds = (0, *cuts, n_layers)
        yield tuple(bounds[i + 1] - bounds[i] for i in range(num_stages))


def predicted_balance_time(
    costs: LayerCosts,
    balance: tuple[int, ...],
    schedule,
    num_chunks: int,
    *,
    transfer_cost: float = 0.0,
) -> float:
    """``schedule``'s weighted makespan under ``costs`` grouped by
    ``balance`` (seconds per step, rebuild excluded — it is
    partition-independent). Zero-bubble schedules get the MEASURED B/W
    halves instead of the 50/50 fallback split."""
    from repro.core.schedule import ZeroBubbleH1Schedule

    if isinstance(schedule, ZeroBubbleH1Schedule):
        f, b, w = costs.stage_costs_split(balance)
        return schedule.predicted_step_time(
            len(balance),
            num_chunks,
            stage_fwd_costs=f,
            stage_bwd_b_costs=b,
            stage_bwd_w_costs=w,
            transfer_cost=transfer_cost,
        )
    f, b = costs.stage_costs(balance)
    return schedule.predicted_step_time(
        len(balance),
        num_chunks,
        stage_fwd_costs=f,
        stage_bwd_costs=b,
        transfer_cost=transfer_cost,
    )


def choose_balance(
    costs: LayerCosts,
    num_stages: int,
    schedule,
    num_chunks: int,
    *,
    transfer_cost: float = 0.0,
    max_candidates: int = 100_000,
) -> tuple[tuple[int, ...], float]:
    """The contiguous balance minimizing ``schedule``'s weighted makespan
    under the measured costs. Exhaustive over the C(n-1, S-1) candidates
    (ties break toward the uniform split, then lexicographically) — GNN
    stacks are tens of layers, not thousands; ``max_candidates`` guards the
    combinatorial cliff with a clear error instead of a silent hang.
    Returns (balance, predicted_step_seconds)."""
    n = len(costs.names)
    n_cand = math.comb(n - 1, num_stages - 1)
    if n_cand > max_candidates:
        raise ValueError(
            f"{n_cand} candidate partitions of {n} layers into {num_stages} "
            f"stages exceeds max_candidates={max_candidates}"
        )
    uniform = uniform_balance(n, num_stages)
    best: tuple | None = None
    for bal in enumerate_balances(n, num_stages):
        t = predicted_balance_time(
            costs, bal, schedule, num_chunks, transfer_cost=transfer_cost
        )
        cand = (t, bal != uniform, bal)
        if best is None or cand < best:
            best = cand
    assert best is not None
    return best[2], best[0]
