"""Deterministic synthetic LM data pipeline (offline container).

Token streams are a seeded counter-hash — reproducible across hosts without
shared state, sharding-friendly (any (batch, seq) window is addressable), and
non-degenerate (a bigram structure exists so training loss moves).
"""

from __future__ import annotations

import numpy as np


def _mix(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 16)) * np.uint64(0x45D9F3B)
    x = (x ^ (x >> 16)) * np.uint64(0x45D9F3B)
    return x ^ (x >> 16)


def token_batch(
    *, batch: int, seq: int, vocab: int, seed: int = 0, step: int = 0
) -> np.ndarray:
    """(batch, seq+1) int32 tokens — callers slice input/label windows."""
    rows = np.arange(batch, dtype=np.uint64)[:, None]
    cols = np.arange(seq + 1, dtype=np.uint64)[None, :]
    base = _mix(rows * np.uint64(1_000_003) + np.uint64(seed * 7 + step * 131))
    # bigram-ish structure: token depends on its left neighbor's hash bucket
    raw = _mix(base + cols * np.uint64(2_654_435_761))
    prev = _mix(base + (cols - np.uint64(1)) * np.uint64(2_654_435_761))
    toks = (raw % np.uint64(vocab) + (prev % np.uint64(97))) % np.uint64(vocab)
    return toks.astype(np.int32)


def frontend_embeds(
    *, batch: int, seq: int, d_model: int, seed: int = 0
) -> np.ndarray:
    """Precomputed modality-frontend embeddings (assignment carve-out stub):
    stands in for ViT patch embeddings / EnCodec frame embeddings."""
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((batch, seq, d_model)) * 0.02).astype(np.float32)
