"""Single-device training loop for the GNN experiments (paper Tables 1–2).

The pipelined multi-device loops live in ``repro.core.pipeline``; this module
is the "single CPU / single GPU" rows of the paper's benchmarks and the
correctness oracle against which the pipeline must agree.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.graphs.data import GraphBatch
from repro.models.gnn.net import GNNModel
from repro.train import optimizer as opt_lib
from repro.train.losses import masked_accuracy, masked_nll


@dataclass
class TrainResult:
    params: Any
    epoch_times_s: list[float] = field(default_factory=list)
    train_loss: float = 0.0
    train_acc: float = 0.0
    val_acc: float = 0.0
    test_acc: float = 0.0

    @property
    def first_epoch_s(self) -> float:
        return self.epoch_times_s[0] if self.epoch_times_s else 0.0

    @property
    def rest_epochs_s(self) -> float:
        return sum(self.epoch_times_s[1:])

    @property
    def avg_epoch_s(self) -> float:
        rest = self.epoch_times_s[1:] or self.epoch_times_s
        return sum(rest) / max(len(rest), 1)


def make_train_step(model: GNNModel, optimizer: opt_lib.Optimizer):
    def loss_fn(params, g: GraphBatch, rng):
        logp = model.apply(params, g, rng=rng, train=True)
        return masked_nll(logp, g.labels, g.train_mask)

    @jax.jit
    def step(params, opt_state, g: GraphBatch, rng):
        loss, grads = jax.value_and_grad(loss_fn)(params, g, rng)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        return params, opt_state, loss

    return step


def make_eval(model: GNNModel):
    @jax.jit
    def evaluate(params, g: GraphBatch):
        logp = model.apply(params, g, train=False)
        return {
            "train_loss": masked_nll(logp, g.labels, g.train_mask),
            "train_acc": masked_accuracy(logp, g.labels, g.train_mask),
            "val_acc": masked_accuracy(logp, g.labels, g.val_mask),
            "test_acc": masked_accuracy(logp, g.labels, g.test_mask),
        }

    return evaluate


def train(
    model: GNNModel,
    g: GraphBatch,
    *,
    epochs: int = 300,
    lr: float = 5e-3,
    weight_decay: float = 5e-4,
    seed: int = 0,
    log_every: int = 0,
    time_epochs: bool = True,
) -> TrainResult:
    """Full-batch training, paper §7 protocol (300 epochs, fixed model)."""
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    params = model.init_params(init_key)
    optimizer = opt_lib.adam(lr, weight_decay=weight_decay)
    opt_state = optimizer.init(params)
    step = make_train_step(model, optimizer)
    evaluate = make_eval(model)

    result = TrainResult(params=params)
    for epoch in range(epochs):
        key, rng = jax.random.split(key)
        t0 = time.perf_counter()
        params, opt_state, loss = step(params, opt_state, g, rng)
        if time_epochs:
            jax.block_until_ready(loss)
            result.epoch_times_s.append(time.perf_counter() - t0)
        if log_every and (epoch % log_every == 0 or epoch == epochs - 1):
            m = evaluate(params, g)
            print(
                f"epoch {epoch:4d} loss {float(loss):.4f} "
                f"train {float(m['train_acc']):.3f} val {float(m['val_acc']):.3f}"
            )

    metrics = evaluate(params, g)
    result.params = params
    result.train_loss = float(metrics["train_loss"])
    result.train_acc = float(metrics["train_acc"])
    result.val_acc = float(metrics["val_acc"])
    result.test_acc = float(metrics["test_acc"])
    return result
