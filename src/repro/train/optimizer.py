"""Pure-JAX optimizers (no external deps): Adam/AdamW, SGD, schedules.

API mirrors optax minimally: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``. States are plain pytrees so they shard with pjit (ZeRO-1
just means sharding these over the data axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), norm


@dataclasses.dataclass(frozen=True)
class AdamState:
    step: jax.Array
    mu: Any
    nu: Any


jax.tree_util.register_dataclass(AdamState, data_fields=["step", "mu", "nu"], meta_fields=[])


def adam(
    lr: float | Callable[[jax.Array], jax.Array],
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float | None = None,
) -> Optimizer:
    """Adam / AdamW. Moments are kept in f32 regardless of param dtype so
    bf16 training stays stable (master-quality moments)."""

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)
        return AdamState(
            step=jnp.zeros((), dtype=jnp.int32),
            mu=jax.tree_util.tree_map(f32, params),
            nu=jax.tree_util.tree_map(f32, params),
        )

    def update(grads, state, params):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr)
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        mu_hat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** step.astype(jnp.float32)), mu)
        nu_hat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** step.astype(jnp.float32)), nu)

        def upd(m, v, p):
            u = -lr_t * m / (jnp.sqrt(v) + eps)
            if weight_decay > 0.0:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            # cast to param dtype here: with ZeRO-1 (sharded moments,
            # replicated param) the update is what gets all-gathered
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu_hat, nu_hat, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable, *, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "vel": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        }

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr)
        if momentum == 0.0:
            updates = jax.tree_util.tree_map(lambda g: -lr_t * g, grads)
            return updates, {"step": step}
        vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g.astype(jnp.float32), state["vel"], grads
        )
        updates = jax.tree_util.tree_map(lambda v: -lr_t * v, vel)
        return updates, {"step": step, "vel": vel}

    return Optimizer(init=init, update=update)


def cosine_schedule(peak: float, *, warmup: int, total: int, floor: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return sched
