"""npz-based checkpointing (no external deps), bf16-safe.

Leaves are flattened to ``path.to.leaf`` keys; bf16 arrays are stored as
uint16 views with a dtype sidecar so numpy round-trips them losslessly.
Sharded arrays are gathered on save (fine at this framework's scale; a
production TPU deployment would swap in a tensorstore backend behind the
same two calls).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict[str, jax.Array]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, params: Any, *, step: int = 0, extra: dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(params)
    meta = {"step": step, "dtypes": dtypes, "treedef": str(treedef), **(extra or {})}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str) -> tuple[dict, dict]:
    """Returns (nested dict of arrays, meta). The nested structure is
    reconstructed from the dotted keys (dicts all the way down)."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    out: dict = {}
    for k in data.files:
        a = data[k]
        if meta["dtypes"].get(k) == "bfloat16":
            a = jnp.asarray(a.view(jnp.bfloat16))
        else:
            a = jnp.asarray(a)
        node = out
        parts = k.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = a
    return out, meta
