"""Loss functions: masked node classification + microbatched LM xent."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_nll(log_probs: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Negative log-likelihood over masked nodes (model emits log-softmax,
    matching the paper's final layer)."""
    nll = -jnp.take_along_axis(log_probs, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(mask.sum(), 1)
    return jnp.sum(nll * mask) / denom


def masked_accuracy(log_probs: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    pred = jnp.argmax(log_probs, axis=-1)
    denom = jnp.maximum(mask.sum(), 1)
    return jnp.sum((pred == labels) * mask) / denom


def softmax_xent(logits: jax.Array, labels: jax.Array, *, mask: jax.Array | None = None) -> jax.Array:
    """Token-level cross entropy, numerically stable, f32 accumulation."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1.0)
        return jnp.sum(nll * mask) / denom
    return jnp.mean(nll)
