"""SPMD pipeline correctness on a multi-device (forced host) mesh.

These run in subprocesses so the 8-device XLA flag never leaks into the
main test process (smoke tests must see 1 device)."""

import subprocess
import sys
import textwrap

import pytest


def _run(src: str, devices: int = 8, timeout: int = 1200):
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        "HOME": "/root",
    }
    import os

    env["PATH"] = os.environ.get("PATH", env["PATH"])
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**os.environ, **env},
        cwd="/root/repo",
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_pipeline_fwd_grad_equivalence():
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map
        SM_KW = {}
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        SM_KW = {"check_rep": False}  # old-jax scan-transpose rep tracking
    from repro.core.spmd_pipe import spmd_pipeline, make_scanned_stage, make_gather_fn

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    D, S, PER, NM, B = 16, 4, 2, 4, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (S, PER, D, D)) * 0.3
    extras = {'active': jnp.ones((S, PER))}
    x = jax.random.normal(jax.random.PRNGKey(1), (NM, B // NM, D))

    def block_fn(lp, ex, h):
        return jnp.where(ex['active'] > 0, jnp.tanh(h @ lp['w']), h)

    def pipe(wp, ex, xm):
        gfn = make_gather_fn({'w': True}, 'data')
        stage_fn = make_scanned_stage(
            block_fn,
            jax.tree_util.tree_map(lambda a: a[0], wp),
            jax.tree_util.tree_map(lambda a: a[0], ex),
            gather_fn=gfn)
        out, _ = spmd_pipeline(stage_fn, xm, stage_axis='model', num_stages=S,
                               remat=True, vma_refs=(wp,))
        return out

    f = jax.jit(shard_map(pipe, mesh=mesh,
        in_specs=({'w': P('model', None, 'data', None)}, {'active': P('model', None)},
                  P(None, 'data', None)),
        out_specs=P(None, 'data', None), **SM_KW))
    out = f({'w': w}, extras, x)
    ref = x
    for s in range(S):
        for i in range(PER):
            ref = jnp.tanh(ref @ w[s, i])
    assert jnp.allclose(out, ref, atol=1e-5), float(jnp.max(jnp.abs(out - ref)))

    g1 = jax.grad(lambda wd: jnp.sum(f(wd, extras, x) ** 2) / 2)({'w': w})
    def loss_ref(wd):
        h = x
        for s in range(S):
            for i in range(PER):
                h = jnp.tanh(h @ wd['w'][s, i])
        return jnp.sum(h ** 2) / 2
    g2 = jax.grad(loss_ref)({'w': w})
    assert jnp.allclose(g1['w'], g2['w'], atol=1e-4), float(jnp.max(jnp.abs(g1['w'] - g2['w'])))
    print('EQUIV_OK')
    """)
    assert "EQUIV_OK" in out


@pytest.mark.slow
def test_pipeline_scatter_dim_equivalence():
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map
        SM_KW = {}
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        SM_KW = {"check_rep": False}  # old-jax scan-transpose rep tracking
    from repro.core.spmd_pipe import spmd_pipeline, make_scanned_stage

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    D, S, PER, NM, B, SEQ = 8, 4, 1, 2, 4, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (S, PER, D, D)) * 0.3
    ex = {'active': jnp.ones((S, PER))}
    x = jax.random.normal(jax.random.PRNGKey(1), (NM, B // NM, SEQ, D))

    def block_fn(lp, exx, h):
        return jnp.tanh(h @ lp['w'])

    def pipe(wp, exx, xm):
        stage_fn = make_scanned_stage(block_fn,
            jax.tree_util.tree_map(lambda a: a[0], wp),
            jax.tree_util.tree_map(lambda a: a[0], exx))
        out, _ = spmd_pipeline(stage_fn, xm, stage_axis='model', num_stages=S,
                               scatter_dim=2, vma_refs=(wp,))
        return out

    f = jax.jit(shard_map(pipe, mesh=mesh,
        in_specs=({'w': P('model', None, None, None)}, {'active': P('model', None)},
                  P(None, 'data', None, None)),
        out_specs=P(None, 'data', 'model', None), **SM_KW))
    out = f({'w': w}, ex, x)   # (NM, mb, SEQ, D) with SEQ sharded over model
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ w[s, 0])
    assert out.shape == ref.shape
    assert jnp.allclose(out, ref, atol=1e-5), float(jnp.max(jnp.abs(out - ref)))
    print('SCATTER_OK')
    """)
    assert "SCATTER_OK" in out


@pytest.mark.slow
def test_stateful_pipeline_cache_writes():
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map
        SM_KW = {}
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        SM_KW = {"check_rep": False}  # old-jax scan-transpose rep tracking
    from repro.core.spmd_pipe import spmd_pipeline, make_scanned_stage_stateful

    mesh = jax.make_mesh((4,), ('model',))
    D, S, PER, NM, B = 8, 4, 1, 4, 4
    w = jax.random.normal(jax.random.PRNGKey(0), (S, PER, D, D)) * 0.3
    ex = {'active': jnp.ones((S, PER))}
    x = jax.random.normal(jax.random.PRNGKey(1), (NM, B // NM, D))
    state = jnp.zeros((S, NM, PER, B // NM, D))  # per-layer cache of inputs

    def block_fn(lp, exx, h, cache_i):
        return jnp.tanh(h @ lp['w']), h  # cache the INPUT seen by each layer

    def pipe(wp, exx, xm, st):
        stage_fn = make_scanned_stage_stateful(block_fn,
            jax.tree_util.tree_map(lambda a: a[0], wp),
            jax.tree_util.tree_map(lambda a: a[0], exx))
        out, st2 = spmd_pipeline(stage_fn, xm, stage_axis='model', num_stages=S,
                                 state=st[0], vma_refs=(wp,))
        return out, st2[None]

    f = jax.jit(shard_map(pipe, mesh=mesh,
        in_specs=({'w': P('model', None, None, None)}, {'active': P('model', None)},
                  P(None, None, None), P('model', None, None, None, None)),
        out_specs=(P(None, None, None), P('model', None, None, None, None)), **SM_KW))
    out, st2 = f({'w': w}, ex, x, state)
    # stage 0's cached input for microbatch m must equal x[m]
    st0 = st2[0]   # (NM, PER, mb, D)
    assert jnp.allclose(st0[:, 0], x, atol=1e-6)
    # stage 1's cached input must equal tanh(x @ w0)
    st1 = st2[1]
    assert jnp.allclose(st1[:, 0], jnp.tanh(x @ w[0, 0]), atol=1e-5)
    print('STATE_OK')
    """)
    assert "STATE_OK" in out


@pytest.mark.slow
def test_interleaved_pipeline_fwd_grad_equivalence():
    """Circular/interleaved pipeline: D devices x V virtual stages each;
    forward matches the sequential reference and grads flow through the
    ppermute ring + rotating chunk buffer."""
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map
        SM_KW = {}
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        SM_KW = {"check_rep": False}  # old-jax scan-transpose rep tracking
    from repro.core.spmd_pipe import spmd_pipeline_interleaved, make_interleaved_stage

    mesh = jax.make_mesh((2,), ('model',))
    D, V, PER, NM, B, Dm = 2, 2, 2, 4, 8, 16
    S = D * V
    w = jax.random.normal(jax.random.PRNGKey(0), (S, PER, Dm, Dm)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (NM, B // NM, Dm))

    def dev_stack(a):   # (S, ...) -> (D, V, ...); device d holds stages v*D + d
        return jnp.stack([jnp.stack([a[v*D + d] for v in range(V)]) for d in range(D)])

    wp = {'w': dev_stack(w)}
    ex = {'active': dev_stack(jnp.ones((S, PER)))}

    def block_fn(lp, exx, h):
        return jnp.where(exx['active'] > 0, jnp.tanh(h @ lp['w']), h)

    def pipe(wpp, exx, xm):
        stage_fn = make_interleaved_stage(
            block_fn,
            jax.tree_util.tree_map(lambda a: a[0], wpp),
            jax.tree_util.tree_map(lambda a: a[0], exx))
        return spmd_pipeline_interleaved(stage_fn, xm, stage_axis='model',
                                         num_devices=D, num_virtual=V,
                                         remat=True, vma_refs=(wpp,))

    f = jax.jit(shard_map(pipe, mesh=mesh,
        in_specs=({'w': P('model')}, {'active': P('model')}, P()),
        out_specs=P(), **SM_KW))
    out = f(wp, ex, x)
    ref = x
    for k in range(S):
        for i in range(PER):
            ref = jnp.tanh(ref @ w[k, i])
    assert jnp.allclose(out, ref, atol=1e-5), float(jnp.max(jnp.abs(out - ref)))

    g1 = jax.grad(lambda wd: jnp.sum(f(wd, ex, x) ** 2) / 2)(wp)
    def loss_ref(wd):
        h = x
        for k in range(S):
            for i in range(PER):
                h = jnp.tanh(h @ wd[k, i])
        return jnp.sum(h ** 2) / 2
    g2 = dev_stack(jax.grad(loss_ref)(w))
    assert jnp.allclose(g1['w'], g2, atol=1e-4), float(jnp.max(jnp.abs(g1['w'] - g2)))

    # C == D edge (same-tick buffer write/read) and deeper virtual stacks
    mesh4 = jax.make_mesh((4,), ('model',))
    for D2, V2, NM2 in [(4, 2, 4), (4, 3, 8)]:
        S2 = D2 * V2
        w2 = jax.random.normal(jax.random.PRNGKey(2), (S2, 1, Dm, Dm)) * 0.3
        x2 = jax.random.normal(jax.random.PRNGKey(3), (NM2, 4, Dm))
        def ds(a, D=D2, V=V2):
            return jnp.stack([jnp.stack([a[v*D + d] for v in range(V)]) for d in range(D)])
        def pipe2(wpp, xm, D=D2, V=V2):
            sf = make_interleaved_stage(lambda lp, e, h: jnp.tanh(h @ lp),
                                        jax.tree_util.tree_map(lambda a: a[0], wpp),
                                        jax.tree_util.tree_map(lambda a: a[0], wpp) * 0)
            return spmd_pipeline_interleaved(sf, xm, stage_axis='model',
                                             num_devices=D, num_virtual=V, vma_refs=(wpp,))
        f2 = jax.jit(shard_map(pipe2, mesh=mesh4, in_specs=(P('model'), P()),
                               out_specs=P(), **SM_KW))
        o2 = f2(ds(w2), x2)
        r2 = x2
        for k in range(S2):
            r2 = jnp.tanh(r2 @ w2[k, 0])
        assert jnp.allclose(o2, r2, atol=1e-5), float(jnp.max(jnp.abs(o2 - r2)))
    print('INTERLEAVED_OK')
    """)
    assert "INTERLEAVED_OK" in out


@pytest.mark.slow
def test_multidevice_train_smoke_all_paths():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch, ShapeConfig
    from repro.models.transformer.model import Topology, init_params, make_train_step
    from repro.data.tokens import token_batch

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    shape = ShapeConfig('smoke', 64, 8, 'train')
    for name in ['qwen2.5-32b', 'arctic-480b', 'musicgen-large', 'glm4-9b']:
        cfg = get_arch(name, smoke=True)
        topo = Topology(num_stages=4, fsdp_size=2, num_micro=2, loss_chunks=2)
        art = make_train_step(cfg, topo, shape, mesh, dtype=jnp.float32)
        params = jax.device_put(
            init_params(cfg, jax.random.PRNGKey(0), num_stages=4, dtype=jnp.float32),
            art.in_shardings[0])
        opt_state = art.meta['optimizer'].init(params)
        s_front = int(shape.seq_len * cfg.frontend_frac) if cfg.frontend != 'none' else 0
        batch = {'tokens': jnp.asarray(token_batch(batch=8, seq=shape.seq_len - s_front,
                                                   vocab=cfg.vocab_size))}
        if s_front:
            from repro.data.tokens import frontend_embeds
            batch['frontend_embeds'] = jnp.asarray(frontend_embeds(
                batch=8, seq=s_front, d_model=cfg.d_model))
        _, _, m = jax.jit(art.fn, in_shardings=art.in_shardings,
                          out_shardings=art.out_shardings)(params, opt_state, batch)
        assert np.isfinite(float(m['loss'])), name
    print('MD_SMOKE_OK')
    """, timeout=2400)
    assert "MD_SMOKE_OK" in out
