"""GPipe engine: gradient equivalence, schedule accounting, strategies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.microbatch import make_plan
from repro.core.pipeline import GPipe, GPipeConfig
from repro.core.schedule import bubble_fraction, fill_drain_timeline, predicted_step_time
from repro.graphs import load_dataset
from repro.models.gnn.net import build_paper_gat
from repro.train import optimizer as opt_lib
from repro.train.losses import masked_nll


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes, feat_dropout=0.0, attn_dropout=0.0)
    params = m.init_params(jax.random.PRNGKey(0))
    return g, m, params


def _full_batch_step(m, g, params, opt):
    def loss_fn(p):
        return masked_nll(m.apply(p, g, train=True), g.labels, g.train_mask)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    upd, _ = opt.update(grads, opt.init(params), params)
    return loss, opt_lib.apply_updates(params, upd)


@pytest.mark.parametrize("chunks", [2, 4])
def test_halo_pipeline_equals_full_batch(setup, chunks):
    """THE GPipe invariant: with lossless micro-batching, chunk count does
    not change the update (paper §4: 'the number of partitions separating
    the data does not affect model quality')."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    pipe = GPipe(m, GPipeConfig(balance=(2, 1, 1, 2), chunks=chunks))
    plan = make_plan(g, chunks, strategy="halo", halo_hops=2)
    assert plan.edge_cut == 0.0
    p2, _, loss = pipe.train_step(params, opt.init(params), plan, jax.random.PRNGKey(1), opt)
    ref_loss, p_ref = _full_batch_step(m, g, params, opt)
    assert abs(float(loss) - float(ref_loss)) < 1e-5
    # atol 5e-5: adam's 1/(sqrt(v)+eps) amplifies float noise on ~zero grads
    for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p2)):
        assert jnp.allclose(a, b, atol=5e-5), float(jnp.max(jnp.abs(a - b)))


def test_sequential_strategy_loses_edges(setup):
    g, _, _ = setup
    plan = make_plan(g, 4, strategy="sequential")
    assert plan.edge_cut > 0.3  # karate is small and tangled: heavy loss
    assert plan.rebuild_seconds > 0.0


def test_balance_must_sum_to_layers(setup):
    _, m, _ = setup
    with pytest.raises(ValueError):
        GPipe(m, GPipeConfig(balance=(2, 2), chunks=2))


def test_fill_drain_timeline_counts():
    s, c = 4, 3
    items = fill_drain_timeline(s, c)
    fwd = [i for i in items if i.phase == "fwd"]
    bwd = [i for i in items if i.phase == "bwd"]
    assert len(fwd) == len(bwd) == s * c
    # stage s processes chunk c at tick c + s
    for it in fwd:
        assert it.tick == it.chunk + it.stage
    # no two work items share (tick, stage)
    assert len({(i.tick, i.stage) for i in items}) == len(items)


def test_bubble_fraction_monotone():
    assert bubble_fraction(4, 1) > bubble_fraction(4, 4) > bubble_fraction(4, 64)
    assert bubble_fraction(1, 8) == 0.0


def test_predicted_step_time_grows_with_rebuild():
    base = predicted_step_time(4, 4, fwd_cost_per_chunk=1.0, bwd_cost_per_chunk=2.0)
    with_rebuild = predicted_step_time(
        4, 4, fwd_cost_per_chunk=1.0, bwd_cost_per_chunk=2.0, rebuild_cost_per_chunk=0.5
    )
    assert with_rebuild > base


def test_pipeline_records_schedule(setup):
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    pipe = GPipe(m, GPipeConfig(balance=(3, 3), chunks=2))
    plan = make_plan(g, 2, strategy="sequential")
    rec = []
    pipe.train_step(params, opt.init(params), plan, jax.random.PRNGKey(0), opt, record=rec)
    fwd = [r for r in rec if r[0] == "fwd"]
    bwd = [r for r in rec if r[0] == "bwd"]
    assert len(fwd) == 2 * 2 and len(bwd) == 2 * 2
    assert all(r[4] >= 0 for r in rec)


@pytest.mark.parametrize(
    "schedule,num_devices",
    [("1f1b", None), ("interleaved", 2), ("zb-h1", None), ("zb-v", 2)],
)
def test_schedule_gradients_match_fill_drain(setup, schedule, num_devices):
    """Any schedule's train_step yields the same update as the fill-drain
    baseline (per-chunk gradients reduce in a canonical order, so the floats
    are identical bit for bit — allclose with atol 0). zb-h1's split
    backward rides the same invariant: its W half differentiates the same
    re-materialized stage wrt params with the same cotangent, so the
    deferred weight grads are the very floats the fused vjp produces."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    base = GPipe(m, GPipeConfig(balance=(2, 1, 1, 2), chunks=C))
    p_ref, _, loss_ref = base.train_step(
        params, opt.init(params), plan, jax.random.PRNGKey(1), opt
    )
    pipe = GPipe(m, GPipeConfig(
        balance=(2, 1, 1, 2), chunks=C, schedule=schedule, num_devices=num_devices
    ))
    p2, _, loss = pipe.train_step(
        params, opt.init(params), plan, jax.random.PRNGKey(1), opt
    )
    assert float(loss) == float(loss_ref)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p2)):
        assert jnp.array_equal(a, b), float(jnp.max(jnp.abs(a - b)))


@pytest.mark.parametrize("chunks", [4, 8])
def test_1f1b_measured_peak_live_below_fill_drain(setup, chunks):
    """C >= S: 1F1B's measured peak live-activation count in the engine is
    strictly below fill-drain's (which must hold all S*C stage inputs)."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    peaks = {}
    for schedule in ("fill_drain", "1f1b"):
        pipe = GPipe(m, GPipeConfig(balance=(2, 1, 1, 2), chunks=chunks, schedule=schedule))
        plan = make_plan(g, chunks, strategy="sequential")
        stats = {}
        pipe.train_step(
            params, opt.init(params), plan, jax.random.PRNGKey(0), opt, stats=stats
        )
        peaks[schedule] = stats["measured_peak_live_activations"]
        assert stats["bubble_fraction"] == pipe.schedule.bubble_fraction(4, chunks)
    assert peaks["fill_drain"] == 4 * chunks
    assert peaks["1f1b"] < peaks["fill_drain"], peaks


def test_interleaved_engine_stats(setup):
    """Interleaved 1F1B in the engine: bubble accounting beats fill-drain's
    at the same physical device count and the step still records S*C work
    items per phase."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    pipe = GPipe(m, GPipeConfig(
        balance=(2, 1, 1, 2), chunks=C, schedule="interleaved", num_devices=2
    ))
    plan = make_plan(g, C, strategy="sequential")
    rec, stats = [], {}
    pipe.train_step(
        params, opt.init(params), plan, jax.random.PRNGKey(0), opt,
        record=rec, stats=stats,
    )
    assert len([r for r in rec if r[0] == "fwd"]) == 4 * C
    assert len([r for r in rec if r[0] == "bwd"]) == 4 * C
    assert stats["bubble_fraction"] < bubble_fraction(2, C)  # fill-drain, 2 devices
    assert stats["num_devices"] == 2


def test_zb_h1_host_engine_stats_and_record(setup):
    """The host engine executes the three-phase zb-h1 timeline: S*C items
    per phase (fwd / bwd_b / bwd_w), a bubble strictly below 1F1B's, peak
    live stage-inputs no higher than 1F1B's, and the deferred-W residual
    count surfaced in stats."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="sequential")
    peaks = {}
    recs = {}
    for schedule in ("1f1b", "zb-h1"):
        pipe = GPipe(m, GPipeConfig(balance=(2, 1, 1, 2), chunks=C, schedule=schedule))
        rec, stats = [], {}
        pipe.train_step(
            params, opt.init(params), plan, jax.random.PRNGKey(0), opt,
            record=rec, stats=stats,
        )
        peaks[schedule] = stats
        recs[schedule] = rec
    zb, ob = peaks["zb-h1"], peaks["1f1b"]
    for phase in ("fwd", "bwd_b", "bwd_w"):
        assert len([r for r in recs["zb-h1"] if r[0] == phase]) == 4 * C
    assert zb["bubble_fraction"] < ob["bubble_fraction"]
    assert zb["measured_peak_live_activations"] <= ob["measured_peak_live_activations"]
    assert 0 < zb["measured_peak_w_residuals"] <= 4 * C
    assert ob["measured_peak_w_residuals"] == 0


def test_bad_schedule_config_raises(setup):
    _, m, _ = setup
    with pytest.raises(KeyError):
        GPipe(m, GPipeConfig(balance=(3, 3), chunks=2, schedule="nope"))
    with pytest.raises(ValueError):
        GPipe(m, GPipeConfig(balance=(3, 3), chunks=2, schedule="interleaved"))


def test_training_with_pipeline_learns(setup):
    """30 GPipe epochs on karate should reach high train accuracy (halo)."""
    g, m, _ = setup
    opt = opt_lib.adam(1e-2)
    pipe = GPipe(m, GPipeConfig(balance=(2, 1, 1, 2), chunks=2))
    plan = make_plan(g, 2, strategy="halo", halo_hops=2)
    key = jax.random.PRNGKey(42)
    params = pipe.init_params(key)
    state = opt.init(params)
    for i in range(30):
        key, rng = jax.random.split(key)
        params, state, loss = pipe.train_step(params, state, plan, rng, opt)
    logp = m.apply(params, g)
    acc = float(((jnp.argmax(logp, -1) == g.labels) * g.train_mask).sum() / g.train_mask.sum())
    assert acc >= 0.8, acc
