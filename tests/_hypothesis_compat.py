"""Optional-``hypothesis`` shim so tier-1 collects without the dependency.

``from tests._hypothesis_compat import given, settings, st`` behaves exactly
like ``from hypothesis import given, settings, strategies as st`` when
hypothesis is installed. Without it, ``@given(...)`` turns the test into a
pytest skip (the property tests are extra assurance, not tier-1 gating), and
the strategy/settings surfaces become inert stand-ins so module import and
decoration still succeed.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning None (strategies are only consumed by ``given``,
        which skips before reading them)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _Strategies()

    def given(*args, **kwargs):
        def decorate(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate
