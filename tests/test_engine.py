"""Engine equivalence: host GPipe vs compiled SPMD program, plus the stacked
micro-batch plan and the pytree-generalized spmd_pipeline.

The 1-device tests exercise the compiled engine's chunk-scan substrate; the
`slow` subprocess test forces 4 host devices so the shard_map/ppermute ring
substrate runs (same pattern as tests/test_spmd_pipe.py)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core.microbatch import make_plan
from repro.core.pipeline import GPipeConfig, make_engine
from repro.core.spmd_pipe import spmd_pipeline
from repro.graphs import load_dataset
from repro.models.gnn.net import build_paper_gat
from repro.train import optimizer as opt_lib


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    return g, m, params


def _params_close(p1, p2, atol):
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        assert jnp.allclose(a, b, atol=atol), float(jnp.max(jnp.abs(a - b)))


# ------------------------------------------------------------ stacked plan --


@pytest.mark.parametrize("pad_to_max", [True, False])
def test_stacked_plan_uniform_shapes(setup, pad_to_max):
    g, _, _ = setup
    plan = make_plan(g, 3, strategy="halo", halo_hops=2, pad_to_max=pad_to_max)
    stacked = plan.stacked()
    assert stacked is plan.stacked()  # cached
    # one uniform-shape pytree: every leaf leads with the chunk axis
    for leaf in jax.tree_util.tree_leaves(stacked.graph):
        assert leaf.shape[0] == 3
        assert leaf.shape[1] == stacked.n_pad
    assert stacked.graph.neighbors.shape == (3, stacked.n_pad, stacked.max_deg)
    assert stacked.core_mask.shape == (3, stacked.n_pad)
    # padding must not invent loss rows: core counts survive stacking
    want = sum(int(mb.core_mask.sum()) for mb in plan.batches)
    assert int(stacked.core_mask.sum()) == want == g.num_nodes
    # padded rows are inert: no edge slots, no norm mass
    for c, mb in enumerate(plan.batches):
        n = mb.num_nodes
        assert not bool(stacked.graph.mask[c, n:].any())
        assert float(jnp.abs(stacked.graph.norm[c, n:]).sum()) == 0.0


# ----------------------------------------------------- engine equivalence --


@pytest.mark.parametrize("strategy", ["halo", "sequential"])
def test_compiled_engine_matches_host(setup, strategy):
    """Same plan, same seed: the compiled engine's loss trajectory and
    post-step params match the host GPipe fill-drain baseline — including
    the paper's dropout, whose per-(chunk, layer) keys both engines derive
    identically."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy=strategy, halo_hops=2)
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=C))
    comp = make_engine(m, GPipeConfig(engine="compiled", balance=(2, 1, 1, 2), chunks=C))
    ph = pc = params
    oh = oc = opt.init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(3):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
        assert abs(float(lh) - float(lc)) < 1e-4, (float(lh), float(lc))
    # 5e-4 over 3 adam steps: 1/(sqrt(v)+eps) amplifies the engines'
    # different float-accumulation orders on near-zero gradients (the same
    # effect the host-only schedule tests absorb at 5e-5 per single step)
    _params_close(ph, pc, atol=5e-4)


def test_compiled_engine_trains(setup):
    """30 compiled-engine epochs on karate reach high train accuracy (the
    host-engine learning test, rerun through the fused program)."""
    g, m, _ = setup
    opt = opt_lib.adam(1e-2)
    pipe = make_engine(m, GPipeConfig(engine="compiled", balance=(2, 1, 1, 2), chunks=2))
    plan = make_plan(g, 2, strategy="halo", halo_hops=2)
    key = jax.random.PRNGKey(42)
    params = pipe.init_params(key)
    state = opt.init(params)
    for _ in range(30):
        key, rng = jax.random.split(key)
        params, state, loss = pipe.train_step(params, state, plan, rng, opt)
    logp = m.apply(params, g)
    acc = float(((jnp.argmax(logp, -1) == g.labels) * g.train_mask).sum() / g.train_mask.sum())
    assert acc >= 0.8, acc


def test_compiled_engine_stats_and_describe(setup):
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    pipe = make_engine(m, GPipeConfig(engine="compiled", balance=(3, 3), chunks=2))
    plan = make_plan(g, 2, strategy="sequential")
    stats = {}
    pipe.train_step(params, opt.init(params), plan, jax.random.PRNGKey(0), opt, stats=stats)
    assert stats["engine"] == "compiled"
    assert stats["bubble_fraction"] == pipe.schedule.bubble_fraction(2, 2)
    assert pipe.describe()["engine"] == "compiled"


def test_engine_factory_and_config_validation(setup):
    _, m, _ = setup
    with pytest.raises(KeyError):
        make_engine(m, GPipeConfig(engine="nope", balance=(3, 3), chunks=2))
    # both engines accept every schedule; interleaved still needs num_devices
    with pytest.raises(ValueError):
        make_engine(m, GPipeConfig(engine="compiled", balance=(3, 3), chunks=2, schedule="interleaved"))
    comp = make_engine(m, GPipeConfig(engine="compiled", balance=(3, 3), chunks=2, schedule="1f1b"))
    assert comp.describe()["schedule"] == "1f1b"
    host = make_engine(m, GPipeConfig(engine="host", balance=(3, 3), chunks=2, schedule="1f1b"))
    assert host.describe()["engine"] == "host"


def test_make_engine_requires_config(setup):
    """The redesigned factory: model first, assembled GPipeConfig second —
    anything else (a bare dict, a missing config) is a TypeError, not a
    silent default."""
    _, m, _ = setup
    with pytest.raises(TypeError):
        make_engine(m)
    with pytest.raises(TypeError):
        make_engine(m, {"engine": "host", "balance": (3, 3)})


def test_make_engine_name_first_removed(setup):
    """The deprecated name-first spelling make_engine("host", model, config)
    is gone: the engine name lives on GPipeConfig.engine and the old form
    now raises TypeError instead of warning."""
    _, m, _ = setup
    with pytest.raises(TypeError):
        make_engine("host", m)
    with pytest.raises(TypeError):
        make_engine("nope", m)


# ------------------------------------------- scheduled compiled executor --


SCHEDULE_MATRIX = [  # (schedule, num_devices kwarg)
    ("fill_drain", None),
    ("1f1b", None),
    ("interleaved", 2),
    ("zb-h1", None),
    ("zb-v", 2),
]


@pytest.mark.parametrize("schedule,pipe_devices", SCHEDULE_MATRIX)
def test_compiled_schedules_match_host_fill_drain(setup, schedule, pipe_devices):
    """The full schedule×engine matrix: every compiled schedule (fill-drain
    scan path, 1F1B and interleaved through the scheduled executor) produces
    the same loss trajectory and post-step params as the host fill-drain
    baseline — the canonical gradient-reduction order makes the update
    schedule-invariant on both engines. On hosts with fewer devices than the
    schedule's placement the scheduled work dispatcher runs through the
    lane-stacked substrate (spmd_pipeline_scheduled_lanes); with enough
    devices it runs the shard_map ring — so CI forcing 1 and 4 host devices
    covers both substrates."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=C))
    comp = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=C, schedule=schedule, num_devices=pipe_devices,
    ))
    ph = pc = params
    oh = oc = opt.init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(3):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
        assert abs(float(lh) - float(lc)) < 1e-4, (schedule, float(lh), float(lc))
    _params_close(ph, pc, atol=5e-4)


def test_scheduled_engine_peak_live_below_fill_drain(setup):
    """The scheduled executor's stash accounting realizes 1F1B's memory
    lever: peak banked activations strictly below the fill-drain S*C at
    chunks >= 4 (the fig3 acceptance invariant), and the per-device slot
    count is the schedule's live window, not C."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    pipe = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=C, schedule="1f1b",
    ))
    stats = {}
    pipe.train_step(params, opt.init(params), plan, jax.random.PRNGKey(0), opt, stats=stats)
    S = 4
    assert stats["measured_peak_live_activations"] < S * C
    assert stats["stash_slots_per_device"] <= min(S, C) + 1
    # and the schedule's own accounting agrees with the dominance claim
    assert pipe.schedule.peak_live_activations(S, C) < S * C


def test_zb_h1_engine_peak_live_not_above_1f1b(setup):
    """The zero-bubble invariant in the engine: zb-h1's B half keeps 1F1B's
    activation window, so its peak banked stage inputs never exceed 1F1B's
    (the residual stash is accounted separately as ``w_slots_per_device``)."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    peaks = {}
    for schedule in ("1f1b", "zb-h1"):
        pipe = make_engine(m, GPipeConfig(engine="compiled",
            balance=(2, 1, 1, 2), chunks=C, schedule=schedule,
        ))
        stats = {}
        pipe.train_step(
            params, opt.init(params), plan, jax.random.PRNGKey(0), opt, stats=stats
        )
        peaks[schedule] = stats
    zb, ob = peaks["zb-h1"], peaks["1f1b"]
    assert zb["measured_peak_live_activations"] <= ob["measured_peak_live_activations"]
    assert zb["stash_slots_per_device"] == ob["stash_slots_per_device"]
    assert 0 < zb["w_slots_per_device"] <= C
    assert ob["w_slots_per_device"] == 0  # fused backward banks no residuals
    assert zb["bubble_fraction"] < ob["bubble_fraction"]


# ------------------------------------------- placement / partition matrix --


PLACED_MATRIX = [  # (schedule, num_devices kwarg, ring rotation)
    ("fill_drain", None, 1),  # non-identity ring: routes through the scheduled executor
    ("1f1b", None, 2),
    ("interleaved", 2, 1),
    ("zb-h1", None, 3),
    ("zb-v", 2, 1),
]


@pytest.mark.parametrize("schedule,pipe_devices,rotation", PLACED_MATRIX)
def test_placed_schedules_match_host_fill_drain(setup, schedule, pipe_devices, rotation):
    """The placement axis of the property matrix: ANY valid (= ring) device
    placement produces updates bit-identical to the host fill-drain baseline
    on every schedule — placement relabels which device hosts which stage,
    never what runs. On 1 device this exercises the lane substrate's rotated
    columns; under CI's 4 forced devices the shard_map ring."""
    from repro.core.schedule import Placement

    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    placement = Placement.ring(4, pipe_devices, rotation=rotation)
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=C))
    comp = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=C, schedule=schedule,
        num_devices=pipe_devices, placement=placement,
    ))
    ph = pc = params
    oh = oc = opt.init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(2):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
        assert abs(float(lh) - float(lc)) < 1e-4, (schedule, float(lh), float(lc))
    _params_close(ph, pc, atol=5e-4)


@pytest.mark.parametrize("balance", [(1, 2, 2, 1), (1, 1, 1, 3)])
def test_any_partition_matches_host_fill_drain(setup, balance):
    """The partition axis: moving stage boundaries (any contiguous balance,
    e.g. the cost-model partitioner's output) leaves the update bit-identical
    to the canonical host fill-drain baseline — partitioning redistributes
    work across devices, never reorders the math."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=C))
    comp = make_engine(m, GPipeConfig(engine="compiled",
        balance=balance, chunks=C, schedule="1f1b",
    ))
    ph = pc = params
    oh = oc = opt.init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(2):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
        assert abs(float(lh) - float(lc)) < 1e-4, (balance, float(lh), float(lc))
    _params_close(ph, pc, atol=5e-4)


def test_host_engine_with_placement_matches_baseline(setup):
    """Host-engine leg of the placement matrix: an explicit ring placement
    (with a device list, so ``_place`` actually routes tensors) leaves the
    host zb-h1 update identical to the unplaced host fill-drain baseline."""
    from repro.core.schedule import Placement

    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=C))
    placed = make_engine(m, GPipeConfig(engine="host",
        balance=(2, 1, 1, 2), chunks=C, schedule="zb-h1",
        devices=tuple(jax.devices()) * 4,  # cycle the host's devices
        placement=Placement.ring(4, rotation=2, device_order=(2, 0, 3, 1)),
    ))
    ph = pp = params
    oh = op = opt.init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(2):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pp, op, lp = placed.train_step(pp, op, plan, rng, opt)
        assert abs(float(lh) - float(lp)) < 1e-6, (float(lh), float(lp))
    _params_close(ph, pp, atol=1e-6)


def test_engine_rejects_incompatible_placement(setup):
    from repro.core.schedule import Placement

    _, m, _ = setup
    with pytest.raises(ValueError):  # not ring-compatible
        make_engine(m, GPipeConfig(engine="compiled",
            balance=(2, 1, 1, 2), chunks=4, placement=Placement((0, 2, 1, 3)),
        ))
    with pytest.raises(ValueError):  # device count != schedule's placement
        make_engine(m, GPipeConfig(engine="host",
            balance=(2, 1, 1, 2), chunks=4, schedule="interleaved",
            num_devices=2, placement=Placement.ring(4),
        ))


def test_scheduled_engine_rejects_illegal_combo(setup):
    """Interleaved needs chunks divisible by devices: the lowering-time
    ValueError surfaces at train_step, not as silent mis-routing."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    plan = make_plan(g, 3, strategy="sequential")
    pipe = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=3, schedule="interleaved", num_devices=2,
    ))
    with pytest.raises(ValueError):
        pipe.train_step(params, opt.init(params), plan, jax.random.PRNGKey(0), opt)


# ----------------------------------------- data axis (data x stage mesh) --


def _dp_fixture(chunks):
    """A small streamed power-law graph + GCN for the data-parallel matrix
    (streamed because the data axis exists for the streamed-graph scale
    path; tiny node count keeps the oracle runs fast)."""
    from repro.graphs import open_streamed, streamed_plan
    from repro.models.gnn.net import build_gnn

    ds = open_streamed("powerlaw-64k", num_nodes=512, block_size=256)
    plan = streamed_plan(ds, chunks, max_degree=16)
    g0 = plan.batches[0].graph
    m = build_gnn("gcn", g0.num_features, g0.num_classes, hidden=16, depth=2)
    return plan, m


def test_data_parallel_validation(setup):
    _, m, _ = setup
    with pytest.raises(ValueError):  # dp < 1
        make_engine(m, GPipeConfig(engine="compiled", balance=(3, 3),
                                   chunks=4, data_parallel=0))
    with pytest.raises(ValueError):  # host queue loop has no data axis
        make_engine(m, GPipeConfig(engine="host", balance=(3, 3),
                                   chunks=4, data_parallel=2))
    plan, m2 = _dp_fixture(3)
    eng = make_engine(m2, GPipeConfig(engine="compiled", balance=(2, 2),
                                      chunks=3, schedule="1f1b",
                                      data_parallel=2))
    opt = opt_lib.adam(1e-2)
    params = eng.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):  # chunks % dp != 0
        eng.train_step(params, opt.init(params), plan, jax.random.PRNGKey(0), opt)


@pytest.mark.parametrize("schedule,rotation", [
    ("fill_drain", 1),  # rotated ring: dp=1 fill-drain must ALSO run the
    ("1f1b", None),     # scheduled executor (the fused scan fuses differently)
    ("zb-h1", None),
])
def test_data_parallel_bit_identical_to_one_replica(schedule, rotation):
    """data_parallel=2 produces updates BIT-identical to data_parallel=1 on
    every scheduled executor: the data axis re-distributes which replica
    pipelines which chunks, and the executor's ordered all_gather reduction
    restores the canonical global chunk order exactly — zero numerical
    change. On 1 device this exercises the explicit fallback (single replica
    over all chunks); under CI's 4 forced devices the real (data, stage)
    mesh."""
    import numpy as np
    from repro.core.schedule import Placement

    plan, m = _dp_fixture(4)
    opt = opt_lib.adam(1e-2)
    params = m.init_params(jax.random.PRNGKey(0))
    placement = None if rotation is None else Placement.ring(2, rotation=rotation)
    engines = [
        make_engine(m, GPipeConfig(engine="compiled", balance=(2, 2), chunks=4,
                                   schedule=schedule, placement=placement,
                                   data_parallel=dp))
        for dp in (1, 2)
    ]
    ps = [params, params]
    os_ = [opt.init(params), opt.init(params)]
    key = jax.random.PRNGKey(42)
    for _ in range(2):
        key, rng = jax.random.split(key)
        for i, eng in enumerate(engines):
            ps[i], os_[i], _ = eng.train_step(ps[i], os_[i], plan, rng, opt)
    for a, b in zip(jax.tree_util.tree_leaves(ps[0]), jax.tree_util.tree_leaves(ps[1])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            schedule, float(jnp.max(jnp.abs(a - b))))


def test_data_parallel_matches_host_fill_drain():
    """The dp=2 update agrees with the host fill-drain oracle on the same
    streamed plan at the standard engine tolerance (the compiled program
    fuses differently; bit-identity is vs dp=1 above)."""
    plan, m = _dp_fixture(4)
    opt = opt_lib.adam(1e-2)
    params = m.init_params(jax.random.PRNGKey(0))
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 2), chunks=4))
    comp = make_engine(m, GPipeConfig(engine="compiled", balance=(2, 2),
                                      chunks=4, schedule="1f1b",
                                      data_parallel=2))
    ph = pc = params
    oh = oc = opt.init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(2):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
        assert abs(float(lh) - float(lc)) < 1e-4, (float(lh), float(lc))
    _params_close(ph, pc, atol=5e-4)


@pytest.mark.slow
def test_data_parallel_mesh_multidevice():
    """The real 2-D (data, stage) mesh on 4 simulated devices (2 replicas x
    2 ring positions): per-replica timelines over sharded streamed chunks
    still produce BIT-identical params to data_parallel=1 on every
    scheduled executor, and match the host fill-drain oracle."""
    out = _run("""
    import jax, numpy as np
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.core.schedule import Placement
    from repro.graphs import open_streamed, streamed_plan
    from repro.models.gnn.net import build_gnn
    from repro.train import optimizer as opt_lib

    assert jax.device_count() == 4, jax.device_count()
    ds = open_streamed("powerlaw-64k", num_nodes=512, block_size=256)
    plan = streamed_plan(ds, 4, max_degree=16)
    g0 = plan.batches[0].graph
    m = build_gnn("gcn", g0.num_features, g0.num_classes, hidden=16, depth=2)
    opt = opt_lib.adam(1e-2)
    params = m.init_params(jax.random.PRNGKey(0))
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 2), chunks=4))
    for schedule, rotation in (("fill_drain", 1), ("1f1b", None), ("zb-h1", None)):
        # the rotated ring keeps dp=1 fill-drain on the scheduled executor
        # (the fused scan fuses differently -> not bit-comparable)
        placement = None if rotation is None else Placement.ring(2, rotation=rotation)
        e1 = make_engine(m, GPipeConfig(engine="compiled", balance=(2, 2),
            chunks=4, schedule=schedule, placement=placement, data_parallel=1))
        e2 = make_engine(m, GPipeConfig(engine="compiled", balance=(2, 2),
            chunks=4, schedule=schedule, placement=placement, data_parallel=2))
        assert not e2._data_parallel_active  # set lazily at first step
        ph = p1 = p2 = params
        oh = o1 = o2 = opt.init(params)
        key = jax.random.PRNGKey(42)
        for _ in range(2):
            key, rng = jax.random.split(key)
            ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
            p1, o1, l1 = e1.train_step(p1, o1, plan, rng, opt)
            p2, o2, l2 = e2.train_step(p2, o2, plan, rng, opt)
            assert abs(float(lh) - float(l2)) < 1e-4, (schedule, float(lh), float(l2))
        assert e2._data_parallel_active, schedule  # the 2-D mesh really ran
        for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                schedule, float(np.max(np.abs(np.asarray(a) - np.asarray(b)))))
        for a, b in zip(jax.tree_util.tree_leaves(ph), jax.tree_util.tree_leaves(p2)):
            assert np.allclose(np.asarray(a), np.asarray(b), atol=5e-4), schedule
        print('DP_MESH_OK', schedule)
    """)
    for schedule in ("fill_drain", "1f1b", "zb-h1"):
        assert f"DP_MESH_OK {schedule}" in out


# ------------------------------------------------- compiled eval path --


def test_compiled_evaluate_matches_host_eval(setup):
    """The forward-only jitted eval program: on a lossless halo plan (hops
    >= model depth) the chunked core-node metrics equal the host full-batch
    ``make_eval`` numbers — so --engine compiled validation can run through
    the compiled path without changing any reported accuracy."""
    from repro.train.loop import make_eval

    g, m, params = setup
    plan = make_plan(g, 3, strategy="halo", halo_hops=2)
    pipe = make_engine(m, GPipeConfig(engine="compiled", balance=(2, 1, 1, 2), chunks=3))
    got = pipe.evaluate(params, plan)
    want = make_eval(m)(params, g)
    assert set(got) == {"train_loss", "train_acc", "val_acc", "test_acc"}
    for k in got:
        assert abs(float(got[k]) - float(want[k])) < 1e-5, (k, got[k], want[k])


def test_compiled_evaluate_after_training(setup):
    """Eval and train steps share the engine: training through the
    scheduled executor then evaluating through the forward-only program
    works on the same instance (separate program caches), and the eval
    program is cached per plan shape."""
    g, m, _ = setup
    opt = opt_lib.adam(1e-2)
    pipe = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=2, schedule="zb-h1",
    ))
    plan = make_plan(g, 2, strategy="halo", halo_hops=2)
    key = jax.random.PRNGKey(42)
    params = pipe.init_params(key)
    state = opt.init(params)
    accs = []
    for _ in range(15):
        key, rng = jax.random.split(key)
        params, state, loss = pipe.train_step(params, state, plan, rng, opt)
        accs.append(float(pipe.evaluate(params, plan)["train_acc"]))
    assert len(pipe._evals) == 1  # one program per stacked-plan shape
    assert accs[-1] >= 0.8, accs[-1]
    # and the metrics agree with a host full-batch apply of the same params
    logp = m.apply(params, g)
    want = float(((jnp.argmax(logp, -1) == g.labels) * g.train_mask).sum()
                 / g.train_mask.sum())
    assert abs(accs[-1] - want) < 1e-5


@pytest.mark.parametrize("engine", ["host", "compiled"])
def test_eval_program_binds_params_once(setup, engine, monkeypatch):
    """The re-replication bugfix: ``compile_eval`` returns a bound
    EvalProgram and repeated calls with the *same params object* must not
    device_put the param tree again — binding is identity-cached, so a
    serving loop pays replication once per param version, not per batch.
    (On 1 device the eval mesh is absent and the count is zero throughout;
    the 4-forced-device serving test checks the mesh path.)"""
    g, m, params = setup
    plan = make_plan(g, 2, strategy="halo", halo_hops=2)
    pipe = make_engine(m, GPipeConfig(engine=engine, balance=(3, 3), chunks=2))
    first = pipe.evaluate(params, plan)  # compile + first bind outside the count

    calls = []
    real_put = jax.device_put

    def counting_put(*args, **kwargs):
        calls.append(1)
        return real_put(*args, **kwargs)

    monkeypatch.setattr(jax, "device_put", counting_put)
    again = pipe.evaluate(params, plan)
    assert not calls, f"evaluate re-replicated params: {len(calls)} device_puts"
    for k in first:
        assert float(first[k]) == float(again[k]), k
    # same shape + same params -> the exact same cached program object
    stacked = plan.stacked()
    assert pipe.compile_eval(params, stacked.graph) is pipe.compile_eval(
        params, stacked.graph
    )


# ------------------------------------------------ ragged / empty chunks --


def _plan_with_empty_chunk(g, chunks=3):
    """A ragged halo plan plus one chunk that is EMPTY after core-halo
    padding: its nodes are all pad duplicates of node 0 with core_mask False
    (count == 0), the shape every chunk in the plan shares."""
    import dataclasses as dc

    import numpy as np

    from repro.core.microbatch import MicroBatch
    from repro.graphs.data import subgraph
    from repro.graphs.partition import pad_partition

    plan = make_plan(g, chunks, strategy="halo", halo_hops=2)
    n_pad = max(mb.num_nodes for mb in plan.batches)
    nodes, core = pad_partition(
        np.zeros(0, dtype=np.int64), np.zeros(0, dtype=bool), n_pad
    )
    empty = MicroBatch(graph=subgraph(g, nodes), core_mask=jnp.asarray(core))
    assert int(empty.core_mask.sum()) == 0
    # replace() gives the new plan a fresh (empty) _stacked cache — the old
    # cache never carries over (the microbatch satellite bugfix)
    return dc.replace(plan, chunks=chunks + 1, batches=plan.batches + [empty])


def test_stacked_plan_keeps_empty_chunk_mask_correct(setup):
    g, _, _ = setup
    plan = _plan_with_empty_chunk(g, chunks=3)
    stacked = plan.stacked()
    assert stacked.chunks == 4
    # the empty chunk contributes zero loss rows and zero norm mass
    assert int(stacked.core_mask[3].sum()) == 0
    assert int((stacked.graph.train_mask[3] & stacked.core_mask[3]).sum()) == 0
    assert float(jnp.abs(stacked.graph.norm[3]).sum()) > 0  # self-loops exist...
    # ...but every loss-counting row across the plan is a real core node
    assert int(stacked.core_mask.sum()) == g.num_nodes


@pytest.mark.parametrize("schedule", ["1f1b", "zb-h1"])
def test_empty_chunk_trains_identically_on_both_engines(setup, schedule):
    """A count=0 chunk must ride the scheduled executor as an inert
    microbatch: same loss and params as the host engine running the same
    ragged plan, and everything stays finite — including through zb-h1's
    split B/W ticks, whose deferred weight grads for the empty chunk are
    all zeros."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    plan = _plan_with_empty_chunk(g, chunks=3)  # C = 4 incl. empty
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=4))
    comp = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=4, schedule=schedule,
    ))
    ph = pc = params
    oh = oc = opt.init(params)
    key = jax.random.PRNGKey(7)
    for _ in range(2):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
        assert jnp.isfinite(lh) and jnp.isfinite(lc)
        assert abs(float(lh) - float(lc)) < 1e-4, (float(lh), float(lc))
    _params_close(ph, pc, atol=5e-4)


# ---------------------------------------- communication/compute overlap --


@pytest.mark.parametrize("schedule,rotation,dp", [
    ("fill_drain", 1, 1),  # rotated ring: the serialized side must ALSO run
    ("1f1b", None, 1),     # the scheduled executor (the fused fill-drain
    ("zb-h1", None, 1),    # scan fuses differently at the float level)
    ("1f1b", None, 2),
])
def test_double_buffer_bit_identical_to_serialized(schedule, rotation, dp):
    """The tentpole's correctness property: retiming the wires to latency 2
    (ppermute pair posted one tick before its arrivals are consumed) is pure
    dataflow retiming — params after each step are BIT-identical to the
    serialized latency-1 executor, on every schedule and with the data axis
    active. On 1 device this exercises the lane substrate's pend-tuple
    rotation; under CI's 4 forced devices the real shard_map ring."""
    import numpy as np

    from repro.core.schedule import Placement

    plan, m = _dp_fixture(4)
    opt = opt_lib.adam(1e-2)
    params = m.init_params(jax.random.PRNGKey(0))
    placement = None if rotation is None else Placement.ring(2, rotation=rotation)
    engines = [
        make_engine(m, GPipeConfig(engine="compiled", balance=(2, 2), chunks=4,
                                   schedule=schedule, placement=placement,
                                   data_parallel=dp, overlap=overlap))
        for overlap in ("off", "double-buffer")
    ]
    ps = [params, params]
    os_ = [opt.init(params), opt.init(params)]
    key = jax.random.PRNGKey(42)
    stats = [{}, {}]
    for _ in range(2):
        key, rng = jax.random.split(key)
        for i, eng in enumerate(engines):
            ps[i], os_[i], _ = eng.train_step(
                ps[i], os_[i], plan, rng, opt, stats=stats[i]
            )
    assert stats[0]["wire_latency"] == 1
    assert stats[1]["wire_latency"] == 2
    assert stats[1]["num_ticks"] > stats[0]["num_ticks"]  # retime adds ticks
    for a, b in zip(jax.tree_util.tree_leaves(ps[0]), jax.tree_util.tree_leaves(ps[1])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            schedule, dp, float(jnp.max(jnp.abs(a - b))))


def test_double_buffer_matches_host_oracle(setup):
    """Engine-cross check on the paper model: the double-buffered 1f1b
    update agrees with the host fill-drain oracle at the standard engine
    tolerance (bit-identity is vs the serialized executor above)."""
    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    plan = make_plan(g, 4, strategy="halo", halo_hops=2)
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=4))
    comp = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=4, schedule="1f1b", overlap="double-buffer",
    ))
    ph = pc = params
    oh = oc = opt.init(params)
    key = jax.random.PRNGKey(42)
    for _ in range(2):
        key, rng = jax.random.split(key)
        ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
        pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
        assert abs(float(lh) - float(lc)) < 1e-4, (float(lh), float(lc))
    _params_close(ph, pc, atol=5e-4)


def test_empty_chunk_skips_its_ticks(setup):
    """Dead-tick elimination at the engine level: the ragged karate plan
    with a trailing EMPTY chunk runs in exactly the tick count of the clean
    3-chunk plan (the empty chunk's ticks are skipped, not pipelined), and
    the double-buffered executor composes with the skip — bit-identical
    params to the serialized run on the same ragged plan."""
    import numpy as np

    g, m, params = setup
    opt = opt_lib.adam(1e-2)
    ragged = _plan_with_empty_chunk(g, chunks=3)  # C = 4 incl. empty
    clean = make_plan(g, 3, strategy="halo", halo_hops=2)
    engines = {
        name: make_engine(m, GPipeConfig(engine="compiled",
            balance=(2, 1, 1, 2), chunks=4, schedule="1f1b", overlap=name))
        for name in ("off", "double-buffer")
    }
    clean3 = make_engine(m, GPipeConfig(engine="compiled",
        balance=(2, 1, 1, 2), chunks=3, schedule="1f1b"))
    st = {name: {} for name in engines}
    st["clean"] = {}
    ps = {}
    for name, eng in engines.items():
        p, o, loss = eng.train_step(
            params, opt.init(params), ragged, jax.random.PRNGKey(7), opt,
            stats=st[name],
        )
        assert jnp.isfinite(loss)
        ps[name] = p
    clean3.train_step(params, opt.init(params), clean, jax.random.PRNGKey(7),
                      opt, stats=st["clean"])
    assert st["off"]["num_ticks"] == st["clean"]["num_ticks"]
    for a, b in zip(jax.tree_util.tree_leaves(ps["off"]),
                    jax.tree_util.tree_leaves(ps["double-buffer"])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            float(jnp.max(jnp.abs(a - b))))


def test_overlap_validation(setup):
    """The host queue loop has no wires to double-buffer — overlap modes are
    compiled-engine only — and an unknown mode is a config error."""
    g, m, params = setup
    with pytest.raises(ValueError, match="host"):
        make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2),
                                   chunks=4, overlap="double-buffer"))
    with pytest.raises(ValueError, match="overlap"):
        make_engine(m, GPipeConfig(engine="compiled", balance=(2, 1, 1, 2),
                                   chunks=4, overlap="eager"))


# ------------------------------------------- pytree-generalized pipeline --


def test_spmd_pipeline_accepts_pytree_microbatches():
    """x may be any pytree of (num_micro, ...) leaves — mixed float/int/bool
    dtypes ride the scan + ppermute with the activations (the GNN contract).
    Runs under vmap(axis_name=...), which shares the collective semantics."""
    S, NM, D = 3, 4, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.4

    def stage_fn(my_in, state):
        s = jax.lax.axis_index("stage")
        wp = jax.lax.dynamic_index_in_dim(w, s, 0, keepdims=False)
        h = jnp.tanh(my_in["h"] @ wp)
        # int/bool leaves pass through untouched
        return dict(my_in, h=h), state

    x = {
        "h": jax.random.normal(jax.random.PRNGKey(1), (NM, 2, D)),
        "tag": jnp.arange(NM, dtype=jnp.int32),
        "flag": jnp.ones((NM,), bool),
    }

    def body(xs):
        out, _ = spmd_pipeline(
            stage_fn, xs, stage_axis="stage", num_stages=S, reduce="psum"
        )
        return out

    out = jax.jit(
        jax.vmap(body, in_axes=None, out_axes=0, axis_name="stage", axis_size=S)
    )(x)
    out = jax.tree_util.tree_map(lambda a: a[0], out)  # identical post-psum lanes
    ref = x["h"]
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    assert jnp.allclose(out["h"], ref, atol=1e-5)
    assert jnp.array_equal(out["tag"], x["tag"])
    assert jnp.array_equal(out["flag"], x["flag"])


def test_spmd_pipeline_reduce_validation():
    with pytest.raises(ValueError):
        spmd_pipeline(lambda a, b: (a, b), jnp.ones((2, 2)),
                      stage_axis="stage", num_stages=2, reduce="mean")


# ------------------------------------------------- multi-device substrate --


def _run(src: str, devices: int = 4, timeout: int = 1200):
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": "src",
    }
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**os.environ, **env},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_compiled_engine_matches_host_multidevice():
    """The full schedule×engine matrix on 4 simulated devices: the
    fill-drain shard_map/ppermute ring AND the scheduled executor (1F1B and
    zb-h1 split backward on the 4-device ring, interleaved on a 2-device
    ring with 2 virtual stages each) all produce the same per-epoch losses
    and post-step params as the host GPipe fill-drain baseline — and the
    forward-only compiled eval program agrees with the host full-batch
    eval on the ring substrate too."""
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.core.microbatch import make_plan
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.graphs import load_dataset
    from repro.models.gnn.net import build_paper_gat
    from repro.train import optimizer as opt_lib
    from repro.train.loop import make_eval

    assert jax.device_count() == 4, jax.device_count()
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="halo", halo_hops=2)
    host = make_engine(m, GPipeConfig(engine="host", balance=(2, 1, 1, 2), chunks=C))
    for schedule, nd in (("fill_drain", None), ("1f1b", None),
                         ("interleaved", 2), ("zb-h1", None), ("zb-v", 2)):
        comp = make_engine(m, GPipeConfig(engine="compiled",
            balance=(2, 1, 1, 2), chunks=C, schedule=schedule, num_devices=nd))
        ph = pc = params
        oh = oc = opt.init(params)
        key = jax.random.PRNGKey(42)
        for ep in range(3):
            key, rng = jax.random.split(key)
            ph, oh, lh = host.train_step(ph, oh, plan, rng, opt)
            pc, oc, lc = comp.train_step(pc, oc, plan, rng, opt)
            assert abs(float(lh) - float(lc)) < 1e-4, (schedule, ep, float(lh), float(lc))
        for a, b in zip(jax.tree_util.tree_leaves(ph), jax.tree_util.tree_leaves(pc)):
            assert jnp.allclose(a, b, atol=5e-4), (schedule, float(jnp.max(jnp.abs(a - b))))
        print('MD_ENGINE_OK', schedule)
    ev = comp.evaluate(pc, plan)
    want = make_eval(m)(pc, g)
    for k in ev:
        assert abs(float(ev[k]) - float(want[k])) < 1e-5, (k, ev[k], want[k])
    print('MD_EVAL_OK')
    """)
    for schedule in ("fill_drain", "1f1b", "interleaved", "zb-h1", "zb-v"):
        assert f"MD_ENGINE_OK {schedule}" in out
    assert "MD_EVAL_OK" in out


@pytest.mark.slow
def test_double_buffer_bit_identical_multidevice():
    """The tentpole property on the real 4-device shard_map ring: the
    double-buffered executor (ppermute pair for tick t+1 issued before
    tick t's work) produces BIT-identical params to the serialized latency-1
    executor for every schedule family — rotated fill-drain (so both sides
    run the scheduled path), 1f1b, zb-h1, and 1f1b on the 2x2 (data, stage)
    mesh."""
    out = _run("""
    import jax, numpy as np
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.core.schedule import Placement
    from repro.graphs import open_streamed, streamed_plan
    from repro.models.gnn.net import build_gnn
    from repro.train import optimizer as opt_lib

    assert jax.device_count() == 4, jax.device_count()
    ds = open_streamed("powerlaw-64k", num_nodes=512, block_size=256)
    plan = streamed_plan(ds, 4, max_degree=16)
    g0 = plan.batches[0].graph
    m = build_gnn("gcn", g0.num_features, g0.num_classes, hidden=16, depth=2)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = opt_lib.adam(1e-2)
    cases = [
        ("fill_drain", (1, 1, 1, 1), Placement.ring(4, rotation=1), 1),
        ("1f1b", (1, 1, 1, 1), None, 1),
        ("zb-h1", (1, 1, 1, 1), None, 1),
        ("1f1b", (2, 2), None, 2),  # 2 replicas x 2-stage ring
    ]
    for schedule, balance, placement, dp in cases:
        engines = [
            make_engine(m, GPipeConfig(engine="compiled", balance=balance,
                chunks=4, schedule=schedule, placement=placement,
                data_parallel=dp, overlap=overlap))
            for overlap in ("off", "double-buffer")
        ]
        ps = [params, params]
        os_ = [opt.init(params), opt.init(params)]
        key = jax.random.PRNGKey(42)
        stats = [{}, {}]
        for _ in range(2):
            key, rng = jax.random.split(key)
            for i, eng in enumerate(engines):
                ps[i], os_[i], _ = eng.train_step(
                    ps[i], os_[i], plan, rng, opt, stats=stats[i])
        assert stats[1]["wire_latency"] == 2 and stats[0]["wire_latency"] == 1
        for a, b in zip(jax.tree_util.tree_leaves(ps[0]),
                        jax.tree_util.tree_leaves(ps[1])):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                schedule, dp, float(np.max(np.abs(np.asarray(a) - np.asarray(b)))))
        print('MD_OVERLAP_OK', schedule, dp)
    """)
    for schedule, dp in (("fill_drain", 1), ("1f1b", 1), ("zb-h1", 1), ("1f1b", 2)):
        assert f"MD_OVERLAP_OK {schedule} {dp}" in out


# ---------------------------------------------- aggregation backend matrix --


BACKEND_MATRIX = [  # (engine, schedule): fused scan + split-B/W executor
    ("host", "fill_drain"),
    ("compiled", "fill_drain"),
    ("compiled", "zb-h1"),
]


def _backend_fixture(dataset):
    """(graph, model-factory, balance) for the backend-equivalence matrix.

    karate drives the paper GAT (attention path; attn_dropout=0 because the
    fused kernel is deterministic), the skewed twin drives a GCN whose padded
    layout is mostly padding — the case the bucketed layout exists for."""
    from repro.models.gnn.net import build_gnn

    if dataset == "karate":
        g = load_dataset("karate")
        def mk(backend):
            return build_paper_gat(g.num_features, g.num_classes,
                                   backend=backend, attn_dropout=0.0)
        return g, mk, (3, 3)
    g = load_dataset("skewed-mini")
    def mk(backend):
        return build_gnn("gcn", g.num_features, g.num_classes,
                         hidden=16, depth=2, backend=backend)
    return g, mk, (2, 2)


@pytest.mark.parametrize("dataset", ["karate", "skewed-mini"])
@pytest.mark.parametrize("engine,schedule", BACKEND_MATRIX)
def test_pallas_backend_matches_padded_host(dataset, engine, schedule):
    """backend="pallas" (degree-bucketed aggregation inside the stage
    programs) must reproduce the host fill-drain padded baseline's losses,
    updates and eval metrics on both engines, through the fused scan AND the
    split-B/W zb-h1 executor — the layout changes where edge slots live,
    never the math (float summation order absorbed by the oracle
    tolerance)."""
    g, mk, balance = _backend_fixture(dataset)
    opt = opt_lib.adam(1e-2)
    C = 2
    plan = make_plan(g, C, strategy="sequential")
    ref = make_engine(mk("padded"), GPipeConfig(
        engine="host", balance=balance, chunks=C, backend="padded"))
    pal = make_engine(mk("pallas"), GPipeConfig(
        engine=engine, balance=balance, chunks=C, schedule=schedule,
        backend="pallas"))
    params = ref.init_params(jax.random.PRNGKey(0))
    pr = pp = params
    orf = opl = opt.init(params)
    key = jax.random.PRNGKey(11)
    for _ in range(2):
        key, rng = jax.random.split(key)
        pr, orf, lr = ref.train_step(pr, orf, plan, rng, opt)
        pp, opl, lp = pal.train_step(pp, opl, plan, rng, opt)
        assert abs(float(lr) - float(lp)) < 1e-4, (dataset, engine, schedule)
    _params_close(pr, pp, atol=5e-4)
    ev_r, ev_p = ref.evaluate(pr, plan), pal.evaluate(pp, plan)
    for k in ev_r:
        assert abs(float(ev_r[k]) - float(ev_p[k])) < 1e-4, (k, ev_r[k], ev_p[k])


def test_engine_layout_cache_and_passthrough(setup):
    """PipelineEngine.layout: identity for non-pallas backends and for
    already-bucketed graphs; under backend="pallas" the bucketed wrapper is
    built once per stacked graph and cached by identity (entries retain the
    graph, so a recycled id() can never serve a stale layout)."""
    from repro.graphs.data import BucketedGraphBatch

    g, m, _ = setup
    plan = make_plan(g, 2, strategy="sequential")
    stacked = plan.stacked().graph

    padded = make_engine(m, GPipeConfig(engine="host", balance=(3, 3), chunks=2))
    assert padded.layout(stacked) is stacked

    pal = make_engine(m, GPipeConfig(engine="host", balance=(3, 3), chunks=2,
                                     backend="pallas"))
    wrapped = pal.layout(stacked)
    assert isinstance(wrapped, BucketedGraphBatch)
    assert wrapped.base is stacked
    assert pal.layout(stacked) is wrapped  # cached by identity
    assert pal.layout(wrapped) is wrapped  # already bucketed: pass through


@pytest.mark.slow
def test_pallas_backend_matches_padded_multidevice():
    """The backend axis on 4 simulated devices: the bucketed pallas stage
    programs ride the shard_map ring (fused fill-drain AND the zb-h1
    scheduled executor) and still match the host padded fill-drain
    baseline's updates at oracle tolerance."""
    out = _run("""
    import jax, jax.numpy as jnp
    from repro.core.microbatch import make_plan
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.graphs import load_dataset
    from repro.models.gnn.net import build_gnn
    from repro.train import optimizer as opt_lib

    assert jax.device_count() == 4, jax.device_count()
    g = load_dataset("skewed-mini")
    def mk(backend):
        return build_gnn("gcn", g.num_features, g.num_classes,
                         hidden=16, depth=4, backend=backend)
    opt = opt_lib.adam(1e-2)
    C = 4
    plan = make_plan(g, C, strategy="sequential")
    balance = (3, 3, 2)  # the depth-4 gcn stack's 8 layers over 3 stages
    ref = make_engine(mk("padded"), GPipeConfig(
        engine="host", balance=balance, chunks=C, backend="padded"))
    params = ref.init_params(jax.random.PRNGKey(0))
    for schedule in ("fill_drain", "zb-h1"):
        pal = make_engine(mk("pallas"), GPipeConfig(
            engine="compiled", balance=balance, chunks=C, schedule=schedule,
            backend="pallas"))
        pr = pp = params
        orf = opl = opt.init(params)
        key = jax.random.PRNGKey(11)
        for _ in range(2):
            key, rng = jax.random.split(key)
            pr, orf, lr = ref.train_step(pr, orf, plan, rng, opt)
            pp, opl, lp = pal.train_step(pp, opl, plan, rng, opt)
            assert abs(float(lr) - float(lp)) < 1e-4, (schedule, float(lr), float(lp))
        for a, b in zip(jax.tree_util.tree_leaves(pr), jax.tree_util.tree_leaves(pp)):
            assert jnp.allclose(a, b, atol=5e-4), (schedule, float(jnp.max(jnp.abs(a - b))))
        print('MD_BACKEND_OK', schedule)
    """)
    for schedule in ("fill_drain", "zb-h1"):
        assert f"MD_BACKEND_OK {schedule}" in out
