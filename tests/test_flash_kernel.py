"""Flash-attention Pallas kernel vs oracles (naive + blocked), with
shape/dtype/GQA/window/softcap sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.kernels.flash.ops import flash_attention
from repro.kernels.flash.ref import naive_attention
from repro.models.transformer.attention import blocked_attention


def _mk(b, s, h, kv, hd, dtype=jnp.float32, seed=0):
    k0 = jax.random.PRNGKey(seed)
    q = jax.random.normal(k0, (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(k0, 1), (b, s, kv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(k0, 2), (b, s, kv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (64, 0.0), (0, 50.0), (32, 50.0)])
def test_flash_matches_naive(h, kv, window, cap):
    b, s, hd = 1, 256, 16
    q, k, v = _mk(b, s, h, kv, hd)
    pos = jnp.arange(s)
    out = flash_attention(q, k, v, window, cap)
    ref = naive_attention(q, k, v, q_pos=pos, kv_pos=pos, window=window, attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_flash_matches_blocked_bf16():
    b, s, h, kv, hd = 1, 128, 2, 2, 32
    q, k, v = _mk(b, s, h, kv, hd, dtype=jnp.bfloat16)
    pos = jnp.arange(s)
    out = flash_attention(q, k, v, 0, 0.0, 64, 64)
    ref = blocked_attention(q, k, v, q_pos=pos, kv_pos=pos, kv_block=64)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=5e-2
    )


def test_flash_mla_style_vdim():
    """K head-dim ≠ V head-dim (MLA)."""
    b, s, h, hd, hdv = 1, 128, 2, 24, 16
    k0 = jax.random.PRNGKey(3)
    q = jax.random.normal(k0, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (b, s, h, hdv))
    pos = jnp.arange(s)
    out = flash_attention(q, k, v, 0, 0.0, 64, 64)
    ref = blocked_attention(q, k, v, q_pos=pos, kv_pos=pos, kv_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(
    s=st.sampled_from([128, 256, 384]),
    h=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 50),
)
def test_flash_hypothesis(s, h, hd, seed):
    q, k, v = _mk(1, s, h, h, hd, seed=seed)
    pos = jnp.arange(s)
    out = flash_attention(q, k, v, 0, 0.0, 128, 128)
    ref = naive_attention(q, k, v, q_pos=pos, kv_pos=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4)


def test_flash_grads():
    q, k, v = _mk(1, 128, 2, 2, 16)
    pos = jnp.arange(128)
    for arg in range(3):
        g1 = jax.grad(lambda *a: jnp.sum(flash_attention(*a) ** 2), argnums=arg)(q, k, v)
        g2 = jax.grad(
            lambda *a: jnp.sum(naive_attention(*a, q_pos=pos, kv_pos=pos) ** 2), argnums=arg
        )(q, k, v)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3)
