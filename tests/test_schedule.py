"""Schedule subsystem: timeline validity invariants, bubble/memory
accounting, and schedule dominance (1F1B/interleaved vs fill-drain)."""

import pytest

from repro.core.schedule import (
    PHASE_BWD,
    PHASE_BWD_B,
    PHASE_BWD_W,
    PHASE_FWD,
    PHASE_IDLE,
    FillDrainSchedule,
    InterleavedSchedule,
    OneFOneBSchedule,
    Placement,
    WorkItem,
    ZeroBubbleH1Schedule,
    bubble_fraction,
    forward_timeline,
    get_schedule,
    lower_timeline,
    peak_live_activations,
    retime_timeline,
    validate_timeline,
)

GRID = [(2, 2), (2, 4), (3, 3), (3, 6), (4, 4), (4, 8), (6, 8), (1, 4), (4, 2)]
INTERLEAVED_GRID = [  # (num_devices, num_stages, num_chunks); V = S / D
    (2, 4, 4), (2, 4, 8), (2, 6, 4), (4, 8, 8), (3, 6, 6), (2, 8, 2), (1, 4, 4),
]


def _schedules_for(S, C):
    scheds = [get_schedule("fill_drain"), get_schedule("1f1b"), get_schedule("zb-h1")]
    for D in range(1, S + 1):
        if S % D == 0 and C % D == 0 and C >= D:
            scheds.append(get_schedule("interleaved", num_devices=D))
    for D in range(1, S + 1):
        if S % D == 0:  # zb-v: round-robin placement, no chunk constraint
            scheds.append(get_schedule("zb-v", num_devices=D))
    return scheds


# ------------------------------------------------------ validity invariants --


@pytest.mark.parametrize("S,C", GRID)
def test_timelines_valid_all_schedules(S, C):
    """Each (stage, chunk, phase) exactly once; a chunk's bwd never precedes
    its fwd; stage dependencies respected; no device double-booked."""
    for sched in _schedules_for(S, C):
        validate_timeline(sched.timeline(S, C), S, C)


@pytest.mark.parametrize("S,C", GRID)
def test_timeline_sorted_and_ticks(S, C):
    for sched in _schedules_for(S, C):
        tl = sched.timeline(S, C)
        assert [it.tick for it in tl] == sorted(it.tick for it in tl)
        assert sched.ticks(S, C) == max(it.tick for it in tl) + 1


def test_fill_drain_closed_form_matches_timeline():
    fd = FillDrainSchedule()
    for S, C in GRID:
        tl = fd.timeline(S, C)
        assert fd.ticks(S, C) == 2 * (C + S - 1)
        assert fd.peak_live_activations(S, C) == S * C == peak_live_activations(tl)
        # generic timeline-based accounting agrees with the paper's formula
        generic = 1.0 - 2 * S * C / (S * fd.ticks(S, C))
        assert abs(fd.bubble_fraction(S, C) - generic) < 1e-12
        assert abs(fd.bubble_fraction(S, C) - (S - 1) / (C + S - 1)) < 1e-12


def test_device_placement():
    il = InterleavedSchedule(2)
    tl = il.timeline(4, 4)
    for it in tl:
        assert it.device == it.stage % 2
    fd = get_schedule("fill_drain")
    assert all(it.device == it.stage for it in fd.timeline(3, 3))


# ----------------------------------------------------------- dominance --


@pytest.mark.parametrize("S,C", [(s, c) for s, c in GRID if c >= s])
def test_1f1b_dominates_fill_drain(S, C):
    """For C >= S: 1F1B's bubble accounting is <= fill-drain's and (for
    S >= 2, C > 2) its peak live-activation count is strictly lower."""
    fd, ob = FillDrainSchedule(), OneFOneBSchedule()
    assert ob.bubble_fraction(S, C) <= fd.bubble_fraction(S, C) + 1e-12
    assert ob.ticks(S, C) <= fd.ticks(S, C)
    if S >= 2 and C > 2:
        assert ob.peak_live_activations(S, C) < fd.peak_live_activations(S, C)
    else:
        assert ob.peak_live_activations(S, C) <= fd.peak_live_activations(S, C)


def test_1f1b_peak_is_sum_of_windows():
    """1F1B caps stage s at min(S - s, C) in-flight activations."""
    ob = OneFOneBSchedule()
    for S, C in GRID:
        want = sum(min(S - s, C) for s in range(S))
        assert ob.peak_live_activations(S, C) == want, (S, C)


@pytest.mark.parametrize("D,S,C", INTERLEAVED_GRID)
def test_interleaved_bubble_beats_fill_drain_at_same_device_count(D, S, C):
    """V virtual stages per device divide the bubble by ~V: interleaved on D
    devices always has bubble <= fill-drain with S = D stages (strictly
    smaller whenever V > 1 and there is a bubble at all)."""
    il = InterleavedSchedule(D)
    V = S // D
    fd_bubble = bubble_fraction(D, C)  # fill-drain on the same D devices
    il_bubble = il.bubble_fraction(S, C)
    assert il_bubble <= fd_bubble + 1e-12
    if V > 1 and D > 1:
        assert il_bubble < fd_bubble
    # Megatron's closed form: fill = D - 1 ticks around V*C work ticks
    assert il.ticks(S, C) == 2 * (V * C + D - 1)
    assert abs(il_bubble - (D - 1) / (V * C + D - 1)) < 1e-12


def test_interleaved_validation_errors():
    il = InterleavedSchedule(2)
    with pytest.raises(ValueError):
        il.timeline(5, 4)  # stages not divisible by devices
    with pytest.raises(ValueError):
        il.timeline(4, 3)  # chunks not a multiple of devices
    with pytest.raises(ValueError):
        get_schedule("interleaved")  # num_devices required
    with pytest.raises(KeyError):
        get_schedule("no-such-schedule")


# ------------------------------------------------------- cost accounting --


def test_predicted_step_time_ordering():
    """At a fixed device count, interleaved's weighted makespan undercuts
    fill-drain's and 1F1B's (which tie for equal per-phase costs)."""
    kw = dict(fwd_cost_per_chunk=1.0, bwd_cost_per_chunk=2.0)
    fd = get_schedule("fill_drain").predicted_step_time(2, 4, **kw)
    ob = get_schedule("1f1b").predicted_step_time(2, 4, **kw)
    il = get_schedule("interleaved", num_devices=2).predicted_step_time(4, 4, **kw)
    assert abs(fd - ob) < 1e-9
    assert il < fd
    # rebuild term is schedule-independent
    fd_r = get_schedule("fill_drain").predicted_step_time(
        2, 4, rebuild_cost_per_chunk=0.5, **kw
    )
    assert abs((fd_r - fd) - 4 * 0.5) < 1e-9


def _all_schedules():
    return [
        ("fill_drain", get_schedule("fill_drain"), 4),
        ("1f1b", get_schedule("1f1b"), 4),
        ("zb-h1", get_schedule("zb-h1"), 4),
        ("interleaved", get_schedule("interleaved", num_devices=2), 4),
        ("zb-v", get_schedule("zb-v", num_devices=2), 4),
    ]


def test_predicted_step_time_stage_vector_uniform_unchanged():
    """Regression (the per-stage-cost satellite): the balanced-partition
    scalar path and an explicitly uniform per-stage vector must agree
    exactly for EVERY schedule — routing through ``_weighted`` is a
    refactor of the uniform model, not a change to it."""
    C = 4
    kw = dict(fwd_cost_per_chunk=1.0, bwd_cost_per_chunk=2.0, transfer_cost=0.1,
              rebuild_cost_per_chunk=0.05)
    for name, sched, S in _all_schedules():
        scalar = sched.predicted_step_time(S, C, **kw)
        vector = sched.predicted_step_time(
            S, C, transfer_cost=0.1, rebuild_cost_per_chunk=0.05,
            stage_fwd_costs=[1.0 / S] * S, stage_bwd_costs=[2.0 / S] * S,
        )
        assert abs(scalar - vector) < 1e-12, (name, scalar, vector)


def test_predicted_step_time_imbalanced_vector_changes_makespan():
    """An imbalanced vector with the SAME total cost lengthens the makespan
    (the slowest stage sets the tick — the divergence the balanced model
    hides), for every schedule."""
    C = 4
    fwd = [0.7, 0.1, 0.1, 0.1]
    bwd = [1.4, 0.2, 0.2, 0.2]
    for name, sched, S in _all_schedules():
        uniform = sched.predicted_step_time(
            S, C, stage_fwd_costs=[0.25] * S, stage_bwd_costs=[0.5] * S
        )
        skewed = sched.predicted_step_time(
            S, C, stage_fwd_costs=fwd, stage_bwd_costs=bwd
        )
        assert skewed > uniform + 1e-9, (name, skewed, uniform)
        # and the bottleneck bound holds: at least C ticks of the heaviest
        # stage's fwd+bwd work must appear in the makespan
        assert skewed >= C * (fwd[0] + bwd[0]) - 1e-9, (name, skewed)


def test_predicted_step_time_vector_validation():
    sched = get_schedule("1f1b")
    with pytest.raises(ValueError):
        sched.predicted_step_time(4, 4, stage_fwd_costs=[1.0] * 3,
                                  stage_bwd_costs=[1.0] * 4)
    with pytest.raises(ValueError):
        sched.predicted_step_time(4, 4, stage_fwd_costs=[1.0, 1.0, -0.5, 1.0],
                                  stage_bwd_costs=[1.0] * 4)
    # neither scalar nor vector given: every schedule raises the SAME
    # descriptive ValueError (zb-h1 used to trip a bare TypeError instead)
    for name, s, S in _all_schedules():
        with pytest.raises(ValueError, match="cost_per_chunk or stage_"):
            s.predicted_step_time(S, 4)


def test_zb_h1_uses_measured_bw_split():
    """zb-h1's weighted makespan can take the MEASURED B/W halves: a skewed
    split (W-heavy — e.g. a wide input conv's weight grad) prices worse than
    the 50/50 fallback of the same fused total, because the critical-path B
    stream no longer hides half the backward in drain ticks symmetrically —
    and passing halves that sum to the fused cost with an even split matches
    the fallback exactly."""
    zb = get_schedule("zb-h1")
    S = C = 4
    f = [0.25] * S
    bwd = [0.5] * S
    even = zb.predicted_step_time(S, C, stage_fwd_costs=f, stage_bwd_costs=bwd)
    via_halves = zb.predicted_step_time(
        S, C, stage_fwd_costs=f,
        stage_bwd_b_costs=[0.25] * S, stage_bwd_w_costs=[0.25] * S,
    )
    assert abs(even - via_halves) < 1e-12
    skewed = zb.predicted_step_time(
        S, C, stage_fwd_costs=f,
        stage_bwd_b_costs=[0.45] * S, stage_bwd_w_costs=[0.05] * S,
    )
    assert skewed != even  # the split, not just the total, moves the makespan
    with pytest.raises(ValueError):  # halves go together
        zb.predicted_step_time(S, C, stage_fwd_costs=f,
                               stage_bwd_b_costs=[0.25] * S)


def test_fill_drain_weighted_uniform_matches_closed_form():
    """FillDrain's generic weighted makespan (per-device stream ASAP) agrees
    with the paper's closed form on uniform costs — the closed form stays
    the fast path, the stream model extends it."""
    fd = FillDrainSchedule()
    for S, C in GRID:
        closed = fd.predicted_step_time(
            S, C, fwd_cost_per_chunk=1.0, bwd_cost_per_chunk=2.0
        )
        streamed = fd.predicted_step_time(
            S, C, stage_fwd_costs=[1.0 / S] * S, stage_bwd_costs=[2.0 / S] * S
        )
        assert abs(closed - streamed) < 1e-9, (S, C, closed, streamed)


# ------------------------------------------------------------- placement --


def test_placement_ring_constructor_and_validate():
    p = Placement.ring(4, rotation=1)
    assert p.stage_to_device == (1, 2, 3, 0)
    assert p.num_devices == 4
    assert Placement.ring(4, 2).stage_to_device == (0, 1, 0, 1)
    assert Placement.ring(4, 2, rotation=1).stage_to_device == (1, 0, 1, 0)
    # identity round-trips through validate
    assert Placement.ring(3).validate(3).stage_to_device == (0, 1, 2)


def test_placement_rejects_non_ring():
    with pytest.raises(ValueError):
        Placement((0, 2, 1, 3)).validate(4)  # not one hop per stage
    with pytest.raises(ValueError):
        Placement((3, 2, 1, 0)).validate(4)  # reversed ring
    with pytest.raises(ValueError):
        Placement((0, 1, 2)).validate(4)  # wrong length
    with pytest.raises(ValueError):
        Placement((1, 2, 3, 4)).validate(4)  # positions not 0-based/contiguous
    with pytest.raises(ValueError):
        Placement((0, 1, 0, 1), device_order=(0, 0)).validate(4)  # dup device
    with pytest.raises(ValueError):
        Placement((0, 1, 2, 3), device_order=(0, 1)).validate(4)  # wrong length


def test_placement_apply_lowers_for_every_schedule():
    """Every rotation of every schedule's default placement lowers cleanly
    (the ring check accepts it) and preserves the tick structure."""
    C = 4
    for name, sched, S in _all_schedules():
        D = sched.num_devices(S)
        for rot in range(D):
            p = Placement.ring(S, None if D == S else D, rotation=rot)
            items = p.apply(sched.timeline(S, C))
            low = lower_timeline(items, S, C)
            assert low.num_devices == D, (name, rot)
            for it in items:
                assert it.device == (sched.device_of(it.stage, S) + rot) % D


def test_placement_rotation_rotates_lowered_columns():
    """A rotation permutes the lowered per-tick columns, nothing else: the
    rotated lowering equals the identity lowering with columns rolled."""
    import numpy as np

    S = C = 4
    base = lower_timeline(OneFOneBSchedule().timeline(S, C), S, C)
    rot = lower_timeline(
        Placement.ring(S, rotation=1).apply(OneFOneBSchedule().timeline(S, C)), S, C
    )
    assert np.array_equal(np.roll(base.phase, 1, axis=1), rot.phase)
    assert np.array_equal(np.roll(base.stage, 1, axis=1), rot.stage)
    assert np.array_equal(np.roll(base.chunk, 1, axis=1), rot.chunk)
    assert base.n_fslots == rot.n_fslots
    assert base.peak_live_stash == rot.peak_live_stash


def test_validate_timeline_catches_violations():
    fd = FillDrainSchedule()
    S, C = 3, 3
    good = fd.timeline(S, C)
    with pytest.raises(AssertionError):  # duplicate item
        validate_timeline(good + [good[0]], S, C)
    bad = [
        WorkItem(it.tick, it.stage, it.chunk, it.phase)
        for it in good
        if not (it.stage == 0 and it.chunk == 0 and it.phase == "fwd")
    ]
    with pytest.raises(AssertionError):  # missing item
        validate_timeline(bad, S, C)
    # bwd before its fwd
    flipped = [
        WorkItem(
            (2 * (C + S - 1) - 1) - it.tick, it.stage, it.chunk, it.phase
        )
        for it in good
    ]
    with pytest.raises(AssertionError):
        validate_timeline(flipped, S, C)


def test_validate_timeline_rejects_bwd_before_next_stage_fwd():
    """Regression: a backward for chunk c on stage s scheduled before the
    forward of c on stage s+1 must be rejected — the cotangent it consumes
    does not exist yet. (The chained per-phase checks imply this for
    consistent timelines; the direct check pins the property and reports the
    offending item.)"""
    S, C = 3, 2
    good = {(it.stage, it.chunk, it.phase): it.tick
            for it in FillDrainSchedule().timeline(S, C)}
    bad = dict(good)
    # pull bwd(1, 0) to before fwd(2, 0)
    bad[(1, 0, "bwd")] = good[(2, 0, "fwd")] - 1
    items = [WorkItem(t, s, c, ph) for (s, c, ph), t in bad.items()]
    with pytest.raises(AssertionError):
        validate_timeline(items, S, C)
    # same-stage variant: bwd(1, 0) before fwd(1, 0)
    bad = dict(good)
    bad[(1, 0, "bwd")] = good[(1, 0, "fwd")]
    items = [WorkItem(t, s, c, ph) for (s, c, ph), t in bad.items()]
    with pytest.raises(AssertionError):
        validate_timeline(items, S, C)


# --------------------------------------------------- timeline lowering --


def _replay(low, skip=()):
    """Interpret the lowered index arrays against an abstract machine and
    assert the dataflow is exact: every fwd reads the value its upstream
    stage produced, every bwd/bwd_b reads the stage input it stashed and the
    cotangent its downstream stage sent back, every bwd_w reads the residual
    its matching bwd_b banked, slots never clobber live values."""
    S, C, D, T = low.num_stages, low.num_chunks, low.num_devices, low.num_ticks
    L = low.wire_latency  # sends reach the neighbour L ticks later
    flight_f = [[None] * D for _ in range(L)]  # flight_f[0] arrives this tick
    flight_b = [[None] * D for _ in range(L)]
    fstash = [[None] * (low.n_fslots + 1) for _ in range(D)]
    bstash = [[None] * (low.n_bslots + 1) for _ in range(D)]
    wstash = [[None] * (low.n_wslots + 1) for _ in range(D)]
    done_f, done_b, done_w, split = set(), set(), set(), set()
    for t in range(T):
        wire_f, wire_b = flight_f.pop(0), flight_b.pop(0)
        send_f, send_b = [None] * D, [None] * D
        for d in range(D):
            if low.in_fslot[t, d] < low.n_fslots:
                assert wire_f[d] is not None, (t, d, "banking a garbage fwd wire")
                fstash[d][low.in_fslot[t, d]] = wire_f[d]
            if low.in_bslot[t, d] < low.n_bslots:
                assert wire_b[d] is not None, (t, d, "banking a garbage bwd wire")
                bstash[d][low.in_bslot[t, d]] = wire_b[d]
        for d in range(D):
            ph = low.phase[t, d]
            if ph == PHASE_IDLE:
                continue
            s, c = int(low.stage[t, d]), int(low.chunk[t, d])
            if ph == PHASE_FWD:
                if s > 0:
                    got = fstash[d][low.work_fslot[t, d]]
                    assert got == ("act", s - 1, c), (t, d, got, ("act", s - 1, c))
                done_f.add((s, c))
                send_f[(d + 1) % D] = ("act", s, c)
            elif ph == PHASE_BWD_W:
                got = wstash[d][low.work_wslot[t, d]]
                assert got == ("res", s, c), (t, d, got, ("res", s, c))
                assert (s, c) in done_b, (t, d, "W before its B")
                done_w.add((s, c))
            else:  # fused bwd or split bwd_b
                assert (s, c) in done_f
                if s > 0:
                    got = fstash[d][low.work_fslot[t, d]]
                    assert got == ("act", s - 1, c), (t, d, "bwd stage input")
                if s < S - 1:
                    got = bstash[d][low.work_bslot[t, d]]
                    assert got == ("ct", s + 1, c), (t, d, got)
                done_b.add((s, c))
                send_b[(d - 1) % D] = ("ct", s, c)
                if ph == PHASE_BWD_B:
                    split.add((s, c))
                    assert low.store_wslot[t, d] < low.n_wslots, (t, d, "B has no W slot")
                    wstash[d][low.store_wslot[t, d]] = ("res", s, c)
        flight_f.append(send_f)
        flight_b.append(send_b)
    assert done_f == {
        (s, c) for s in range(S) for c in range(C) if c not in set(skip)
    }
    assert done_b == done_f
    assert done_w == split  # every banked residual consumed, none invented


@pytest.mark.parametrize("S,C", [(2, 2), (4, 4), (4, 8), (3, 6), (6, 8)])
def test_lowered_timeline_dataflow_exact(S, C):
    for sched in _schedules_for(S, C):
        low = lower_timeline(sched.timeline(S, C), S, C)
        assert low.phase.shape == (low.num_ticks, low.num_devices)
        assert int((low.phase == PHASE_FWD).sum()) == S * C
        # the input-grad half appears exactly once per (stage, chunk) —
        # fused for fill-drain/1F1B/interleaved, split for zb-h1 — and W
        # pairs off with B one to one
        n_b = int((low.phase == PHASE_BWD).sum() + (low.phase == PHASE_BWD_B).sum())
        assert n_b == S * C
        assert int((low.phase == PHASE_BWD_W).sum()) == int(
            (low.phase == PHASE_BWD_B).sum()
        )
        _replay(low)


@pytest.mark.parametrize("S,C", [(4, 4), (4, 8), (6, 6)])
def test_lowered_1f1b_stash_window(S, C):
    """The scheduled executor's stash realizes 1F1B's memory cap: the
    per-device slot count stays within the min(S, C) live window (+1 tick of
    wire slack), and true peak banked activations undercut fill-drain's."""
    ob = lower_timeline(OneFOneBSchedule().timeline(S, C), S, C)
    fd = lower_timeline(FillDrainSchedule().timeline(S, C), S, C)
    assert ob.n_fslots <= min(S, C) + 1
    assert fd.n_fslots == C  # fill-drain banks every chunk
    if C >= 4:
        assert ob.peak_live_stash < fd.peak_live_stash
    assert ob.peak_live_stash <= OneFOneBSchedule().peak_live_activations(S, C)


def test_lower_timeline_rejects_non_ring_placement():
    items = FillDrainSchedule().timeline(2, 2)
    # every stage on one device: two items per tick, caught by validation
    broken = [
        WorkItem(it.tick, it.stage, it.chunk, it.phase, device=0)
        for it in items
    ]
    with pytest.raises(AssertionError):
        lower_timeline(broken, 2, 2)
    # reversed placement (stage s on device S-1-s) is not a forward ring
    items = FillDrainSchedule().timeline(3, 3)
    reversed_ = [
        WorkItem(it.tick, it.stage, it.chunk, it.phase, device=2 - it.device)
        for it in items
    ]
    with pytest.raises(ValueError):
        lower_timeline(reversed_, 3, 3)


def test_lower_timeline_interleaved_devices():
    il = InterleavedSchedule(2)
    low = lower_timeline(il.timeline(4, 4), 4, 4)
    assert low.num_devices == 2
    # every device runs both of its virtual stages
    for d in range(2):
        stages = {int(s) for s, p in zip(low.stage[:, d], low.phase[:, d])
                  if p != PHASE_IDLE}
        assert stages == {d, d + 2}
    _replay(low)


def test_describe_keys():
    d = get_schedule("1f1b").describe(4, 8)
    for key in ("schedule", "ticks", "bubble_fraction", "peak_live_activations"):
        assert key in d
    assert d["schedule"] == "1f1b"


# ------------------------------------------------- zero-bubble (zb-h1) --


def _zb_timeline_dict(S, C):
    return {(it.stage, it.chunk, it.phase): (it.tick, it.device)
            for it in ZeroBubbleH1Schedule().timeline(S, C)}


@pytest.mark.parametrize("S,C", [(s, c) for s, c in GRID if s >= 2])
def test_zb_h1_dominates_1f1b(S, C):
    """The headline zero-bubble claims: zb-h1's bubble fraction sits
    strictly below 1F1B's whenever 1F1B has a bubble at all, B keeps 1F1B's
    activation window so peak live stage-inputs never exceed 1F1B's, and
    the weighted makespan (B = W = half a backward) undercuts 1F1B's."""
    zb, ob = ZeroBubbleH1Schedule(), OneFOneBSchedule()
    if ob.bubble_fraction(S, C) > 0:
        assert zb.bubble_fraction(S, C) < ob.bubble_fraction(S, C), (S, C)
    assert zb.peak_live_activations(S, C) <= ob.peak_live_activations(S, C)
    kw = dict(fwd_cost_per_chunk=1.0, bwd_cost_per_chunk=2.0)
    assert zb.predicted_step_time(S, C, **kw) <= ob.predicted_step_time(S, C, **kw)


def test_zb_h1_unit_cost_makespan():
    """With unit costs per phase the greedy zb-h1 scheduler achieves the
    analytic optimum: 3C work ticks per device + S - 1 fill ticks."""
    zb = ZeroBubbleH1Schedule()
    for S, C in [(2, 2), (4, 4), (4, 8), (3, 6), (6, 8)]:
        assert zb.ticks(S, C) == 3 * C + S - 1, (S, C, zb.ticks(S, C))


def test_zb_h1_every_w_after_its_b_on_same_device():
    tl = _zb_timeline_dict(4, 4)
    for s in range(4):
        for c in range(4):
            tb, db = tl[(s, c, "bwd_b")]
            tw, dw = tl[(s, c, "bwd_w")]
            assert tw > tb and dw == db == s
            assert (s, c, "bwd") not in tl


def test_zb_h1_lowering_w_slots():
    """The residual free-list realizes the deferred-W window: slots stay
    within C per device and the stash replay (in ``_replay``) is exact."""
    low = lower_timeline(ZeroBubbleH1Schedule().timeline(4, 4), 4, 4)
    assert low.n_wslots <= 4
    assert int((low.phase == PHASE_BWD_B).sum()) == 16
    assert int((low.phase == PHASE_BWD_W).sum()) == 16
    # fstash window identical to 1F1B's: B frees the stage input
    ob = lower_timeline(OneFOneBSchedule().timeline(4, 4), 4, 4)
    assert low.n_fslots == ob.n_fslots
    assert low.peak_live_stash <= ob.peak_live_stash


def test_validate_timeline_rejects_w_before_its_b():
    """Regression (the satellite bugfix): a W item scheduled before its
    matching B — or placed on a different device than its B — must be
    rejected: the residual it consumes either does not exist yet or lives
    on another device and never travels the wire."""
    S, C = 3, 2
    good = _zb_timeline_dict(S, C)
    T = 1 + max(t for t, _ in good.values())
    # pull bwd_w(1, 0) to before its bwd_b(1, 0)
    bad = dict(good)
    bad[(1, 0, "bwd_w")] = (good[(1, 0, "bwd_b")][0] - 1, 1)
    items = [WorkItem(t, s, c, ph, d) for (s, c, ph), (t, d) in bad.items()]
    with pytest.raises(AssertionError):
        validate_timeline(items, S, C)
    # same tick is also too early (W consumes the residual B writes)
    bad = dict(good)
    bad[(1, 0, "bwd_w")] = (good[(1, 0, "bwd_b")][0], 1)
    items = [WorkItem(t, s, c, ph, d) for (s, c, ph), (t, d) in bad.items()]
    with pytest.raises(AssertionError):
        validate_timeline(items, S, C)
    # W on a different device than its matching B (free tick, wrong place)
    bad = dict(good)
    bad[(1, 0, "bwd_w")] = (T + 1, 2)
    items = [WorkItem(t, s, c, ph, d) for (s, c, ph), (t, d) in bad.items()]
    with pytest.raises(AssertionError):
        validate_timeline(items, S, C)
    # a W whose B is missing entirely (fused bwd instead) is rejected too
    bad = dict(good)
    tb, db = bad.pop((1, 0, "bwd_b"))
    bad[(1, 0, "bwd")] = (tb, db)
    items = [WorkItem(t, s, c, ph, d) for (s, c, ph), (t, d) in bad.items()]
    with pytest.raises(AssertionError):
        validate_timeline(items, S, C)


# ----------------------------------------------- forward-only lowering --


def test_forward_timeline_lowering():
    """The eval path's timeline: fill-drain forwards only, one stash slot
    per device (wire slack), no cotangent or residual slots."""
    S, C = 4, 4
    items = forward_timeline(S, C)
    assert len(items) == S * C and all(it.phase == "fwd" for it in items)
    low = lower_timeline(items, S, C, forward_only=True)
    assert low.num_ticks == C + S - 1
    assert low.n_fslots == 1 and low.n_bslots == 0 and low.n_wslots == 0
    assert int((low.phase == PHASE_FWD).sum()) == S * C
    # a backward-bearing timeline does not pass the forward-only validator
    with pytest.raises(AssertionError):
        lower_timeline(
            FillDrainSchedule().timeline(S, C), S, C, forward_only=True
        )


# ------------------------------------------- wire retiming / dead ticks --


@pytest.mark.parametrize("S,C", [(2, 2), (4, 4), (4, 8), (3, 6), (6, 8)])
def test_retimed_latency2_dataflow_exact(S, C):
    """Retiming to wire latency 2 keeps the lowered dataflow exact: the
    retimed timeline passes lowering's arrival validation at latency 2 and
    the abstract-machine replay (arrivals land two ticks after the send, so
    each tick's ppermute pair can be posted one tick early)."""
    for sched in _schedules_for(S, C):
        items = retime_timeline(sched.timeline(S, C), S, C, wire_latency=2)
        low = lower_timeline(items, S, C, wire_latency=2)
        assert low.wire_latency == 2
        _replay(low)


@pytest.mark.parametrize("S,C", [(4, 4), (4, 8), (3, 6)])
def test_retime_preserves_per_device_order(S, C):
    """Retiming moves items later in time only — each device still runs the
    same (stage, chunk, phase) sequence, so stash slot assignment and the
    executor's work arrays describe the same program."""
    for sched in _schedules_for(S, C):
        items = sorted(sched.timeline(S, C), key=lambda it: (it.tick, it.stage))
        moved = retime_timeline(items, S, C, wire_latency=2)
        assert len(moved) == len(items)
        for d in {it.device for it in items}:
            before = [(it.stage, it.chunk, it.phase)
                      for it in sorted(items, key=lambda it: it.tick)
                      if it.device == d]
            after = [(it.stage, it.chunk, it.phase)
                     for it in sorted(moved, key=lambda it: it.tick)
                     if it.device == d]
            assert after == before


def test_latency2_lowering_requires_retime():
    """An unretimed timeline has 1-tick wire edges; lowering it at latency 2
    must refuse (the consumer would read a value still in flight)."""
    items = OneFOneBSchedule().timeline(4, 4)
    with pytest.raises(ValueError, match="retime the timeline first"):
        lower_timeline(items, 4, 4, wire_latency=2)
    with pytest.raises(ValueError, match="wire_latency"):
        lower_timeline(items, 4, 4, wire_latency=0)


@pytest.mark.parametrize("schedule,slack", [("fill_drain", 0), ("1f1b", 0), ("zb-h1", 1)])
def test_skip_chunks_collapses_to_smaller_plan_tick_count(schedule, slack):
    """Dead-tick elimination: lowering the C=4 timeline with the trailing
    chunk skipped runs in the C=3 timeline's tick count — an empty chunk in
    a ragged plan costs zero ticks, not a full pipeline pass. (zb-h1 keeps
    one extra WORKING tick: its C=4 drain places deferred W ticks
    differently than the native C=3 timeline does.)"""
    S = 4
    sched = get_schedule(schedule)
    skipped = lower_timeline(sched.timeline(S, 4), S, 4, skip_chunks=(3,))
    smaller = lower_timeline(sched.timeline(S, 3), S, 3)
    assert skipped.num_ticks <= smaller.num_ticks + slack
    assert skipped.num_ticks < lower_timeline(sched.timeline(S, 4), S, 4).num_ticks
    _replay(skipped, skip=(3,))
    # at wire latency 1 every surviving tick either works or banks an
    # arrival — the all-idle ticks are gone
    for t in range(skipped.num_ticks):
        assert (
            (skipped.phase[t] != PHASE_IDLE).any()
            or (skipped.in_fslot[t] < skipped.n_fslots).any()
            or (skipped.in_bslot[t] < skipped.n_bslots).any()
        ), f"tick {t} is dead but survived"


def test_skip_chunks_latency2_dataflow():
    """skip_chunks composes with the retimed latency-2 lowering: arrival
    distances stay exactly wire_latency across the tick remap."""
    S, C = 4, 4
    items = retime_timeline(OneFOneBSchedule().timeline(S, C), S, C, wire_latency=2)
    low = lower_timeline(items, S, C, wire_latency=2, skip_chunks=(3,))
    assert low.wire_latency == 2
    _replay(low, skip=(3,))


def test_skip_chunks_validates_before_filtering():
    """Skip filtering happens AFTER full-timeline validation: an invalid
    timeline is rejected even when the offending items are in the skipped
    chunk, and out-of-range / total skips are named errors."""
    S, C = 3, 2
    items = FillDrainSchedule().timeline(S, C)
    with pytest.raises(ValueError, match="outside the chunk range"):
        lower_timeline(items, S, C, skip_chunks=(5,))
    with pytest.raises(ValueError, match="removed every item"):
        lower_timeline(items, S, C, skip_chunks=(0, 1))
    # corrupt chunk 1's bwd ordering; skipping chunk 1 must not hide it
    bad = [
        WorkItem(0 if (it.chunk, it.phase) == (1, "bwd") else it.tick,
                 it.stage, it.chunk, it.phase, it.device)
        for it in items
    ]
    with pytest.raises(AssertionError):
        lower_timeline(bad, S, C, skip_chunks=(1,))
