"""Cost-model-driven stage partitioning: the profiler over real jitted layer
slices, the makespan-minimizing partitioner, and the per-stage cost vectors
it routes through the schedules' ``_weighted`` hooks."""

import jax
import pytest

from repro.core.costmodel import (
    LayerCosts,
    choose_balance,
    enumerate_balances,
    predicted_balance_time,
    profile_layer_costs,
    uniform_balance,
)
from repro.core.microbatch import make_plan
from repro.core.schedule import get_schedule
from repro.graphs import load_dataset
from repro.models.gnn.net import build_imbalanced_gcn, build_paper_gat


def _costs(fwd, scale_b=1.0, scale_w=1.0):
    return LayerCosts(
        names=tuple(f"l{i}" for i in range(len(fwd))),
        fwd=tuple(fwd),
        bwd=tuple(f * (scale_b + scale_w) for f in fwd),
        bwd_b=tuple(f * scale_b for f in fwd),
        bwd_w=tuple(f * scale_w for f in fwd),
    )


# ------------------------------------------------------------ partitioner --


def test_uniform_balance_contiguous_split():
    assert uniform_balance(8, 4) == (2, 2, 2, 2)
    assert uniform_balance(6, 4) == (2, 2, 1, 1)
    assert uniform_balance(4, 4) == (1, 1, 1, 1)
    with pytest.raises(ValueError):
        uniform_balance(3, 4)


def test_enumerate_balances_counts_and_sums():
    bals = list(enumerate_balances(6, 3))
    assert len(bals) == 10  # C(5, 2)
    assert all(sum(b) == 6 and all(x >= 1 for x in b) for b in bals)
    assert len(set(bals)) == len(bals)


def test_partitioner_prefers_uniform_on_uniform_costs():
    """Flat per-layer costs: the layer-count split already minimizes the
    makespan; the tie-break must return it (not an arbitrary winner)."""
    costs = _costs([1.0] * 8)
    for name in ("fill_drain", "1f1b", "zb-h1"):
        bal, _ = choose_balance(costs, 4, get_schedule(name), 4)
        assert bal == (2, 2, 2, 2), (name, bal)


def test_partitioner_isolates_heavy_layer():
    """One dominant layer: every schedule's best partition gives it its own
    stage — the bottleneck sets the tick, so co-locating anything with it
    only stretches the makespan."""
    costs = _costs([10.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
    for name in ("fill_drain", "1f1b", "zb-h1"):
        bal, t = choose_balance(costs, 4, get_schedule(name), 4)
        assert bal[0] == 1, (name, bal)
        uni_t = predicted_balance_time(costs, (2, 2, 2, 2), get_schedule(name), 4)
        assert t < uni_t, (name, t, uni_t)


def test_partitioner_never_worse_than_uniform():
    """The chosen balance's predicted makespan is <= the uniform split's for
    every schedule (uniform is in the candidate set)."""
    costs = _costs([3.0, 0.5, 2.0, 0.1, 0.1, 4.0, 0.2, 0.3], scale_b=0.8, scale_w=1.3)
    for name, nd in (("fill_drain", None), ("1f1b", None), ("zb-h1", None),
                     ("interleaved", 2), ("zb-v", 2)):
        sched = get_schedule(name, num_devices=nd)
        bal, t = choose_balance(costs, 4, sched, 4)
        assert t <= predicted_balance_time(costs, uniform_balance(8, 4), sched, 4)


def test_stage_costs_and_validation():
    costs = _costs([1.0, 2.0, 3.0, 4.0])
    f, b = costs.stage_costs((1, 3))
    assert f == [1.0, 9.0]
    assert b == [2.0, 18.0]  # bwd = b + w = 2x fwd here
    f, bb, bw = costs.stage_costs_split((1, 3))
    assert bb == [1.0, 9.0] and bw == [1.0, 9.0]
    with pytest.raises(ValueError):
        costs.stage_costs((2, 3))
    with pytest.raises(ValueError):
        choose_balance(_costs([1.0] * 40), 20, get_schedule("1f1b"), 4,
                       max_candidates=10)


def test_zb_partitioning_weights_measured_bw_halves():
    """predicted_balance_time hands zb-h1 the measured B/W halves, not the
    50/50 fallback: two cost tables with identical fused backwards but
    opposite B/W skew price differently under zb-h1 (and identically under
    a fused-backward schedule, which only sees the sum)."""
    fwd = [1.0, 1.0, 1.0, 1.0]
    b_heavy = LayerCosts(names=("a", "b", "c", "d"), fwd=tuple(fwd),
                         bwd=(2.0,) * 4, bwd_b=(1.8,) * 4, bwd_w=(0.2,) * 4)
    w_heavy = LayerCosts(names=("a", "b", "c", "d"), fwd=tuple(fwd),
                         bwd=(2.0,) * 4, bwd_b=(0.2,) * 4, bwd_w=(1.8,) * 4)
    bal = (1, 1, 1, 1)
    zb = get_schedule("zb-h1")
    assert b_heavy.bwd == w_heavy.bwd
    t_b = predicted_balance_time(b_heavy, bal, zb, 4)
    t_w = predicted_balance_time(w_heavy, bal, zb, 4)
    assert t_b != t_w
    ob = get_schedule("1f1b")
    assert abs(
        predicted_balance_time(b_heavy, bal, ob, 4)
        - predicted_balance_time(w_heavy, bal, ob, 4)
    ) < 1e-12


def test_cost_table_shape():
    table = _costs([1.0, 2.0]).table()
    assert [r["name"] for r in table] == ["l0", "l1"]
    assert all({"layer", "name", "fwd_s", "bwd_b_s", "bwd_w_s"} <= set(r) for r in table)


# --------------------------------------------------------------- profiler --


@pytest.fixture(scope="module")
def karate_chunk():
    g = load_dataset("karate")
    plan = make_plan(g, 2, strategy="sequential")
    return g, jax.tree_util.tree_map(lambda a: a[0], plan.stacked().graph)


def test_profiler_measures_every_layer(karate_chunk):
    g, chunk0 = karate_chunk
    model = build_paper_gat(g.num_features, g.num_classes)
    costs = profile_layer_costs(
        model, model.init_params(jax.random.PRNGKey(0)), chunk0, repeats=2
    )
    assert costs.names == tuple(layer.name for layer in model.layers)
    assert len(costs.fwd) == len(model.layers)
    assert all(c > 0 for c in costs.fwd + costs.bwd + costs.bwd_b)
    assert all(c >= 0 for c in costs.bwd_w)
    # the fused backward is measured DIRECTLY (one vjp, one primal), not
    # summed from the halves (two primals) — on tiny layers dispatch noise
    # swamps the primal, so only the structural bound is asserted; a single
    # scheduler hiccup can still break it, so one re-profile is allowed
    def _bound_holds(c):
        return all(b < 2 * (bb + bw) for b, bb, bw in
                   zip(c.bwd, c.bwd_b, c.bwd_w))

    if not _bound_holds(costs):
        costs = profile_layer_costs(
            model, model.init_params(jax.random.PRNGKey(0)), chunk0, repeats=3
        )
    assert _bound_holds(costs), (costs.bwd, costs.bwd_b, costs.bwd_w)


def test_profiler_ranks_imbalanced_stack(karate_chunk):
    """On the deliberately imbalanced fixture the measured cost of the
    widest conv dominates the tail convs — the ordering the partitioner's
    win rests on. (karate is tiny, so the tail costs are mostly dispatch
    noise: the heavy 1024-wide conv must clear their max with margin.)"""
    g, chunk0 = karate_chunk
    model = build_imbalanced_gcn(g.num_features, g.num_classes,
                                 hidden=(1024, 1024, 4, 4, 4, 4))
    costs = profile_layer_costs(
        model, model.init_params(jax.random.PRNGKey(0)), chunk0, repeats=3
    )
    heavy = costs.fwd[1]  # the 1024 -> 1024 conv
    tail = max(costs.fwd[2:])
    assert heavy > 1.5 * tail, (costs.fwd,)


def test_profiled_balance_runs_through_engine(karate_chunk):
    """End-to-end: profile -> choose_balance -> engine accepts the balance
    and trains (partitioning moves layer boundaries, never the math)."""
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.train import optimizer as opt_lib

    g, chunk0 = karate_chunk
    model = build_imbalanced_gcn(g.num_features, g.num_classes,
                                 hidden=(64, 8, 8, 8, 8, 8))
    params = model.init_params(jax.random.PRNGKey(0))
    costs = profile_layer_costs(model, params, chunk0, repeats=1)
    bal, _ = choose_balance(costs, 4, get_schedule("1f1b"), 2)
    assert sum(bal) == len(model.layers)
    plan = make_plan(g, 2, strategy="sequential")
    pipe = make_engine(model, GPipeConfig(engine="compiled",
        balance=bal, chunks=2, schedule="1f1b",
    ))
    opt = opt_lib.adam(1e-2)
    state = opt.init(params)
    params, state, loss = pipe.train_step(
        params, state, plan, jax.random.PRNGKey(1), opt
    )
    assert float(loss) == float(loss)  # finite, engine accepted the balance
