"""Roofline machinery: loop-aware HLO walker + report math."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import model_flops, roofline_report
from repro.roofline.hlo_walk import analyze_hlo, parse_module
from repro.configs import get_arch, get_shape


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_walker_counts_plain_dot():
    m, k, n = 64, 32, 16
    txt = _compile_text(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    )
    r = analyze_hlo(txt)
    assert r["flops"] == pytest.approx(2 * m * k * n, rel=0.01)


def test_walker_multiplies_scan_trip_count():
    m = 32

    def f(a, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, a, None, length=10)
        return out

    txt = _compile_text(
        f,
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32),
    )
    r = analyze_hlo(txt)
    # ten matmuls, not one
    assert r["flops"] == pytest.approx(10 * 2 * m * m * m, rel=0.05)


def test_walker_nested_scans():
    m = 16

    def f(a, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, a, None, length=4)
        return out

    txt = _compile_text(
        f,
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32),
    )
    r = analyze_hlo(txt)
    assert r["flops"] == pytest.approx(12 * 2 * m ** 3, rel=0.05)


def test_parse_module_finds_computations():
    txt = _compile_text(lambda a: jnp.sum(a * a), jax.ShapeDtypeStruct((8, 8), jnp.float32))
    comps = parse_module(txt)
    assert len(comps) >= 1


def test_roofline_report_terms():
    rep = roofline_report(
        device_flops=197e12,  # exactly one second of compute
        device_bytes=819e9,  # exactly one second of HBM
        device_collective={"total": 0, "all-gather": 0},
        chips=256,
        model_flops_global=197e12 * 256 * 0.5,
    )
    assert rep["compute_s"] == pytest.approx(1.0)
    assert rep["memory_s"] == pytest.approx(1.0)
    assert rep["collective_s"] == 0.0
    assert rep["useful_flops_ratio"] == pytest.approx(0.5)
    assert rep["dominant"] in ("compute_s", "memory_s")


def test_model_flops_train_vs_decode():
    cfg = get_arch("codeqwen1.5-7b")
    tr = model_flops(cfg, get_shape("train_4k"), training=True)
    de = model_flops(cfg, get_shape("decode_32k"), training=False)
    # train: 6·N·(256·4096) ; decode: 2·N·128
    assert tr / de == pytest.approx(3 * 256 * 4096 / 128, rel=0.01)


def test_moe_active_params_used():
    cfg = get_arch("deepseek-v3-671b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
    mf = model_flops(cfg, get_shape("train_4k"), training=True)
    assert mf == pytest.approx(6 * cfg.active_param_count() * 256 * 4096, rel=1e-6)


# ----------------------------------------------- per-stage layout roofline --


def test_sparse_stage_report_padded_vs_bucketed():
    """The fig-row payload: per-stage measured HLO flops/bytes for the padded
    vs degree-bucketed layouts, against the live-slot roof. The invariants
    the report exists to show: measured >= roof (it is a floor), the padded
    layout materializes more slots than the bucketed one, and neither layout
    can undercut the live count."""
    from repro.core.microbatch import make_plan
    from repro.graphs import bucketize_stacked, load_dataset
    from repro.models.gnn.net import build_gnn
    from repro.roofline import layout_slots, live_slots, sparse_stage_report

    g = load_dataset("skewed-mini")
    model = build_gnn("gcn", g.num_features, g.num_classes,
                      hidden=16, depth=2, backend="pallas")
    params = model.init_params(jax.random.PRNGKey(0))
    plan = make_plan(g, 2, strategy="sequential")
    stacked = plan.stacked().graph
    bucketed = bucketize_stacked(stacked)

    assert live_slots(stacked) == live_slots(bucketed)
    assert layout_slots(bucketed) < layout_slots(stacked)
    assert live_slots(bucketed) <= layout_slots(bucketed)

    report = sparse_stage_report(model, params, stacked, bucketed, (2, 2))
    assert report["slots"]["bucketed"] < report["slots"]["padded"]
    assert report["slots"]["live"] <= report["slots"]["bucketed"]
    assert len(report["stages"]) == 2
    for row in report["stages"]:
        assert row["layers"]
        for layout in ("padded", "bucketed"):
            assert row[layout]["measured_flops"] >= row["roof_flops"] * 0.99
            assert row[layout]["measured_bytes"] >= row["roof_bytes"] * 0.99
    # the stack in total reads fewer bytes through the bucketed tiles
    total = {
        layout: sum(r[layout]["measured_bytes"] for r in report["stages"])
        for layout in ("padded", "bucketed")
    }
    assert total["bucketed"] < total["padded"]
