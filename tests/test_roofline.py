"""Roofline machinery: loop-aware HLO walker + report math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import HW, model_flops, roofline_report
from repro.roofline.hlo_walk import analyze_hlo, parse_module
from repro.configs import get_arch, get_shape


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_walker_counts_plain_dot():
    m, k, n = 64, 32, 16
    txt = _compile_text(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    )
    r = analyze_hlo(txt)
    assert r["flops"] == pytest.approx(2 * m * k * n, rel=0.01)


def test_walker_multiplies_scan_trip_count():
    m = 32

    def f(a, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, a, None, length=10)
        return out

    txt = _compile_text(
        f,
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32),
    )
    r = analyze_hlo(txt)
    # ten matmuls, not one
    assert r["flops"] == pytest.approx(10 * 2 * m * m * m, rel=0.05)


def test_walker_nested_scans():
    m = 16

    def f(a, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, a, None, length=4)
        return out

    txt = _compile_text(
        f,
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((m, m), jnp.float32),
    )
    r = analyze_hlo(txt)
    assert r["flops"] == pytest.approx(12 * 2 * m ** 3, rel=0.05)


def test_parse_module_finds_computations():
    txt = _compile_text(lambda a: jnp.sum(a * a), jax.ShapeDtypeStruct((8, 8), jnp.float32))
    comps = parse_module(txt)
    assert len(comps) >= 1


def test_roofline_report_terms():
    rep = roofline_report(
        device_flops=197e12,  # exactly one second of compute
        device_bytes=819e9,  # exactly one second of HBM
        device_collective={"total": 0, "all-gather": 0},
        chips=256,
        model_flops_global=197e12 * 256 * 0.5,
    )
    assert rep["compute_s"] == pytest.approx(1.0)
    assert rep["memory_s"] == pytest.approx(1.0)
    assert rep["collective_s"] == 0.0
    assert rep["useful_flops_ratio"] == pytest.approx(0.5)
    assert rep["dominant"] in ("compute_s", "memory_s")


def test_model_flops_train_vs_decode():
    cfg = get_arch("codeqwen1.5-7b")
    tr = model_flops(cfg, get_shape("train_4k"), training=True)
    de = model_flops(cfg, get_shape("decode_32k"), training=False)
    # train: 6·N·(256·4096) ; decode: 2·N·128
    assert tr / de == pytest.approx(3 * 256 * 4096 / 128, rel=0.01)


def test_moe_active_params_used():
    cfg = get_arch("deepseek-v3-671b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
    mf = model_flops(cfg, get_shape("train_4k"), training=True)
    assert mf == pytest.approx(6 * cfg.active_param_count() * 256 * 4096, rel=1e-6)
