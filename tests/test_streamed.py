"""Streamed power-law generator: chunk-invariance, padded-layout equality,
plan construction, and the double-buffered host->device loader.

The generator's contract (see ``repro.graphs.datasets``) is that every chunk
``[lo, hi)`` is a pure function of (name, seed, node ids) — independent of
how the node axis is split. These tests pin that down by comparing arbitrary
(including block-misaligned) ranges against restrictions of a whole-graph
build, and check the vectorized padded-row constructor against the reference
``build_graph_batch`` path edge-list for edge-list.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.graphs import (
    STREAMED_DATASETS,
    DoubleBufferedLoader,
    open_streamed,
    streamed_plan,
    validate_graph,
)
from repro.graphs.data import build_graph_batch
from repro.graphs.datasets import _padded_rows_from_edges

N_SMALL = 2048  # overridden node count: full graph stays test-sized


@pytest.fixture(scope="module")
def ds():
    return open_streamed("powerlaw-64k", num_nodes=N_SMALL, block_size=512)


def _edge_set(edges):
    return {(int(a), int(b)) for a, b in edges}


def test_registry_and_override():
    assert set(STREAMED_DATASETS) == {"powerlaw-64k", "powerlaw-256k", "powerlaw-1m"}
    full = open_streamed("powerlaw-64k")
    assert full.num_nodes == 65_536
    small = open_streamed("powerlaw-64k", num_nodes=100)
    assert small.num_nodes == 100
    with pytest.raises(KeyError):
        open_streamed("not-a-dataset")


def test_chunk_edges_is_restriction(ds):
    """Edges of any sub-range are exactly the whole-graph edges with both
    endpoints inside it — chunking can drop cut edges but never invent,
    move, or duplicate any."""
    full, _ = ds.chunk_edges(0, ds.num_nodes)
    full_set = _edge_set(full)
    for lo, hi in [(0, 512), (512, 1024), (300, 900), (1, ds.num_nodes - 1)]:
        sub, dropped = ds.chunk_edges(lo, hi)
        want = {(a - lo, b - lo) for a, b in full_set if lo <= a < hi and lo <= b < hi}
        assert _edge_set(sub) == want, (lo, hi)
        # every proper sub-range of a connected power-law graph cuts edges
        assert dropped > 0, (lo, hi)


def test_chunk_batch_fields_are_chunk_invariant(ds):
    """Per-node fields (features, labels, splits) of a misaligned chunk are
    bit-equal to the same rows of the whole-graph build."""
    whole = ds.chunk_batch(0, ds.num_nodes)
    lo, hi = 300, 900  # straddles block boundaries at 512
    part = ds.chunk_batch(lo, hi)
    assert part.num_nodes == hi - lo
    np.testing.assert_array_equal(
        np.asarray(part.features), np.asarray(whole.features)[lo:hi])
    np.testing.assert_array_equal(
        np.asarray(part.labels), np.asarray(whole.labels)[lo:hi])
    np.testing.assert_array_equal(
        np.asarray(part.train_mask), np.asarray(whole.train_mask)[lo:hi])
    np.testing.assert_array_equal(
        np.asarray(part.node_ids), np.arange(lo, hi))
    validate_graph(part)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(64, 1024))
def test_chunk_batch_property_any_range(start, width):
    """Property form of chunk invariance: ANY [lo, lo+width) range agrees
    with the whole-graph restriction on per-node fields and kept edges."""
    ds = open_streamed("powerlaw-64k", num_nodes=N_SMALL, block_size=512)
    lo = start % (ds.num_nodes - 64)
    hi = min(lo + width, ds.num_nodes)
    whole = ds.chunk_batch(0, ds.num_nodes)
    part = ds.chunk_batch(lo, hi)
    np.testing.assert_array_equal(
        np.asarray(part.features), np.asarray(whole.features)[lo:hi])
    np.testing.assert_array_equal(
        np.asarray(part.labels), np.asarray(whole.labels)[lo:hi])
    full_set = _edge_set(ds.chunk_edges(0, ds.num_nodes)[0])
    want = {(a - lo, b - lo) for a, b in full_set if lo <= a < hi and lo <= b < hi}
    assert _edge_set(ds.chunk_edges(lo, hi)[0]) == want


def test_degree_distribution_sanity(ds):
    """The zipf degree draw produces an actual heavy tail: max degree well
    above the median, capped by deg_cap, and no isolated-majority."""
    edges, _ = ds.chunk_edges(0, ds.num_nodes)
    deg = np.bincount(np.concatenate([edges[:, 0], edges[:, 1]]),
                      minlength=ds.num_nodes)
    assert np.median(deg) >= 1
    assert deg.max() > 4 * np.median(deg)  # heavy tail
    # target degree was capped; unions of undirected pairs can at most double
    assert deg.max() <= 2 * ds.deg_cap
    assert (deg == 0).mean() < 0.1


def test_split_fractions(ds):
    b = ds.chunk_batch(0, ds.num_nodes)
    tr = float(np.asarray(b.train_mask).mean())
    va = float(np.asarray(b.val_mask).mean())
    te = float(np.asarray(b.test_mask).mean())
    assert 0.06 < tr < 0.14 and 0.02 < va < 0.08 and 0.02 < te < 0.08
    # disjoint
    assert not np.any(np.asarray(b.train_mask) & np.asarray(b.val_mask))
    assert not np.any(np.asarray(b.train_mask) & np.asarray(b.test_mask))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5), st.integers(20, 60), st.integers(30, 120))
def test_padded_rows_match_build_graph_batch(seed, n, m):
    """The vectorized padded-layout constructor is bit-identical to the
    reference ``build_graph_batch`` on the same edge list (neighbors, mask,
    and norm), including degree truncation."""
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(m, 2))
    feats = rng.standard_normal((n, 4)).astype(np.float32)
    labels = rng.integers(0, 3, size=n)
    for cap in (None, 4):
        ref = build_graph_batch(feats, edges, labels, 3, max_degree=cap)
        nbr, mask, norm = _padded_rows_from_edges(n, edges, max_degree=cap)
        np.testing.assert_array_equal(np.asarray(ref.neighbors), nbr)
        np.testing.assert_array_equal(np.asarray(ref.mask), mask)
        np.testing.assert_allclose(np.asarray(ref.norm), norm, rtol=0, atol=0)


def test_streamed_plan_construction(ds):
    plan = streamed_plan(ds, 4, max_degree=16)
    assert plan.strategy == "streamed"
    assert plan.chunks == 4 and len(plan.batches) == 4
    total = sum(mb.graph.num_nodes for mb in plan.batches)
    assert total == ds.num_nodes
    assert 0.0 <= plan.edge_cut <= 1.0
    stacked = plan.stacked()
    assert stacked.graph.features.shape[0] == 4
    # node ids tile the graph in order, so chunk c owns a contiguous range
    first = np.asarray(plan.batches[0].graph.node_ids)
    assert first[0] == 0 and np.all(np.diff(first) == 1)


def test_streamed_plan_chunks_must_fit(ds):
    with pytest.raises(ValueError):
        streamed_plan(ds, ds.num_nodes + 1)


def test_double_buffered_loader_order_and_device(ds):
    """The loader yields exactly the source items, in order, each already a
    committed device array (the overlap is an optimization, never a
    reordering)."""
    plan = streamed_plan(ds, 4, max_degree=16)
    src = [mb.graph.features for mb in plan.batches]
    out = list(DoubleBufferedLoader(src))
    assert len(out) == len(src)
    for got, want in zip(out, src):
        assert isinstance(got, jax.Array)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert list(DoubleBufferedLoader([])) == []
    one = list(DoubleBufferedLoader([jnp.ones(3)]))
    assert len(one) == 1 and float(one[0].sum()) == 3.0


def test_streamed_seed_changes_graph(ds):
    other = open_streamed("powerlaw-64k", num_nodes=N_SMALL, block_size=512,
                          seed=7)
    a, _ = ds.chunk_edges(0, 512)
    b, _ = other.chunk_edges(0, 512)
    assert _edge_set(a) != _edge_set(b)
