"""Serving-path correctness: bucketed/padded ego-subgraph inference must be
bit-identical to a host full-batch forward on the same query nodes.

The chain under test is ``ego_subgraph`` (lossless k-hop halo) ->
``pad_graph`` (inert rows/columns) -> ``GNNServer.execute`` (stacked bucket
batch through ``compile_eval``). Single-device tests pin strict bit-identity
on both engines; the ``slow`` subprocess test reruns the check on the
4-forced-device shard_map ring, where XLA CPU's divided thread pool may
re-tile bucket-shaped gemms and shift rare rows ~1 ULP — there the bound is
1e-6 plus argmax equality (see ``serve_gnn.verify_results``).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.cli import PipelineCLIConfig
from repro.core.pipeline import make_engine
from repro.graphs import load_dataset
from repro.graphs.data import pad_graph
from repro.graphs.partition import ego_subgraph
from repro.launch.serve_gnn import (
    GNNServer,
    Query,
    ShapeBuckets,
    serve,
    synth_queries,
    verify_results,
)
from repro.models.gnn.net import build_paper_gat


@pytest.fixture(scope="module")
def setup():
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    full = np.asarray(m.apply(params, g, train=False))
    return g, m, params, full


def _server(g, m, params, *, engine="compiled", chunks=2, buckets=None):
    cfg = PipelineCLIConfig(engine=engine, stages=4, chunks=chunks).gpipe_config()
    return GNNServer(make_engine(m, cfg), params, g, hops=2, buckets=buckets)


# ------------------------------------------------------------ ego-subgraph --


def test_ego_subgraph_lossless_bitwise(setup):
    """hops == receptive depth (2 for the paper GAT): every seed's logp row
    on its ego-subgraph equals the full-graph row BIT FOR BIT — subgraph
    keeps neighbor column order, trailing pad slots contribute exact zeros,
    and per-row reductions are order-stable on a single device."""
    g, m, params, full = setup
    for u in range(g.num_nodes):
        sub, rows = ego_subgraph(g, [u], 2)
        got = np.asarray(m.apply(params, sub, train=False))[rows]
        assert np.array_equal(got, full[[u]]), u
        # ...and padding to a bucket shape must not move a single bit
        padded = pad_graph(sub, g.num_nodes, g.max_degree)
        got_p = np.asarray(m.apply(params, padded, train=False))[rows]
        assert np.array_equal(got_p, full[[u]]), u


def test_ego_subgraph_seed_rows(setup):
    g, _, _, _ = setup
    sub, rows = ego_subgraph(g, [0, 33], 2)
    ids = np.asarray(sub.node_ids)
    assert list(ids[rows]) == [0, 33]


# ------------------------------------------------------------- served path --


@pytest.mark.parametrize("engine", ["host", "compiled"])
def test_served_predictions_bit_identical(setup, engine):
    """The tentpole acceptance check at 1 device: every node-classification
    and link-prediction query served through bucketed, padded, stacked
    batches — on BOTH engines — returns logp rows bit-identical to the host
    full-batch forward."""
    g, m, params, full = setup
    server = _server(g, m, params, engine=engine, chunks=2)
    queries = [Query(i, "node", i) for i in range(g.num_nodes)]
    queries += [Query(100 + i, "link", i, (i + 7) % g.num_nodes) for i in range(6)]
    prepared = [server.prepare(q) for q in queries]
    results = []
    for i in range(0, len(prepared), 2):
        results.extend(server.execute(prepared[i : i + 2]))
    assert len(results) == len(queries)
    mismatches, exact, max_diff = verify_results(m, params, g, results)
    assert (mismatches, exact) == (0, len(queries)), max_diff
    # link scores are the dot of the two served rows — recompute from oracle
    for r in results:
        if r.query.kind == "link":
            want = float(np.dot(full[r.query.u], full[r.query.v]))
            assert r.score == want


def test_partial_batch_is_padded_not_dropped(setup):
    """A 1-request dispatch on a chunks=4 server still returns exactly that
    request's (bit-identical) prediction — the pad replicas are discarded."""
    g, m, params, full = setup
    server = _server(g, m, params, chunks=4)
    out = server.execute([server.prepare(Query(0, "node", 17))])
    assert len(out) == 1
    assert np.array_equal(out[0].logp, full[[17]])
    assert server.occupancy()[g.num_nodes]["occupancy"] == 0.25


def test_open_loop_serve_reports_latency_and_occupancy(setup):
    """The open-loop driver end to end (no wall-clock assumptions beyond
    monotonicity): every query completes, latency covers queueing, and the
    batching stats add up."""
    g, m, params, _ = setup
    server = _server(g, m, params, chunks=2)
    queries = synth_queries(g, 12, qps=500.0, link_frac=0.3, seed=1)
    results = serve(server, queries, max_wait_s=0.01)
    assert len(results) == 12
    assert sorted(r.query.qid for r in results) == list(range(12))
    assert all(r.latency_s > 0 for r in results)
    mismatches, exact, _ = verify_results(m, params, g, results)
    assert mismatches == 0 and exact == 12
    occ = server.occupancy()
    assert sum(v["queries"] for v in occ.values()) == 12
    for v in occ.values():
        assert 0 < v["occupancy"] <= 1


# ---------------------------------------------------------------- buckets --


def test_shape_buckets_ladder():
    g = load_dataset("cora")
    b = ShapeBuckets.geometric(g, base=64)
    assert b.sizes[-1] == g.num_nodes
    assert b.sizes == tuple(sorted(set(b.sizes)))
    assert b.bucket_of(1) == 0
    assert b.bucket_of(64) == 0
    assert b.bucket_of(65) == 1
    assert b.bucket_of(g.num_nodes) == len(b.sizes) - 1
    with pytest.raises(ValueError):
        b.bucket_of(g.num_nodes + 1)
    # small graphs collapse to a single full-graph bucket
    k = load_dataset("karate")
    assert ShapeBuckets.geometric(k, base=64).sizes == (k.num_nodes,)


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=10, deadline=None)
def test_bucket_assignment_order_invariant(seed):
    """Bucketing determinism: the same query set maps to the same buckets
    regardless of arrival order — bucket_of is a pure function of the ego
    size, and prepare() carries no cross-query state."""
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    buckets = ShapeBuckets([8, 16, 34])
    server = _server(g, m, params, chunks=2, buckets=buckets)
    queries = [Query(i, "node", i % g.num_nodes) for i in range(12)]
    baseline = {q.qid: server.prepare(q).bucket for q in queries}
    rng = np.random.default_rng(seed)
    shuffled = list(queries)
    rng.shuffle(shuffled)
    assert {q.qid: server.prepare(q).bucket for q in shuffled} == baseline


# ------------------------------------------------- multi-device substrate --


def _run(src: str, devices: int = 4, timeout: int = 1200):
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": "src",
    }
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**os.environ, **env},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_served_path_multidevice():
    """The serving chain on the 4-forced-device shard_map ring: predictions
    stay within 1 ULP of the full-batch oracle (strict bit-identity is a
    single-device guarantee; forced-device XLA may re-tile gemms), argmax
    never moves, and the bound EvalProgram issues ZERO device_puts after
    warmup — the re-replication bugfix on the mesh path, where it matters."""
    out = _run("""
    import numpy as np, jax
    from repro.core.cli import PipelineCLIConfig
    from repro.core.pipeline import make_engine
    from repro.graphs import load_dataset
    from repro.launch.serve_gnn import GNNServer, Query, verify_results
    from repro.models.gnn.net import build_paper_gat

    assert jax.device_count() == 4, jax.device_count()
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    full = np.asarray(m.apply(params, g, train=False))
    cfg = PipelineCLIConfig(engine="compiled", stages=4, chunks=2).gpipe_config()
    server = GNNServer(make_engine(m, cfg), params, g, hops=2)
    queries = [Query(i, "node", i) for i in range(g.num_nodes)]
    prepared = [server.prepare(q) for q in queries]
    results = []
    for i in range(0, len(prepared), 2):
        results.extend(server.execute(prepared[i:i+2]))
    mism, exact, max_diff = verify_results(m, params, g, results, atol=1e-6)
    assert mism == 0, (mism, max_diff)
    for r in results:
        assert r.pred == int(full[r.query.u].argmax()), r.query
    print('MD_SERVE_OK', exact, len(results), max_diff)

    # params were bound at the first execute; further batches must not
    # re-place the tree (the per-call device_put regression, mesh path)
    calls = []
    orig = jax.device_put
    jax.device_put = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
    try:
        server.execute(prepared[:2])
        server.execute(prepared[2:4])
    finally:
        jax.device_put = orig
    assert not calls, f"served batches issued {len(calls)} device_puts"
    print('MD_NO_REPLICATION_OK')
    """)
    assert "MD_SERVE_OK" in out
    assert "MD_NO_REPLICATION_OK" in out
