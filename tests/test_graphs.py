"""Graph substrate: datasets, padded layout invariants, partitioners."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.graphs import load_dataset, validate_graph, DATASETS
from repro.graphs.data import build_graph_batch, subgraph
from repro.graphs import partition as P


@pytest.mark.parametrize("name", ["cora", "citeseer", "karate"])
def test_dataset_stats_match_paper(name):
    n, m, d, c = DATASETS[name]
    g = load_dataset(name)
    assert g.num_nodes == n
    assert g.num_features == d
    assert g.num_classes == c
    assert int(g.num_edges) == 2 * m  # directed slots = 2×undirected
    validate_graph(g)


def test_pubmed_stats():
    n, m, d, c = DATASETS["pubmed"]
    g = load_dataset("pubmed")
    assert (g.num_nodes, g.num_features, g.num_classes) == (n, d, c)
    assert int(g.num_edges) == 2 * m


def _random_graph(rng, n=40, m=80, d=8, c=3):
    edges = rng.integers(0, n, size=(m, 2))
    feats = rng.standard_normal((n, d)).astype(np.float32)
    labels = rng.integers(0, c, size=n)
    return build_graph_batch(feats, edges, labels, c)


def test_subgraph_drops_cross_edges():
    rng = np.random.default_rng(0)
    g = _random_graph(rng)
    half = np.arange(g.num_nodes // 2)
    sub = subgraph(g, half)
    validate_graph(sub)
    # every surviving neighbor must be inside the chunk
    nbr = np.asarray(sub.neighbors)[np.asarray(sub.mask)]
    assert nbr.max(initial=0) < len(half)
    # the drop is real: edge count shrinks below the induced upper bound
    assert int(sub.num_edges) <= int(g.num_edges)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 3))
def test_sequential_partition_covers(chunks, seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n=30 + seed)
    parts = P.sequential_partition(g.num_nodes, chunks)
    got = np.sort(np.concatenate(parts))
    assert np.array_equal(got, np.arange(g.num_nodes))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5))
def test_greedy_partition_cuts_fewer_edges(seed):
    # community-structured graph so locality is exploitable
    g = load_dataset("karate", seed=seed)
    seq = P.sequential_partition(g.num_nodes, 4)
    rnd = P.random_partition(g.num_nodes, 4, seed=seed)
    greedy = P.greedy_partition(g, 4, seed=seed)
    covered = np.sort(np.concatenate(greedy))
    assert np.array_equal(covered, np.arange(g.num_nodes))
    assert P.edge_cut_fraction(g, greedy) <= P.edge_cut_fraction(g, rnd) + 0.15
    del seq


def test_halo_exactness_two_hops():
    """A 2-hop halo contains the full receptive field of a 2-layer GNN."""
    rng = np.random.default_rng(1)
    g = _random_graph(rng, n=50, m=120)
    core = np.arange(10)
    nodes, core_mask = P.expand_halo(g, core, hops=2)
    node_set = set(nodes.tolist())
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    one_hop = set()
    for i in core:
        one_hop |= set(nbr[i][msk[i]].tolist())
    two_hop = set(one_hop)
    for i in one_hop:
        two_hop |= set(nbr[i][msk[i]].tolist())
    assert two_hop <= node_set
    assert core_mask.sum() == len(core)


def test_edge_cut_fraction_bounds():
    g = load_dataset("karate")
    parts = P.sequential_partition(g.num_nodes, 4)
    f = P.edge_cut_fraction(g, parts)
    assert 0.0 < f < 1.0
    whole = [np.arange(g.num_nodes)]
    assert P.edge_cut_fraction(g, whole) == 0.0
