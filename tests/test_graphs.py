"""Graph substrate: datasets, padded layout invariants, partitioners."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.graphs import load_dataset, validate_graph, DATASETS
from repro.graphs.data import build_graph_batch, subgraph
from repro.graphs import partition as P


@pytest.mark.parametrize("name", ["cora", "citeseer", "karate"])
def test_dataset_stats_match_paper(name):
    n, m, d, c = DATASETS[name]
    g = load_dataset(name)
    assert g.num_nodes == n
    assert g.num_features == d
    assert g.num_classes == c
    assert int(g.num_edges) == 2 * m  # directed slots = 2×undirected
    validate_graph(g)


def test_pubmed_stats():
    n, m, d, c = DATASETS["pubmed"]
    g = load_dataset("pubmed")
    assert (g.num_nodes, g.num_features, g.num_classes) == (n, d, c)
    assert int(g.num_edges) == 2 * m


def _random_graph(rng, n=40, m=80, d=8, c=3):
    edges = rng.integers(0, n, size=(m, 2))
    feats = rng.standard_normal((n, d)).astype(np.float32)
    labels = rng.integers(0, c, size=n)
    return build_graph_batch(feats, edges, labels, c)


def test_subgraph_drops_cross_edges():
    rng = np.random.default_rng(0)
    g = _random_graph(rng)
    half = np.arange(g.num_nodes // 2)
    sub = subgraph(g, half)
    validate_graph(sub)
    # every surviving neighbor must be inside the chunk
    nbr = np.asarray(sub.neighbors)[np.asarray(sub.mask)]
    assert nbr.max(initial=0) < len(half)
    # the drop is real: edge count shrinks below the induced upper bound
    assert int(sub.num_edges) <= int(g.num_edges)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 3))
def test_sequential_partition_covers(chunks, seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n=30 + seed)
    parts = P.sequential_partition(g.num_nodes, chunks)
    got = np.sort(np.concatenate(parts))
    assert np.array_equal(got, np.arange(g.num_nodes))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5))
def test_greedy_partition_cuts_fewer_edges(seed):
    # community-structured graph so locality is exploitable
    g = load_dataset("karate", seed=seed)
    seq = P.sequential_partition(g.num_nodes, 4)
    rnd = P.random_partition(g.num_nodes, 4, seed=seed)
    greedy = P.greedy_partition(g, 4, seed=seed)
    covered = np.sort(np.concatenate(greedy))
    assert np.array_equal(covered, np.arange(g.num_nodes))
    assert P.edge_cut_fraction(g, greedy) <= P.edge_cut_fraction(g, rnd) + 0.15
    del seq


def test_halo_exactness_two_hops():
    """A 2-hop halo contains the full receptive field of a 2-layer GNN."""
    rng = np.random.default_rng(1)
    g = _random_graph(rng, n=50, m=120)
    core = np.arange(10)
    nodes, core_mask = P.expand_halo(g, core, hops=2)
    node_set = set(nodes.tolist())
    nbr = np.asarray(g.neighbors)
    msk = np.asarray(g.mask)
    one_hop = set()
    for i in core:
        one_hop |= set(nbr[i][msk[i]].tolist())
    two_hop = set(one_hop)
    for i in one_hop:
        two_hop |= set(nbr[i][msk[i]].tolist())
    assert two_hop <= node_set
    assert core_mask.sum() == len(core)


def test_edge_cut_fraction_bounds():
    g = load_dataset("karate")
    parts = P.sequential_partition(g.num_nodes, 4)
    f = P.edge_cut_fraction(g, parts)
    assert 0.0 < f < 1.0
    whole = [np.arange(g.num_nodes)]
    assert P.edge_cut_fraction(g, whole) == 0.0


# ------------------------------------------------- degree-bucketed layout --


def _live_pairs(nbr_row, nrm_row, msk_row):
    """Sorted multiset of live (neighbor, norm) slots — layout-invariant."""
    pairs = [(int(a), float(b)) for a, b, m in zip(nbr_row, nrm_row, msk_row) if m]
    return sorted(pairs)


def test_degree_bucket_widths_ladder():
    assert P.degree_bucket_widths(100) == (8, 16, 32, 64, 100)
    assert P.degree_bucket_widths(8) == (8,)
    assert P.degree_bucket_widths(3) == (3,)  # narrower than base: one bucket
    with pytest.raises(ValueError):
        P.degree_bucket_widths(0)


def test_bucketed_layout_round_trip():
    """row_node (bucket row -> node) and gather_rows (node -> concat row)
    are mutual inverses over the real rows; capacity-padding rows are inert
    (all-masked, zero norm) and never in gather's image."""
    g = load_dataset("skewed-mini")
    b = P.degree_bucketed_layout(g)
    row_node = np.concatenate([np.asarray(bk.row_node) for bk in b.buckets])
    gather = np.asarray(b.gather_rows)
    assert np.array_equal(row_node[gather], np.arange(g.num_nodes))
    # inert rows: exactly the concat rows outside gather's image, mask-free
    image = np.zeros(len(row_node), dtype=bool)
    image[gather] = True
    offset = 0
    for bk in b.buckets:
        inert = ~image[offset:offset + bk.rows]
        assert not np.asarray(bk.mask)[inert].any()
        assert (np.asarray(bk.norm)[inert] == 0).all()
        offset += bk.rows


def test_bucketed_layout_preserves_live_slots():
    """Every node's live (neighbor, norm) multiset survives bucketing —
    the layout moves slots, never edge data."""
    g = load_dataset("skewed-mini")
    b = P.degree_bucketed_layout(g)
    gather = np.asarray(b.gather_rows)
    offsets = np.cumsum([0] + [bk.rows for bk in b.buckets])
    g_nbr, g_nrm, g_msk = (np.asarray(a) for a in (g.neighbors, g.norm, g.mask))
    for i in range(g.num_nodes):
        r = gather[i]
        k = int(np.searchsorted(offsets, r, side="right")) - 1
        bk = b.buckets[k]
        lr = r - offsets[k]
        got = _live_pairs(
            np.asarray(bk.neighbors)[lr], np.asarray(bk.norm)[lr],
            np.asarray(bk.mask)[lr],
        )
        want = _live_pairs(g_nbr[i], g_nrm[i], g_msk[i])
        assert got == want, f"node {i} (bucket {k})"
        # the row fits the narrowest covering bucket: width >= live slots
        assert len(want) <= bk.width


def test_bucketed_layout_compacts_subgraph_holes():
    """subgraph() leaves interior mask holes; the layout closes them (live
    slots left-packed) so narrow rows land in narrow buckets."""
    rng = np.random.default_rng(3)
    g = _random_graph(rng, n=60, m=200)
    sub = subgraph(g, np.arange(0, 60, 2))  # drop odd nodes -> holes
    msk = np.asarray(sub.mask)
    holes = (~msk[:, :-1] & msk[:, 1:]).any()
    assert holes, "fixture should have interior holes"
    b = P.degree_bucketed_layout(sub)
    for bk in b.buckets:
        bmsk = np.asarray(bk.mask)
        # left-packed: once a slot is dead, the rest of the row is dead
        assert not (~bmsk[:, :-1] & bmsk[:, 1:]).any()
    # and the live-slot multiset still survives per node
    gather = np.asarray(b.gather_rows)
    offsets = np.cumsum([0] + [bk.rows for bk in b.buckets])
    s_nbr, s_nrm, s_msk = (np.asarray(a) for a in (sub.neighbors, sub.norm, sub.mask))
    for i in range(sub.num_nodes):
        r = gather[i]
        k = int(np.searchsorted(offsets, r, side="right")) - 1
        lr = r - offsets[k]
        bk = b.buckets[k]
        assert _live_pairs(
            np.asarray(bk.neighbors)[lr], np.asarray(bk.norm)[lr],
            np.asarray(bk.mask)[lr],
        ) == _live_pairs(s_nbr[i], s_nrm[i], s_msk[i])


def test_bucketed_layout_empty_and_single_bucket():
    g = load_dataset("karate")
    max_deg = g.neighbors.shape[1]
    # a ladder rung no row uses -> zero-capacity bucket, shapes still valid
    b = P.degree_bucketed_layout(g, widths=(1, max_deg))
    assert b.buckets[0].rows == 0 or b.buckets[0].rows % 8 == 0
    row_node = np.concatenate([np.asarray(bk.row_node) for bk in b.buckets])
    assert np.array_equal(row_node[np.asarray(b.gather_rows)], np.arange(g.num_nodes))
    # one bucket as wide as the layout: degenerates to (padded + permutation)
    b1 = P.degree_bucketed_layout(g, widths=(max_deg,))
    assert len(b1.buckets) == 1
    assert b1.buckets[0].width == max_deg
    # too-narrow ladder is rejected, not silently truncated
    with pytest.raises(ValueError, match="last bucket width"):
        P.degree_bucketed_layout(g, widths=(4,))


def test_bucketize_stacked_uniform_caps():
    """Chunk-stacked bucketing: one shared set of bucket shapes (leading
    ``chunks`` axis), each chunk's slice a valid layout of that chunk."""
    from repro.core.microbatch import make_plan

    g = load_dataset("skewed-mini")
    plan = make_plan(g, 2, strategy="sequential")
    stacked = plan.stacked().graph
    b = P.bucketize_stacked(stacked)
    chunks, n_pad = stacked.features.shape[:2]
    assert chunks == 2
    for bk in b.buckets:
        assert bk.neighbors.shape[0] == chunks
        assert bk.neighbors.shape[1] % 8 == 0 or bk.neighbors.shape[1] == 0
    assert b.gather_rows.shape == (chunks, n_pad)
    for c in range(chunks):
        row_node = np.concatenate([np.asarray(bk.row_node[c]) for bk in b.buckets])
        gather = np.asarray(b.gather_rows[c])
        assert np.array_equal(row_node[gather], np.arange(n_pad))
