"""Micro-batch container regressions: the ``dataclasses.replace`` stale
``_stacked`` cache bug and the array-field ``__eq__``/``__hash__`` traps.

Both were latent until something exercised the path: a replaced plan served
a stacked pytree built from the OLD batches with no error, and comparing any
two ``MicroBatch``/``StackedPlan``/``LoweredTimeline`` instances raised the
jnp/np ambiguous-truth-value error the first time a test (or a cache) tried.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.microbatch import MicroBatch, MicroBatchPlan, StackedPlan, make_plan
from repro.core.schedule import FillDrainSchedule, lower_timeline
from repro.graphs import load_dataset


@pytest.fixture(scope="module")
def plan():
    return make_plan(load_dataset("karate"), 3, strategy="halo", halo_hops=2)


# ------------------------------------------------- replace() cache regression --


def test_replace_does_not_carry_stale_stacked_cache(plan):
    """Regression: ``dataclasses.replace(plan, batches=...)`` used to copy the
    ``_stacked`` cache built from the OLD batches — a silently stale pytree.
    The cache is init=False now, so a replaced plan re-stacks from its own
    batches."""
    old_stacked = plan.stacked()
    assert plan.stacked() is old_stacked  # cached on the original

    reordered = dataclasses.replace(plan, batches=list(reversed(plan.batches)))
    new_stacked = reordered.stacked()
    assert new_stacked is not old_stacked
    # the new stack reflects the NEW batch order, not the cached old one
    assert jnp.array_equal(
        new_stacked.graph.features[0], old_stacked.graph.features[-1]
    )
    assert jnp.array_equal(
        new_stacked.core_mask[-1], old_stacked.core_mask[0]
    )
    # and the original plan's cache is untouched
    assert plan.stacked() is old_stacked


def test_replace_rejects_explicit_stacked_override(plan):
    """The cache cannot be smuggled through replace() at all — passing it is
    an error (init=False), not a silent carry."""
    plan.stacked()
    with pytest.raises((ValueError, TypeError)):
        dataclasses.replace(plan, _stacked=None)


def test_plan_equality_ignores_cache(plan):
    """Two plans that differ only in whether stacked() has been called must
    compare equal — the cache is compare=False."""
    bare = MicroBatchPlan(
        strategy=plan.strategy,
        chunks=plan.chunks,
        batches=plan.batches,
        rebuild_seconds=plan.rebuild_seconds,
        edge_cut=plan.edge_cut,
    )
    plan.stacked()
    assert plan == bare


# ------------------------------------------------ eq/hash on array holders --


def test_array_dataclasses_compare_and_hash_without_raising(plan):
    """Regression: the auto-generated __eq__ on frozen dataclasses holding
    jnp/np arrays raised the ambiguous-truth-value error on first comparison
    (and frozen+eq __hash__ tried to hash arrays). eq=False pins identity
    semantics for MicroBatch, StackedPlan and LoweredTimeline."""
    mb0, mb1 = plan.batches[0], plan.batches[1]
    assert isinstance(mb0, MicroBatch)
    assert mb0 == mb0
    assert mb0 != mb1  # identity, no ambiguous-truth-value raise
    assert len({mb0, mb1}) == 2  # hashable (object identity)

    stacked = plan.stacked()
    assert isinstance(stacked, StackedPlan)
    other = dataclasses.replace(plan, batches=list(plan.batches)).stacked()
    assert stacked == stacked
    assert stacked != other
    hash(stacked)

    low_a = lower_timeline(FillDrainSchedule().timeline(2, 2), 2, 2)
    low_b = lower_timeline(FillDrainSchedule().timeline(2, 2), 2, 2)
    assert low_a == low_a
    assert low_a != low_b
    hash(low_a)


def test_microbatch_pytree_arrays_usable_after_eq(plan):
    """The arrays themselves stay first-class after an equality check — the
    original failure mode was tripping inside ==, poisoning innocuous code
    like cache lookups that compare keys."""
    mb = plan.batches[0]
    assert mb != object()
    total = jax.tree_util.tree_reduce(
        lambda acc, a: acc + a.size, mb.graph, 0
    )
    assert total > 0
