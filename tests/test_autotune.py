"""The self-tuning planner (``--auto``): predicted step times validated
against an abstract-machine replay of each schedule's timeline, the
argmin's documented tie-break order, budget truncation, memory pruning,
the cached profiler's sidecar roundtrip, and the ``--auto --dry-run``
candidate table through ``run_gnn``."""

import json
import types

import jax
import pytest

from repro.core.autotune import (
    DEFAULT_CHUNK_COUNTS,
    PLAN_SCHEDULES,
    PipelinePlan,
    PlanConstraints,
    plan_pipeline,
)
from repro.core.cli import PipelineCLIConfig
from repro.core.costmodel import (
    LayerCosts,
    _PROFILE_CACHE,
    cached_profile_layer_costs,
    profile_fingerprint,
    uniform_balance,
)
from repro.core.pipeline import GPipeConfig, make_engine
from repro.core.schedule import get_schedule
from repro.graphs import load_dataset
from repro.launch.train import run_gnn
from repro.models.gnn.net import build_paper_gat


def _costs(fwd, scale_b=1.0, scale_w=1.0):
    return LayerCosts(
        names=tuple(f"l{i}" for i in range(len(fwd))),
        fwd=tuple(fwd),
        bwd=tuple(f * (scale_b + scale_w) for f in fwd),
        bwd_b=tuple(f * scale_b for f in fwd),
        bwd_w=tuple(f * scale_w for f in fwd),
    )


def _uniform_costs_by_chunks(n_layers=6, chunk_counts=DEFAULT_CHUNK_COUNTS):
    """Shape-invariant synthetic costs for every candidate chunk count —
    the injection path that lets the planner run without a graph."""
    c = _costs([1e-3] * n_layers)
    return {C: c for C in chunk_counts}


def _stub_model(n_layers=6):
    """plan_pipeline only touches ``model.layers`` when costs are injected
    and params are supplied."""
    return types.SimpleNamespace(
        layers=[types.SimpleNamespace(name=f"l{i}") for i in range(n_layers)]
    )


# ------------------------------------- predicted time vs abstract machine --


def _replay(sched, S, C, cost):
    """Abstract-machine replay of a schedule's timeline: execute the work
    items in tick order, each starting when its dependencies are done AND
    its device is free, taking ``cost[phase][stage]`` time. The makespan of
    this machine is what ``predicted_step_time`` models."""
    done, free = {}, {}
    for it in sched.timeline(S, C):
        deps = []
        if it.phase == "fwd" and it.stage > 0:
            deps.append((it.stage - 1, it.chunk, "fwd"))
        if it.phase in ("bwd", "bwd_b"):
            deps.append((it.stage, it.chunk, "fwd"))
            if it.stage < S - 1:
                deps.append((it.stage + 1, it.chunk, it.phase))
        if it.phase == "bwd_w":
            deps.append((it.stage, it.chunk, "bwd_b"))
        start = max([free.get(it.device, 0.0)] + [done[d] for d in deps if d in done])
        end = start + cost[it.phase][it.stage]
        done[(it.stage, it.chunk, it.phase)] = end
        free[it.device] = end
    return max(done.values())


SCHED_MATRIX = [  # (name, get_schedule kwargs, split B/W backward?)
    ("fill_drain", {}, False),
    ("1f1b", {}, False),
    ("interleaved", {"num_devices": 2}, False),
    ("zb-h1", {}, True),
    ("zb-v", {"num_devices": 2}, True),
]


@pytest.mark.parametrize("name,kw,split", SCHED_MATRIX)
@pytest.mark.parametrize("S,C", [(4, 4), (4, 8), (6, 4), (4, 2)])
def test_predicted_step_time_equals_tick_count_unit_costs(name, kw, split, S, C):
    """With unit per-stage costs every schedule's predicted makespan is
    EXACTLY its timeline's tick count — the prediction layer and the
    abstract machine agree on the schedule's own currency (ticks), for the
    fused schedules and both zero-bubble ones (B and W each one tick)."""
    sched = get_schedule(name, **kw)
    try:
        sched.timeline(S, C)
    except ValueError:
        pytest.skip(f"{name} rejects S={S},C={C}")
    if split:
        pred = sched.predicted_step_time(
            S, C, stage_fwd_costs=[1.0] * S,
            stage_bwd_b_costs=[1.0] * S, stage_bwd_w_costs=[1.0] * S,
        )
        cost = {"fwd": [1.0] * S, "bwd_b": [1.0] * S, "bwd_w": [1.0] * S}
    else:
        pred = sched.predicted_step_time(
            S, C, stage_fwd_costs=[1.0] * S, stage_bwd_costs=[1.0] * S
        )
        cost = {"fwd": [1.0] * S, "bwd": [1.0] * S}
    assert pred == sched.ticks(S, C), (name, S, C)
    assert pred == _replay(sched, S, C, cost), (name, S, C)


@pytest.mark.parametrize(
    "name,kw",
    [("fill_drain", {}), ("interleaved", {"num_devices": 2}), ("zb-h1", {})],
)
def test_predicted_step_time_equals_replay_skewed_vectors(name, kw):
    """Per-stage cost vectors: for the tick-exact schedules the weighted
    makespan equals the abstract machine's replay of the same timeline with
    per-(stage, phase) costs — not just a bound."""
    S, C = 4, 4
    sched = get_schedule(name, **kw)
    f = [0.7, 0.1, 0.1, 0.1]
    bb = [0.9, 0.15, 0.15, 0.2]
    bw = [0.5, 0.05, 0.05, 0.3]
    if name == "zb-h1":
        pred = sched.predicted_step_time(
            S, C, stage_fwd_costs=f, stage_bwd_b_costs=bb, stage_bwd_w_costs=bw
        )
        rep = _replay(sched, S, C, {"fwd": f, "bwd_b": bb, "bwd_w": bw})
    else:
        fused = [x + y for x, y in zip(bb, bw)]
        pred = sched.predicted_step_time(S, C, stage_fwd_costs=f, stage_bwd_costs=fused)
        rep = _replay(sched, S, C, {"fwd": f, "bwd": fused})
    assert abs(pred - rep) < 1e-9, (name, pred, rep)


@pytest.mark.parametrize("S,C,D", [(4, 4, 2), (4, 2, 2), (6, 6, 3), (4, 8, 2)])
def test_zb_v_predicted_bounded_by_replay_and_device_work(S, C, D):
    """zb-v's prediction re-runs the cost-aware greedy, which may ORDER ops
    differently than the unit-cost timeline — so skewed-cost equality with
    the frozen timeline is not owed. What is owed: the prediction is a
    valid execution (>= the per-device total-work lower bound) and never
    worse than naively replaying the unit-cost order with the true
    costs."""
    sched = get_schedule("zb-v", num_devices=D)
    f = [0.1 + 0.15 * (s % 3) for s in range(S)]
    bb = [0.2 + 0.1 * ((s + 1) % 3) for s in range(S)]
    bw = [0.05 + 0.1 * (s % 2) for s in range(S)]
    pred = sched.predicted_step_time(
        S, C, stage_fwd_costs=f, stage_bwd_b_costs=bb, stage_bwd_w_costs=bw
    )
    rep = _replay(sched, S, C, {"fwd": f, "bwd_b": bb, "bwd_w": bw})
    per_dev = [0.0] * D
    for s in range(S):
        per_dev[s % D] += C * (f[s] + bb[s] + bw[s])
    assert pred >= max(per_dev) - 1e-9, (pred, per_dev)
    assert pred <= rep + 1e-9, (pred, rep)


# ----------------------------------------------------------- the planner --


def test_plan_pipeline_argmin_stable_under_ties():
    """Shape-invariant uniform costs tie huge swaths of the space; the
    documented total order must break them identically on every run — same
    pick, same ranked table."""
    costs = _uniform_costs_by_chunks()
    m = _stub_model()
    kw = dict(params=(), costs_by_chunks=costs)
    p1 = plan_pipeline(m, None, **kw)
    p2 = plan_pipeline(m, None, **kw)
    assert (p1.schedule, p1.chunks, p1.balance, p1.num_devices) == (
        p2.schedule, p2.chunks, p2.balance, p2.num_devices)
    assert p1.table() == p2.table()
    # rotation axis: predicted time is placement-invariant, so the pick is
    # always the schedule's default placement (rotation 0 -> placement None)
    assert p1.placement is None
    assert p1.predicted_step_s == p1.candidates[0].predicted_step_s
    # the winner is feasible and ranked first; pruned candidates sink
    assert p1.candidates[0].pruned is None
    seen_pruned = False
    for c in p1.candidates:
        if c.pruned is not None:
            seen_pruned = True
        else:
            assert not seen_pruned, "feasible candidate ranked after a pruned one"


def test_plan_pipeline_prefers_cheaper_split_backward():
    """A W-light cost profile makes the zero-bubble schedules strictly
    cheaper than fill-drain in the model; the planner must pick one of
    them, and the pick's predicted time must be the table's minimum."""
    base = _costs([2e-3, 1e-3, 1e-3, 1e-3, 1e-3, 2e-3], scale_b=0.9, scale_w=0.1)
    costs = {C: base for C in DEFAULT_CHUNK_COUNTS}
    plan = plan_pipeline(_stub_model(), None, params=(), costs_by_chunks=costs)
    assert plan.schedule in ("zb-h1", "zb-v", "1f1b", "interleaved", "fill_drain")
    feasible = [c for c in plan.candidates if c.pruned is None]
    assert plan.predicted_step_s == min(c.predicted_step_s for c in feasible)


def test_plan_pipeline_budget_truncates_deterministically():
    costs = _uniform_costs_by_chunks()
    plan = plan_pipeline(
        _stub_model(), None,
        PlanConstraints(budget=40), params=(), costs_by_chunks=costs,
    )
    assert plan.evaluated == 40
    assert plan.truncated
    full = plan_pipeline(_stub_model(), None, params=(), costs_by_chunks=costs)
    assert not full.truncated
    assert full.evaluated > 40


def test_plan_pipeline_memory_pruning_and_infeasible():
    costs = _uniform_costs_by_chunks()
    m = _stub_model()
    plan = plan_pipeline(
        m, None, PlanConstraints(max_live_activations=8),
        params=(), costs_by_chunks=costs,
    )
    pruned = [c for c in plan.candidates if c.pruned]
    assert any("peak_live" in c.pruned for c in pruned)
    assert plan.candidates[0].peak_live <= 8
    # over-constrained: every candidate pruned -> ValueError naming reasons
    with pytest.raises(ValueError, match="peak_live"):
        plan_pipeline(
            m, None, PlanConstraints(max_live_activations=0),
            params=(), costs_by_chunks=costs,
        )


def test_plan_pipeline_missing_costs_and_bad_stages():
    m = _stub_model()
    with pytest.raises(ValueError, match="no costs_by_chunks entry"):
        plan_pipeline(m, None, params=(), costs_by_chunks={4: _costs([1.0] * 6)})
    with pytest.raises(ValueError, match="num_stages"):
        plan_pipeline(m, None, PlanConstraints(num_stages=7), params=(),
                      costs_by_chunks=_uniform_costs_by_chunks())


def test_make_engine_accepts_plan_and_to_config_overrides():
    """Both engines take a PipelinePlan directly; ``to_config`` replays the
    pick with overrides winning."""
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    plan = plan_pipeline(
        m, None, params=(), costs_by_chunks=_uniform_costs_by_chunks(),
        engine="host",
    )
    pipe = make_engine(m, plan)
    assert pipe.describe()["engine"] == "host"
    assert pipe.describe()["schedule"] == plan.schedule
    cfg = plan.to_config(engine="compiled")
    assert isinstance(cfg, GPipeConfig)
    assert cfg.engine == "compiled"
    assert cfg.balance == plan.balance and cfg.chunks == plan.chunks


# ------------------------------------------------ cached profiler sidecar --


def test_cached_profile_sidecar_roundtrip(tmp_path):
    """First call profiles and writes the JSON sidecar; a cold process
    (in-process cache cleared) reads the sidecar back instead of
    re-profiling — proven by poisoning the profiler."""
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    path = str(tmp_path / "costs.json")
    key = profile_fingerprint(m, params, g, "padded")
    _PROFILE_CACHE.pop(key, None)
    c1 = cached_profile_layer_costs(m, params, g, cache_path=path,
                                    repeats=1, warmup=0)
    with open(path) as f:
        assert key in json.load(f)
    _PROFILE_CACHE.clear()  # simulate a fresh process
    import repro.core.costmodel as cm

    real = cm.profile_layer_costs
    cm.profile_layer_costs = lambda *a, **k: pytest.fail("re-profiled despite sidecar")
    try:
        c2 = cached_profile_layer_costs(m, params, g, cache_path=path)
    finally:
        cm.profile_layer_costs = real
    assert c1.names == c2.names and c1.fwd == c2.fwd and c1.bwd_w == c2.bwd_w
    # corrupt sidecar: ignored, falls back to the profiler
    with open(path, "w") as f:
        f.write("{not json")
    _PROFILE_CACHE.clear()
    c3 = cached_profile_layer_costs(m, params, g, cache_path=path,
                                    repeats=1, warmup=0)
    assert c3.names == c1.names


def test_profile_fingerprint_keys_on_shape_and_backend():
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    k1 = profile_fingerprint(m, params, g, "padded")
    assert k1 == profile_fingerprint(m, params, g, "padded")
    assert k1 != profile_fingerprint(m, params, g, "bucketed")


# ------------------------------------------------- --auto --dry-run table --


def test_auto_dry_run_prints_ranked_table(capsys):
    """``--auto --dry-run`` through run_gnn: prints the ranked candidate
    table and returns the pick without training."""
    costs = _uniform_costs_by_chunks()
    ns = PipelineCLIConfig(stages=4, auto=True, dry_run=True).namespace(
        mode="gnn", dataset="karate", strategy="sequential", epochs=2,
        seed=0, log_every=0, costs_by_chunks=costs,
    )
    out = run_gnn(ns)
    text = capsys.readouterr().out
    assert out["mode"] == "auto-dry-run"
    assert out["schedule"] in PLAN_SCHEDULES
    assert out["chunks"] in DEFAULT_CHUNK_COUNTS
    assert "[auto] evaluated" in text
    assert "pick: schedule=" in text
    header = [ln for ln in text.splitlines() if "rank" in ln and "pred_ms" in ln]
    assert header, text
    # the pick echoes rank-0's fields
    plan_line = [ln for ln in text.splitlines() if ln.strip().startswith("0 ")][0]
    assert out["schedule"] in plan_line


def test_format_table_marks_truncation_and_pruned_rows():
    costs = _uniform_costs_by_chunks()
    plan = plan_pipeline(
        _stub_model(), None,
        PlanConstraints(budget=40, max_live_activations=8),
        params=(), costs_by_chunks=costs,
    )
    text = plan.format_table(limit=5)
    assert "(budget-truncated)" in text
    assert "more candidates" in text
    full = plan.format_table(limit=None)
    assert "peak_live" in full  # pruned rows carry their reason in the note
