"""Transformer stack: per-arch smoke tests, attention/moe/ssd invariants,
decode-vs-prefill consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, ShapeConfig
from repro.data.tokens import token_batch, frontend_embeds
from repro.models.transformer import blocks as B
from repro.models.transformer.attention import blocked_attention, decode_attention
from repro.models.transformer.common import apply_rope, apply_mrope
from repro.models.transformer.model import (
    Topology,
    init_params,
    make_positions,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

ALL_ARCHS = list_archs()


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _batch_for(cfg, bsz, seq, *, train=True, seed=0):
    s_front = int(seq * cfg.frontend_frac) if cfg.frontend != "none" else 0
    toks = token_batch(batch=bsz, seq=seq - s_front, vocab=cfg.vocab_size, seed=seed)
    batch = {"tokens": jnp.asarray(toks if train else toks[:, :-1])}
    if s_front:
        batch["frontend_embeds"] = jnp.asarray(
            frontend_embeds(batch=bsz, seq=s_front, d_model=cfg.d_model, seed=seed)
        )
    return batch


# ------------------------------------------------- per-arch smoke (f) ----


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch, mesh):
    """REQUIRED smoke: reduced config, one train step on CPU, finite loss."""
    cfg = get_arch(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
    shape = ShapeConfig("smoke", 64, 4, "train")
    topo = Topology(num_stages=1, fsdp_size=1, num_micro=2, loss_chunks=2)
    art = make_train_step(cfg, topo, shape, mesh, dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0), num_stages=1, dtype=jnp.float32)
    opt_state = art.meta["optimizer"].init(params)
    batch = _batch_for(cfg, 4, 64)
    p2, o2, m = jax.jit(art.fn)(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    # params actually moved
    delta = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["mamba2-130m", "qwen2.5-32b"])
def test_train_step_interleaved_schedule(arch, mesh):
    """Topology.schedule='interleaved' routes through
    spmd_pipeline_interleaved (here: 1 physical device hosting V=2 virtual
    stages). With num_layers == total stages the stacked init draws the same
    per-layer keys as the 1-stage reference, so the loss must match exactly,
    and a few steps must still reduce it."""
    cfg = get_arch(arch, smoke=True)
    assert cfg.num_layers == 2
    shape = ShapeConfig("smoke", 64, 4, "train")
    batch = _batch_for(cfg, 4, 64)

    topo_ref = Topology(num_stages=1, fsdp_size=1, num_micro=2, loss_chunks=2)
    art_ref = make_train_step(cfg, topo_ref, shape, mesh, dtype=jnp.float32)
    p_ref = init_params(cfg, jax.random.PRNGKey(0), num_stages=1, dtype=jnp.float32)
    _, _, m_ref = jax.jit(art_ref.fn)(p_ref, art_ref.meta["optimizer"].init(p_ref), batch)

    topo = Topology(num_stages=2, fsdp_size=1, num_micro=2, loss_chunks=2,
                    schedule="interleaved", num_virtual=2)
    assert topo.pipe_devices == 1
    art = make_train_step(cfg, topo, shape, mesh, dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0), num_stages=2, dtype=jnp.float32)
    opt_state = art.meta["optimizer"].init(params)
    step = jax.jit(art.fn)
    losses = []
    for _ in range(5):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert abs(losses[0] - float(m_ref["loss"])) < 1e-4, (losses[0], float(m_ref["loss"]))
    assert losses[-1] < losses[0], losses  # same batch refit: must decrease
    assert np.isfinite(losses).all()


def test_topology_schedule_validation(mesh):
    cfg = get_arch("mamba2-130m", smoke=True)
    shape = ShapeConfig("smoke", 64, 4, "train")
    with pytest.raises(ValueError, match="schedule"):
        make_train_step(cfg, Topology(num_stages=2, schedule="1f1b"), shape, mesh)
    with pytest.raises(ValueError, match="num_virtual"):
        make_train_step(
            cfg,
            Topology(num_stages=3, schedule="interleaved", num_virtual=2, num_micro=4),
            shape, mesh,
        )
    with pytest.raises(ValueError, match="num_micro"):
        make_train_step(
            cfg,
            Topology(num_stages=4, schedule="interleaved", num_virtual=2, num_micro=1),
            shape, mesh,
        )


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "gemma2-27b", "deepseek-v3-671b", "mamba2-130m", "zamba2-7b"])
def test_arch_smoke_serve_step(arch, mesh):
    cfg = get_arch(arch, smoke=True)
    shape = ShapeConfig("smoke_dec", 64, 4, "decode")
    topo = Topology(num_stages=1, fsdp_size=1, num_micro=2)
    art = make_serve_step(cfg, topo, shape, mesh, dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0), num_stages=1, dtype=jnp.float32)
    cache = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), art.abstract_inputs[1])
    nxt, cache2 = jax.jit(art.fn)(params, cache, {"tokens": jnp.zeros((4,), jnp.int32),
                                                  "pos": jnp.asarray(0, jnp.int32)})
    assert nxt.shape == (4,)
    assert nxt.dtype == jnp.int32


# ---------------------------------------- decode vs prefill consistency --


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "gemma2-27b", "mamba2-130m", "deepseek-v3-671b"])
def test_decode_matches_prefill_next_token(arch, mesh):
    """Prefill a prompt, decode one token; the same next-token must come from
    a fresh prefill over prompt+token (KV-cache correctness end-to-end)."""
    cfg = get_arch(arch, smoke=True)
    bsz, plen = 2, 32
    topo = Topology(num_stages=1, fsdp_size=1, num_micro=1)
    params = init_params(cfg, jax.random.PRNGKey(0), num_stages=1, dtype=jnp.float32)

    pshape = ShapeConfig("p", plen, bsz, "prefill")
    part = make_prefill_step(cfg, topo, pshape, mesh, dtype=jnp.float32)
    batch = _batch_for(cfg, bsz, plen, train=False)
    cache0 = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), part.abstract_inputs[1])
    logits1, pcache = jax.jit(part.fn)(params, cache0, batch)
    tok1 = jnp.argmax(logits1, axis=-1).astype(jnp.int32)

    # decode one step from the prefilled cache
    dshape = ShapeConfig("d", plen + 16, bsz, "decode")
    sart = make_serve_step(cfg, topo, dshape, mesh, dtype=jnp.float32)
    dcache = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), sart.abstract_inputs[1])

    def splice(dst, src):
        if dst.ndim >= 5 and src.shape[:3] == dst.shape[:3]:
            w = src.shape[4]
            return dst.at[:, :, :, :, :w].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    dcache = jax.tree_util.tree_map(splice, dcache, pcache)
    tok2, _ = jax.jit(sart.fn)(params, dcache, {"tokens": tok1, "pos": jnp.asarray(plen, jnp.int32)})

    # oracle: prefill over prompt + tok1 and read the new last-token argmax
    p2shape = ShapeConfig("p2", plen + 1, bsz, "prefill")
    part2 = make_prefill_step(cfg, topo, p2shape, mesh, dtype=jnp.float32)
    if cfg.frontend != "none":
        batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], tok1[:, None]], 1))
    else:
        batch2 = {"tokens": jnp.concatenate([batch["tokens"], tok1[:, None]], 1)}
    cache20 = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), part2.abstract_inputs[1])
    logits2, _ = jax.jit(part2.fn)(params, cache20, batch2)
    tok_ref = jnp.argmax(logits2, axis=-1).astype(jnp.int32)
    assert jnp.array_equal(tok2, tok_ref), (np.asarray(tok2), np.asarray(tok_ref))


# -------------------------------------------------- attention invariants --


def test_blocked_attention_matches_naive():
    b, s, h, kv, hd = 2, 96, 4, 2, 16
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, s, h, hd))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(k, 2), (b, s, kv, hd))
    pos = jnp.arange(s)
    out = blocked_attention(q, kk, v, q_pos=pos, kv_pos=pos, kv_block=32)

    # naive causal reference
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bikgd,bjkd->bkgij", qg, kk) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    alpha = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgij,bjkd->bikgd", alpha, v).reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_sliding_window_blocks_distant_tokens():
    b, s, h, hd, w = 1, 64, 2, 8, 8
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, s, h, hd))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, s, h, hd))
    v = jnp.zeros((b, s, h, hd)).at[:, 0].set(100.0)  # poison token 0
    pos = jnp.arange(s)
    out = blocked_attention(q, kk, v, q_pos=pos, kv_pos=pos, window=w, kv_block=16)
    # queries far past the window must not see token 0's value
    assert float(jnp.max(jnp.abs(out[:, w + 1 :]))) < 1.0
    # token 0 itself attends only to itself -> sees the poison
    assert float(jnp.max(jnp.abs(out[:, 0]))) > 50.0


def test_decode_attention_seq_sharded_equivalence():
    """Flash-decoding partial-softmax over a sharded cache == unsharded."""
    b, h, hd, w = 2, 4, 8, 32
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, h, hd))
    kc = jax.random.normal(jax.random.fold_in(k, 1), (b, w, h, hd))
    vc = jax.random.normal(jax.random.fold_in(k, 2), (b, w, h, hd))
    pos = jnp.arange(w)
    ref = decode_attention(q, kc, vc, pos, jnp.asarray(w - 1), window=0)

    import os, subprocess, sys, textwrap  # noqa
    # in-process shard over 1 axis is possible only with >1 devices; emulate
    # the partial-softmax math directly instead:
    halves = [(kc[:, :16], vc[:, :16], pos[:16]), (kc[:, 16:], vc[:, 16:], pos[16:])]
    ms, ls, accs = [], [], []
    for kci, vci, pi in halves:
        s = jnp.einsum("bhd,bchd->bhc", q / jnp.sqrt(hd), kci)
        ok = pi <= w - 1
        s = jnp.where(ok[None, None], s, -1e30)
        m = jnp.max(s, -1)
        p = jnp.exp(s - m[..., None])
        ms.append(m); ls.append(p.sum(-1)); accs.append(jnp.einsum("bhc,bchd->bhd", p, vci))
    m = jnp.maximum(ms[0], ms[1])
    c0, c1 = jnp.exp(ms[0] - m), jnp.exp(ms[1] - m)
    out = (accs[0] * c0[..., None] + accs[1] * c1[..., None]) / (
        (ls[0] * c0 + ls[1] * c1)[..., None]
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_rope_relative_shift_invariance():
    """RoPE: q·k depends only on relative distance."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.asarray([pq]), theta=1e4)
        kr = apply_rope(k, jnp.asarray([pk]), theta=1e4)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3


def test_mrope_shapes_and_text_equivalence():
    """For text positions (t=h=w), m-rope must equal plain rope."""
    hd, s = 16, 12
    x = jax.random.normal(jax.random.PRNGKey(0), (1, s, 2, hd))
    pos = jnp.arange(s)
    r1 = apply_rope(x, pos, theta=1e4)
    r2 = apply_mrope(x, jnp.stack([pos, pos, pos]), theta=1e4)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)


def test_positions_vlm_layout():
    cfg = get_arch("qwen2-vl-2b", smoke=True)
    pos = make_positions(cfg, 64)
    assert pos.shape == (3, 64)
    s_front = int(64 * cfg.frontend_frac)
    # image patches share t=0; text advances
    assert int(pos[0, : s_front].max()) == 0
    assert int(pos[0, -1]) > 0
