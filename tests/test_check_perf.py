"""Unit tests for the CI perf gate's decision logic (benchmarks/check_perf).

The gate ran for several PRs with no tests of its own; the host-normalization
path in particular could silently shrink the comparison set when a host
fill-drain normalizer row was missing or zero — in the limit turning the
speed gate into a no-op pass. These tests drive ``check`` on hand-built
tables covering the missing-row, zero-time, zero-bubble and partition paths
directly.
"""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.check_perf import (  # noqa: E402
    check,
    check_kernels,
    check_serving,
    normalized_ratios,
)


def _row(step_s, *, bubble=0.4, peak=8, peak_acc=16):
    return {
        "step_s": step_s,
        "bubble": bubble,
        "peak_live": peak,
        "peak_live_accounted": peak_acc,
    }


def _table(**rows):
    return {"rows": rows}


def _base_rows(host=1.0, compiled=0.5):
    return {
        "host/fill_drain/chunks2": _row(host),
        "compiled/fill_drain/chunks2": _row(compiled),
    }


def test_gate_passes_on_identical_tables():
    t = _table(**_base_rows())
    assert check(t, t, threshold=1.2, absolute=False) == []


def test_speed_regression_fails_and_is_threshold_scaled():
    base = _table(**_base_rows(host=1.0, compiled=0.5))
    ok = _table(**_base_rows(host=1.0, compiled=0.55))  # 1.1x, inside 1.2
    bad = _table(**_base_rows(host=1.0, compiled=0.7))  # 1.4x
    assert check(base, ok, threshold=1.2, absolute=False) == []
    failures = check(base, bad, threshold=1.2, absolute=False)
    assert any(f.startswith("perf:") for f in failures), failures


def test_missing_compiled_row_is_coverage_failure():
    base = _table(**_base_rows())
    cur = _table(**{"host/fill_drain/chunks2": _row(1.0)})
    failures = check(base, cur, threshold=1.2, absolute=False)
    assert any(
        f.startswith("coverage:") and "compiled/fill_drain/chunks2" in f
        for f in failures
    ), failures


@pytest.mark.parametrize("side", ["baseline", "current"])
def test_missing_host_normalizer_fails_by_name(side):
    """Regression: a table whose host fill-drain normalizer row is MISSING
    used to drop the pair silently — on the baseline side without any
    failure at all. Both sides must now fail naming the missing row."""
    good = _table(**_base_rows())
    broken = _table(**{"compiled/fill_drain/chunks2": _row(0.5)})
    baseline, current = (broken, good) if side == "baseline" else (good, broken)
    failures = check(baseline, current, threshold=1.2, absolute=False)
    assert any(
        f.startswith(f"normalizer({side}):")
        and "host/fill_drain/chunks2 is missing" in f
        for f in failures
    ), failures


@pytest.mark.parametrize("side", ["baseline", "current"])
def test_zero_time_host_normalizer_fails_by_name(side):
    """A zero (or negative) host step time is a broken measurement, not a
    divisor to crash on or a row to skip: the gate fails naming the row."""
    good = _table(**_base_rows())
    broken = _table(**_base_rows(host=0.0))
    baseline, current = (broken, good) if side == "baseline" else (good, broken)
    failures = check(baseline, current, threshold=1.2, absolute=False)
    assert any(
        f.startswith(f"normalizer({side}):") and "non-positive" in f
        for f in failures
    ), failures


def test_normalized_ratios_reports_problems_not_exceptions():
    ratios, problems = normalized_ratios(
        {
            "compiled/fill_drain/chunks2": _row(0.5),
            "compiled/1f1b/chunks4": _row(0.5),
            "host/fill_drain/chunks4": _row(0.0),
        }
    )
    assert ratios == {}
    assert len(problems) == 2
    assert any("is missing" in p for p in problems)
    assert any("non-positive" in p for p in problems)


def test_empty_comparison_set_fails():
    failures = check(_table(), _table(), threshold=1.2, absolute=False)
    assert any("no comparable compiled rows" in f for f in failures)


# ------------------------------------------------------- zero-bubble path --


def _zb_rows(zb_step, ob_step, *, zb_bubble=0.2, ob_bubble=0.43, zb_peak=9, ob_peak=9):
    return {
        "host/fill_drain/chunks4": _row(1.0),
        "compiled/fill_drain/chunks4": _row(0.6, peak=None, peak_acc=16),
        "compiled/1f1b/chunks4": _row(ob_step, bubble=ob_bubble, peak=ob_peak),
        "compiled/zb-h1/chunks4": _row(zb_step, bubble=zb_bubble, peak=zb_peak),
    }


def test_zero_bubble_gate_passes_when_zb_dominates():
    t = _table(**_zb_rows(0.45, 0.5))
    assert check(t, t, threshold=1.2, absolute=False) == []


def test_zero_bubble_gate_fails_on_step_bubble_and_peak():
    base = _table(**_zb_rows(0.45, 0.5))
    slow = _table(**_zb_rows(0.7, 0.5))  # zb step > 1f1b * 1.2
    failures = check(base, slow, threshold=1.2, absolute=False)
    assert any("zero-bubble" in f and "does not beat" in f for f in failures)
    bubbly = _table(**_zb_rows(0.45, 0.5, zb_bubble=0.43))
    failures = check(base, bubbly, threshold=1.2, absolute=False)
    assert any("zero-bubble" in f and "bubble" in f for f in failures)
    fat = _table(**_zb_rows(0.45, 0.5, zb_peak=12))
    failures = check(base, fat, threshold=1.2, absolute=False)
    assert any("zero-bubble" in f and "peak_live" in f for f in failures)


def test_zero_bubble_gate_fails_without_1f1b_row():
    base = _table(**_zb_rows(0.45, 0.5))
    cur = dict(_zb_rows(0.45, 0.5))
    del cur["compiled/1f1b/chunks4"]
    failures = check(base, _table(**cur), threshold=1.2, absolute=False)
    assert any("zero-bubble" in f and "no compiled 1f1b row" in f for f in failures)


# --------------------------------------------------------- partition path --


def _part_rows(uniform, profiled):
    rows = _base_rows()
    rows["partition/uniform/chunks4"] = {"step_s": uniform, "balance": [2, 2, 2, 2]}
    rows["partition/profiled/chunks4"] = {"step_s": profiled, "balance": [1, 1, 1, 5]}
    return rows


def test_partition_gate_requires_profiled_to_beat_uniform():
    good = _table(**_part_rows(0.40, 0.30))
    assert check(good, good, threshold=1.2, absolute=False) == []
    tie = _table(**_part_rows(0.40, 0.40))
    failures = check(good, tie, threshold=1.2, absolute=False)
    assert any(f.startswith("partition:") and "does not beat" in f for f in failures)
    worse = _table(**_part_rows(0.40, 0.50))
    failures = check(good, worse, threshold=1.2, absolute=False)
    assert any(f.startswith("partition:") for f in failures)


def test_partition_gate_coverage():
    base = _table(**_part_rows(0.40, 0.30))
    cur = dict(_part_rows(0.40, 0.30))
    del cur["partition/uniform/chunks4"]
    failures = check(base, _table(**cur), threshold=1.2, absolute=False)
    assert any(
        f.startswith("coverage:") and "partition/uniform/chunks4" in f
        for f in failures
    ), failures
    assert any(
        f.startswith("partition:") and "no uniform row" in f for f in failures
    ), failures


# -------------------------------------------------------------- auto gate --


def _auto_rows(pick, best_hand, *, predicted=None, other_hand=None):
    rows = _base_rows()
    rows["auto/hand/1f1b_profiled/chunks4"] = {
        "step_s": best_hand, "schedule": "1f1b", "balance": [1, 1, 1, 5],
    }
    rows["auto/hand/fill_drain_uniform/chunks4"] = {
        "step_s": other_hand if other_hand is not None else best_hand * 1.5,
        "schedule": "fill_drain", "balance": [2, 2, 2, 2],
    }
    rows["auto/pick"] = {
        "step_s": pick, "schedule": "1f1b", "chunks": 4,
        "balance": [1, 1, 1, 5],
        "predicted_step_s": predicted if predicted is not None else pick,
    }
    return rows


def test_auto_gate_passes_when_pick_competitive():
    t = _table(**_auto_rows(0.28, 0.30))
    assert check(t, t, threshold=1.2, absolute=False) == []
    # pick slightly worse than best hand but inside threshold
    ok = _table(**_auto_rows(0.33, 0.30))
    assert check(t, ok, threshold=1.2, absolute=False) == []


def test_auto_gate_fails_by_name_when_pick_loses_to_hand():
    base = _table(**_auto_rows(0.28, 0.30))
    bad = _table(**_auto_rows(0.40, 0.30))  # 1.33x the best hand config
    failures = check(base, bad, threshold=1.2, absolute=False)
    assert any(
        f.startswith("auto-pick:") and "1f1b_profiled" in f for f in failures
    ), failures


def test_auto_gate_bounds_prediction_error_by_name():
    base = _table(**_auto_rows(0.28, 0.30, predicted=0.09))
    assert check(base, base, threshold=1.2, absolute=False) == []
    wild = _table(**_auto_rows(0.28, 0.30, predicted=0.28 * 30))
    failures = check(base, wild, threshold=1.2, absolute=False)
    assert any(f.startswith("auto-prediction:") and "off by" in f for f in failures)
    tiny = _table(**_auto_rows(0.28, 0.30, predicted=0.28 / 30))
    failures = check(base, tiny, threshold=1.2, absolute=False)
    assert any(f.startswith("auto-prediction:") for f in failures)
    # the cap is a flag: a tighter ratio turns the committed-style gap fatal
    failures = check(base, base, threshold=1.2, absolute=False, auto_pred_ratio=2.0)
    assert any(f.startswith("auto-prediction:") for f in failures)


def test_auto_gate_unusable_prediction_fails_by_name():
    rows = _auto_rows(0.28, 0.30)
    rows["auto/pick"]["predicted_step_s"] = 0.0
    t = _table(**rows)
    failures = check(t, t, threshold=1.2, absolute=False)
    assert any(
        f.startswith("auto-prediction:") and "unusable" in f for f in failures
    ), failures


def test_auto_gate_coverage_and_missing_hands():
    base = _table(**_auto_rows(0.28, 0.30))
    # current run lost the pick row entirely
    cur = dict(_auto_rows(0.28, 0.30))
    del cur["auto/pick"]
    failures = check(base, _table(**cur), threshold=1.2, absolute=False)
    assert any(
        f.startswith("coverage:") and "auto/pick" in f for f in failures
    ), failures
    assert any(
        f.startswith("auto-pick:") and "produced none" in f for f in failures
    ), failures
    # pick present but no hand rows to compare against
    cur = dict(_base_rows())
    cur["auto/pick"] = dict(_auto_rows(0.28, 0.30)["auto/pick"])
    failures = check(_table(**cur), _table(**cur), threshold=1.2, absolute=False)
    assert any(
        f.startswith("auto-pick:") and "no auto/hand" in f for f in failures
    ), failures


# ------------------------------------------------------------ sparse gate --


def _sparse_rows(padded, bucketed, *, padded_match=True, bucketed_match=True):
    rows = _base_rows()
    rows["sparse/padded/chunks2"] = {
        "step_s": padded, "max_update_diff": 5e-8, "updates_match": padded_match,
    }
    rows["sparse/bucketed/chunks2"] = {
        "step_s": bucketed, "max_update_diff": 5e-8, "updates_match": bucketed_match,
    }
    return rows


def test_sparse_gate_requires_strict_bucketed_win():
    good = _table(**_sparse_rows(0.35, 0.05))
    assert check(good, good, threshold=1.2, absolute=False) == []
    tie = _table(**_sparse_rows(0.35, 0.35))
    failures = check(good, tie, threshold=1.2, absolute=False)
    assert any(f.startswith("sparse:") and "not strictly below" in f for f in failures)


def test_sparse_gate_requires_updates_match_on_both_rows():
    good = _table(**_sparse_rows(0.35, 0.05))
    for kw in ({"padded_match": False}, {"bucketed_match": False}):
        bad = _table(**_sparse_rows(0.35, 0.05, **kw))
        failures = check(good, bad, threshold=1.2, absolute=False)
        assert any(
            f.startswith("sparse:") and "diverged" in f for f in failures
        ), (kw, failures)


def test_sparse_gate_coverage():
    base = _table(**_sparse_rows(0.35, 0.05))
    cur = dict(_sparse_rows(0.35, 0.05))
    del cur["sparse/padded/chunks2"]
    failures = check(base, _table(**cur), threshold=1.2, absolute=False)
    assert any(
        f.startswith("coverage:") and "sparse/padded/chunks2" in f for f in failures
    ), failures
    assert any(
        f.startswith("sparse:") and "no padded row" in f for f in failures
    ), failures


# ------------------------------------------------------------- scale gate --


def _scale_rows(s1=0.05, s2=0.10, s4=0.22, *, match=True):
    rows = _base_rows()
    for n, s in ((25_000, s1), (50_000, s2), (100_000, s4)):
        rows[f"scale/n{n}/chunks{max(n // 25_000, 2)}"] = {
            "step_s": s, "nodes": n, "max_update_diff": 0.0,
            "updates_match": match, "edge_cut": 0.4,
            "data_parallel_active": True,
        }
    return rows


def test_scale_gate_passes_on_identical_tables():
    t = _table(**_scale_rows())
    assert check(t, t, threshold=1.2, absolute=False) == []


def test_scale_gate_growth_ratio_is_machine_cancelling():
    base = _table(**_scale_rows(0.05, 0.10, 0.22))
    # uniformly 3x slower machine: every ratio to n_min is unchanged
    slower = _table(**_scale_rows(0.15, 0.30, 0.66))
    assert check(base, slower, threshold=1.2, absolute=False) == []
    # superlinear blow-up at the largest size: ratio 8.0x vs baseline 4.4x
    regressed = _table(**_scale_rows(0.05, 0.10, 0.40))
    failures = check(base, regressed, threshold=1.2, absolute=False)
    assert any(
        f.startswith("scale:") and "growth ratio" in f and "n100000" in f
        for f in failures
    ), failures


def test_scale_gate_requires_updates_match():
    base = _table(**_scale_rows())
    bad = _table(**_scale_rows(match=False))
    failures = check(base, bad, threshold=1.2, absolute=False)
    assert any(f.startswith("scale:") and "diverged" in f for f in failures), failures


def test_scale_gate_coverage_fails_by_name():
    base = _table(**_scale_rows())
    cur = dict(_scale_rows())
    del cur["scale/n100000/chunks4"]
    failures = check(base, _table(**cur), threshold=1.2, absolute=False)
    assert any(
        f.startswith("coverage:") and "scale/n100000/chunks4" in f
        for f in failures
    ), failures


def test_scale_gate_zero_anchor_fails():
    base = _table(**_scale_rows())
    cur = _table(**_scale_rows(s1=0.0))
    failures = check(base, cur, threshold=1.2, absolute=False)
    assert any(
        f.startswith("scale:") and "non-positive anchor" in f for f in failures
    ), failures


# ------------------------------------------------------------ overlap gate --


def _overlap_rows(ser=0.140, db=0.158, *, ser_ticks=22, db_ticks=38,
                  fraction=0.0, ser_match=True, db_match=True):
    rows = _base_rows()
    rows["overlap/serialized/chunks8"] = {
        "step_s": ser, "num_ticks": ser_ticks, "wire_latency": 1,
        "max_update_diff": 0.0, "updates_match": ser_match,
        "overlap_fraction": 0.0,
    }
    rows["overlap/double-buffer/chunks8"] = {
        "step_s": db, "num_ticks": db_ticks, "wire_latency": 2,
        "max_update_diff": 0.0, "updates_match": db_match,
        "overlap_fraction": fraction,
    }
    return rows


def test_overlap_gate_passes_on_identical_tables():
    t = _table(**_overlap_rows())
    assert check(t, t, threshold=1.2, absolute=False) == []


def test_overlap_gate_tick_bound_when_no_traced_overlap():
    """fraction ~0 (lockstep CPU): the rule is per-tick — 0.158/38 beats
    0.140/22 even though the raw step is slower; a double-buffered tick
    that got DEARER than the serialized tick fails by name."""
    ok = _table(**_overlap_rows(ser=0.140, db=0.158))
    assert check(ok, ok, threshold=1.2, absolute=False) == []
    # 0.30/38 per tick > 0.140/22 per tick
    bad = _table(**_overlap_rows(ser=0.140, db=0.30))
    failures = check(bad, bad, threshold=1.2, absolute=False)
    assert any(
        f.startswith("overlap:") and "per-tick" in f for f in failures
    ), failures


def test_overlap_gate_strict_step_bound_when_overlap_traced():
    """fraction > 0.05 (the runtime demonstrably hid collectives): the
    double-buffered STEP must beat/match serialized within threshold —
    tick inflation is no excuse once overlap is real."""
    ok = _table(**_overlap_rows(ser=0.140, db=0.130, fraction=0.4))
    assert check(ok, ok, threshold=1.1, absolute=False) == []
    bad = _table(**_overlap_rows(ser=0.140, db=0.158, fraction=0.4))
    failures = check(bad, bad, threshold=1.1, absolute=False)
    assert any(
        f.startswith("overlap:") and "despite traced overlap_fraction" in f
        for f in failures
    ), failures


def test_overlap_gate_requires_updates_match_on_both_rows():
    good = _table(**_overlap_rows())
    for kw in ({"ser_match": False}, {"db_match": False}):
        bad = _table(**_overlap_rows(**kw))
        failures = check(good, bad, threshold=1.2, absolute=False)
        assert any(
            f.startswith("overlap:") and "diverged" in f for f in failures
        ), (kw, failures)


def test_overlap_gate_coverage_and_partner_fail_by_name():
    base = _table(**_overlap_rows())
    cur = dict(_overlap_rows())
    del cur["overlap/serialized/chunks8"]
    failures = check(base, _table(**cur), threshold=1.2, absolute=False)
    assert any(
        f.startswith("coverage:") and "overlap/serialized/chunks8" in f
        for f in failures
    ), failures
    assert any(
        f.startswith("overlap:") and "no serialized row" in f for f in failures
    ), failures


def test_overlap_gate_missing_accounting_fails_by_name():
    """A row without overlap_fraction (no profiler report) or without tick
    counts cannot be gated — named failure, never a silent pass."""
    rows = _overlap_rows()
    del rows["overlap/double-buffer/chunks8"]["overlap_fraction"]
    failures = check(_table(**rows), _table(**rows), threshold=1.2, absolute=False)
    assert any(
        f.startswith("overlap:") and "overlap_fraction" in f for f in failures
    ), failures
    rows = _overlap_rows(db_ticks=0)
    failures = check(_table(**rows), _table(**rows), threshold=1.2, absolute=False)
    assert any(
        f.startswith("overlap:") and "tick accounting" in f for f in failures
    ), failures


# ----------------------------------------------------------- kernels gate --


def _kernel_row(t_us, *, match=True, diff=0.0):
    return {"t_us": t_us, "layout_slots": 1000,
            "max_abs_diff": diff, "outputs_match": match}


def _kernel_table(padded=100.0, bucketed=10.0, **kw):
    return {"rows": {
        "kernels/spmm/padded": _kernel_row(padded),
        "kernels/spmm/bucketed": _kernel_row(bucketed, **kw),
    }}


def test_kernels_gate_passes_on_identical_tables():
    t = _kernel_table()
    assert check_kernels(t, t, threshold=1.3) == []


def test_kernels_gate_requires_strict_bucketed_win():
    base = _kernel_table(padded=100.0, bucketed=10.0)
    cur = _kernel_table(padded=100.0, bucketed=100.0)
    failures = check_kernels(base, cur, threshold=1.3)
    assert any("must win strictly" in f for f in failures), failures


def test_kernels_gate_ratio_regression_is_machine_cancelling():
    base = _kernel_table(padded=100.0, bucketed=10.0)  # 0.10x
    slower_machine = _kernel_table(padded=300.0, bucketed=30.0)  # still 0.10x
    assert check_kernels(base, slower_machine, threshold=1.3) == []
    regressed = _kernel_table(padded=100.0, bucketed=20.0)  # 0.20x > 0.10 * 1.3
    failures = check_kernels(base, regressed, threshold=1.3)
    assert any("bucketed/padded ratio" in f for f in failures), failures


def test_kernels_gate_output_divergence_fails():
    base = _kernel_table()
    bad = _kernel_table(match=False, diff=0.5)
    failures = check_kernels(base, bad, threshold=1.3)
    assert any("output diverged" in f for f in failures), failures


def test_kernels_gate_coverage_fails_by_name():
    base = _kernel_table()
    cur = {"rows": {"kernels/spmm/padded": _kernel_row(100.0)}}
    failures = check_kernels(base, cur, threshold=1.3)
    assert any(
        f.startswith("kernels-coverage:") and "kernels/spmm/bucketed" in f
        for f in failures
    ), failures
    failures = check_kernels(base, {"rows": {}}, threshold=1.3)
    assert any("no kernels/ rows" in f for f in failures), failures


def test_kernels_gate_zero_padded_normalizer_fails():
    base = _kernel_table()
    cur = _kernel_table(padded=0.0)
    failures = check_kernels(base, cur, threshold=1.3)
    assert any("not positive" in f for f in failures), failures


# ----------------------------------------------------------- serving gate --


def _serve_row(p99=0.12, call=0.04, *, qps=45.0, queries=250):
    return {
        "p99_s": p99,
        "p50_s": p99 / 3,
        "eval_call_s": call,
        "achieved_qps": qps,
        "queries": queries,
    }


def _serve_table(**rows):
    return {"rows": {f"serving/{k}": v for k, v in rows.items()}}


def test_serving_gate_passes_on_identical_tables():
    t = _serve_table(cora=_serve_row())
    assert check_serving(t, t, threshold=2.0) == []


def test_serving_gate_p99_ratio_regression():
    """p99 is compared as a ratio over the run's own warm eval_call_s, so a
    uniformly slower machine cancels out — only a genuinely worse
    p99-to-compute ratio trips the gate."""
    base = _serve_table(cora=_serve_row(p99=0.12, call=0.04))  # 3.0x
    slower_machine = _serve_table(cora=_serve_row(p99=0.24, call=0.08))  # still 3.0x
    assert check_serving(base, slower_machine, threshold=2.0) == []
    regressed = _serve_table(cora=_serve_row(p99=0.30, call=0.04))  # 7.5x
    failures = check_serving(base, regressed, threshold=2.0)
    assert any(f.startswith("serving:") and "p99/eval_call" in f for f in failures)


def test_serving_gate_coverage_fails_by_name():
    base = _serve_table(cora=_serve_row(), karate=_serve_row())
    cur = _serve_table(cora=_serve_row())
    failures = check_serving(base, cur, threshold=2.0)
    assert any(
        f.startswith("serving-coverage:") and "serving/karate" in f for f in failures
    ), failures
    failures = check_serving(base, {"rows": {}}, threshold=2.0)
    assert any("no serving/ rows" in f for f in failures), failures


@pytest.mark.parametrize("side", ["baseline", "current"])
def test_serving_gate_missing_normalizer_fails_by_name(side):
    good = _serve_table(cora=_serve_row())
    broken = _serve_table(cora={**_serve_row(), "eval_call_s": 0.0})
    baseline, current = (broken, good) if side == "baseline" else (good, broken)
    failures = check_serving(baseline, current, threshold=2.0)
    assert any(
        f.startswith(f"serving-normalizer({side}):") and "non-positive" in f
        for f in failures
    ), failures


def test_serving_gate_broken_run_fails():
    t = _serve_table(cora=_serve_row())
    dead = _serve_table(cora=_serve_row(qps=0.0, queries=0))
    failures = check_serving(t, dead, threshold=2.0)
    assert any("served no queries" in f for f in failures)
    assert any("achieved_qps" in f for f in failures)


def test_serving_gate_new_row_needs_no_baseline():
    """A row the baseline has never seen is checked for sanity but not for
    regression — committing the baseline is a separate, deliberate step."""
    base = _serve_table(cora=_serve_row())
    cur = _serve_table(cora=_serve_row(), pubmed=_serve_row(p99=9.0, call=0.01))
    assert check_serving(base, cur, threshold=2.0) == []
