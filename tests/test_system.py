"""End-to-end behaviour tests: the paper's qualitative claims + drivers."""

import types

import jax
import numpy as np
import pytest

from repro.graphs import load_dataset
from repro.models.gnn.net import build_paper_gat
from repro.train.loop import train


def _args(**kw):
    base = dict(
        mode="gnn", dataset="karate", arch="mamba2-130m", full_arch=False,
        backend="padded", strategy="sequential", stages=1, chunks=1,
        epochs=40, steps=3, seq=64, batch=4, lr=3e-4, seed=0, log_every=0,
    )
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_single_device_gat_learns_karate():
    g = load_dataset("karate")
    m = build_paper_gat(g.num_features, g.num_classes)
    res = train(m, g, epochs=60)
    assert res.train_acc >= 0.9
    assert res.val_acc >= 0.6


def test_paper_claim_sequential_chunking_degrades_accuracy():
    """Fig 4: accuracy collapses as lossy chunks increase; halo restores it."""
    from repro.launch.train import run_gnn

    full = run_gnn(_args(stages=1, epochs=60))
    seq4 = run_gnn(_args(stages=4, chunks=4, strategy="sequential", epochs=60))
    halo4 = run_gnn(_args(stages=4, chunks=4, strategy="halo", epochs=60))
    # information is lost by the paper's strategy...
    assert seq4["edge_cut"] > 0.3
    # ...and the halo fix recovers full-batch-level accuracy
    assert halo4["val_acc"] >= full["val_acc"] - 0.1
    assert halo4["val_acc"] >= seq4["val_acc"] - 0.05  # usually strictly better


def test_paper_claim_chunking_adds_rebuild_overhead():
    """Fig 3: micro-batching adds sub-graph rebuild cost that grows with
    chunk count (host-side, exactly like the paper's CPU rebuilds)."""
    from repro.core.microbatch import make_plan

    g = load_dataset("citeseer")
    t2 = make_plan(g, 2, strategy="sequential").rebuild_seconds
    t8 = make_plan(g, 8, strategy="sequential").rebuild_seconds
    assert t8 > 0 and t2 > 0
    # more chunks -> more rebuilds (allow generous noise margin)
    assert t8 > 0.5 * t2


def test_lm_driver_runs_and_loss_finite():
    from repro.launch.train import run_lm

    out = run_lm(_args(mode="lm", arch="qwen2-vl-2b", steps=3, seq=64, batch=4))
    assert np.isfinite(out["last_loss"])


def test_serve_driver_generates():
    from repro.launch.serve import run as run_serve

    out = run_serve(_args(arch="musicgen-large", prompt_len=32, decode_steps=4,
                          batch=2, stages=1, chunks=1))
    assert out["tokens_generated"] == 2 * 5
    assert all(0 <= t < 128 for t in out["sample"])
