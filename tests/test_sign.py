"""SIGN precompute (paper §8's prescription) — exactness under chunking."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.microbatch import make_plan
from repro.core.pipeline import GPipe, GPipeConfig
from repro.graphs import load_dataset
from repro.graphs.sign import as_sign_graph, build_sign_mlp, diffuse, sign_features
from repro.train import optimizer as opt_lib
from repro.train.losses import masked_nll


def test_diffusion_matches_dense():
    g = load_dataset("karate")
    h = g.features
    got = diffuse(g, h)
    # dense reference
    n = g.num_nodes
    adj = np.zeros((n, n), np.float32)
    nbr, msk, nrm = map(np.asarray, (g.neighbors, g.mask, g.norm))
    for i in range(n):
        adj[i, nbr[i][msk[i]]] = nrm[i][msk[i]]
    want = adj @ np.asarray(h)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)


def test_sign_features_shape():
    g = load_dataset("karate")
    f = sign_features(g, hops=3)
    assert f.shape == (g.num_nodes, 4 * g.num_features)


def test_sign_chunking_is_exact_even_sequential():
    """The punchline: with SIGN, the paper's lossy sequential split is
    harmless — chunked pipeline training equals full batch EXACTLY."""
    g0 = load_dataset("karate")
    g = as_sign_graph(g0, hops=2)
    # dropout off: the equality claim is about BATCHING, not rng alignment
    m = build_sign_mlp(g.num_features, g.num_classes, hidden=16, dropout=0.0)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    opt = opt_lib.adam(1e-2)

    def loss_fn(p):
        return masked_nll(m.apply(p, g, train=True), g.labels, g.train_mask)

    ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params)
    upd, _ = opt.update(ref_grads, opt.init(params), params)
    p_ref = opt_lib.apply_updates(params, upd)

    pipe = GPipe(m, GPipeConfig(balance=(2, 2), chunks=4))
    plan = make_plan(g, 4, strategy="sequential")  # the paper's lossy split
    assert plan.edge_cut == 0.0  # nothing left to lose: structure-free
    p2, _, loss = pipe.train_step(params, opt.init(params), plan, jax.random.PRNGKey(1), opt)
    assert abs(float(loss) - float(ref_loss)) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p2)):
        assert jnp.allclose(a, b, atol=1e-5)


def test_sign_learns_karate():
    g = as_sign_graph(load_dataset("karate"), hops=2)
    m = build_sign_mlp(g.num_features, g.num_classes, hidden=16)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    opt = opt_lib.adam(1e-2)
    state = opt.init(params)

    def loss_fn(p, rng):
        return masked_nll(m.apply(p, g, rng=rng, train=True), g.labels, g.train_mask)

    step = jax.jit(lambda p, s, r: _step(p, s, r))

    def _step(p, s, r):
        loss, grads = jax.value_and_grad(loss_fn)(p, r)
        u, s = opt.update(grads, s, p)
        return opt_lib.apply_updates(p, u), s, loss

    for i in range(60):
        key, rng = jax.random.split(key)
        params, state, loss = step(params, state, rng)
    logp = m.apply(params, g)
    acc = float(((jnp.argmax(logp, -1) == g.labels) * g.train_mask).sum() / g.train_mask.sum())
    assert acc >= 0.8
