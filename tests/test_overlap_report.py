"""Trace-attribution logic of the overlap profiler (core.overlap_report):
HLO-name filtering, leaf-only compute detection, same-lane intersection,
and the gzipped chrome-trace loader — all on synthetic events, no profiler
run needed."""

import gzip
import json
import os

from repro.core.overlap_report import (
    ASYNC_XLA_FLAGS,
    capture_overlap_report,
    load_trace_events,
    overlap_from_events,
)


def _ev(name, ts, dur, *, pid=1, tid=1):
    return {"ph": "X", "name": name, "ts": ts, "dur": dur, "pid": pid, "tid": tid}


def test_overlap_fraction_same_lane_only():
    """A collective only counts as hidden under compute on ITS OWN lane —
    cross-device concurrency is just the pipeline running."""
    events = [
        _ev("collective-permute.1", 0, 10, tid=1),
        _ev("dot.2", 0, 10, tid=2),  # other device: not overlap
    ]
    r = overlap_from_events(events)
    assert r["collective_time_us"] == 10
    assert r["compute_time_us"] == 10
    assert r["overlapped_time_us"] == 0
    assert r["overlap_fraction"] == 0.0
    # same lane, half-covered
    events = [
        _ev("collective-permute.1", 0, 10),
        _ev("dot.2", 5, 10),
    ]
    r = overlap_from_events(events)
    assert r["overlapped_time_us"] == 5
    assert r["overlap_fraction"] == 0.5


def test_container_events_do_not_count_as_compute():
    """A scan's ``while.N`` span contains every tick including the
    collectives inside it; counting it as compute would report those
    collectives as 100% hidden under themselves."""
    events = [
        _ev("while.1", 0, 100),  # container: spans both children
        _ev("dot.3", 10, 10),
        _ev("collective-permute.2", 50, 20),
    ]
    r = overlap_from_events(events)
    assert r["compute_time_us"] == 10  # the leaf dot only
    assert r["collective_time_us"] == 20
    assert r["overlap_fraction"] == 0.0
    assert r["num_compute_events"] == 1


def test_collectives_count_even_as_parents():
    """An async collective wrapping its own done-event is still collective
    time — only COMPUTE is restricted to leaves."""
    events = [
        _ev("all-gather.1", 0, 30),
        _ev("all-gather-done.2", 20, 5),
        _ev("tanh.4", 10, 10),
    ]
    r = overlap_from_events(events)
    assert r["collective_time_us"] == 30  # union of parent + nested done
    assert r["overlapped_time_us"] == 10
    assert 0.3 < r["overlap_fraction"] < 0.34


def test_non_hlo_events_are_ignored():
    """Python frames, runtime bookkeeping, and zero-duration markers never
    enter the attribution; an all-host trace reports fraction 0.0 without
    dividing by zero."""
    events = [
        _ev("$src/module.py:12 step", 0, 100),
        _ev("PjitFunction(step)", 0, 50),
        _ev("ThreadpoolListener::run", 0, 40),
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 1},
        _ev("dot.1", 0, 0),  # zero dur: dropped
    ]
    r = overlap_from_events(events)
    assert r["collective_time_us"] == 0
    assert r["compute_time_us"] == 0
    assert r["overlap_fraction"] == 0.0


def test_load_trace_events_reads_gzipped_chrome_traces(tmp_path):
    run = tmp_path / "plugins" / "profile" / "run1"
    run.mkdir(parents=True)
    with gzip.open(run / "host.trace.json.gz", "wt") as f:
        json.dump({"traceEvents": [_ev("dot.1", 0, 5)]}, f)
    with gzip.open(run / "bad.trace.json.gz", "wt") as f:
        f.write("not json")  # truncated/foreign file: skipped, not fatal
    events = load_trace_events(str(tmp_path))
    assert [e["name"] for e in events] == ["dot.1"]
    assert load_trace_events(str(tmp_path / "missing")) == []


def test_capture_overlap_report_degrades_on_error(tmp_path):
    """A step_fn that raises must yield a zeroed report with an ``error``
    field (the bench keeps timing; the gate falls back to tick bounds),
    and the trace dir is still reported for upload."""
    def boom():
        raise RuntimeError("no step")

    r = capture_overlap_report(boom, trace_dir=str(tmp_path / "t"))
    assert r["overlap_fraction"] == 0.0
    assert "RuntimeError" in r["error"]
    assert r["trace_dir"] == str(tmp_path / "t")


def test_async_flags_are_verified_spellings():
    """The async fallback appends these to XLA_FLAGS; an unknown flag
    ABORTS backend init, so the list must stay exactly the spellings the
    bundled jaxlib accepts."""
    assert ASYNC_XLA_FLAGS == (
        "--xla_gpu_enable_latency_hiding_scheduler=true",
        "--xla_cpu_enable_concurrency_optimized_scheduler=true",
    )
    assert all(f.startswith("--xla_") and "=" in f for f in ASYNC_XLA_FLAGS)
