"""GNN layers: backend agreement, normalization, attention invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import load_dataset
from repro.models.gnn import layers as L
from repro.models.gnn.net import build_paper_gat, build_gnn


@pytest.fixture(scope="module")
def karate():
    return load_dataset("karate")


def test_gat_dense_equals_padded(karate):
    g = karate
    p = L.init_gat(jax.random.PRNGKey(0), g.num_features, 8, heads=4)
    h = g.features
    out_p = L.gat_layer(p, g, h, backend="padded")
    out_d = L.gat_layer(p, g, h, backend="dense")
    assert jnp.allclose(out_p, out_d, atol=1e-4), float(jnp.max(jnp.abs(out_p - out_d)))


def test_gat_pallas_matches_padded(karate):
    g = karate
    p = L.init_gat(jax.random.PRNGKey(0), g.num_features, 8, heads=4)
    out_p = L.gat_layer(p, g, g.features, backend="padded")
    out_k = L.gat_layer(p, g, g.features, backend="pallas")
    assert jnp.allclose(out_p, out_k, atol=1e-4)


def test_gcn_backends_agree(karate):
    g = karate
    p = L.init_gcn(jax.random.PRNGKey(0), g.num_features, 16)
    out_p = L.gcn_layer(p, g, g.features, backend="padded")
    out_d = L.gcn_layer(p, g, g.features, backend="dense")
    out_k = L.gcn_layer(p, g, g.features, backend="pallas")
    assert jnp.allclose(out_p, out_d, atol=1e-4)
    assert jnp.allclose(out_p, out_k, atol=1e-4)


def test_gat_attention_rows_sum_to_one(karate):
    """Masked softmax invariant, via a uniform-value probe: if all neighbor
    features are 1, the attention-weighted sum must be exactly 1."""
    g = karate
    heads, out_dim = 3, 5
    p = L.init_gat(jax.random.PRNGKey(1), g.num_features, out_dim, heads=heads)
    ones = jnp.ones((g.num_nodes, g.num_features))
    # force W·h == 1 by zeroing W and adding bias-like trick: instead probe
    # alpha directly through a linear model with constant transformed feats
    p = dict(p, w=jnp.zeros_like(p["w"]), b=jnp.ones_like(p["b"]))
    out = L.gat_layer(p, g, ones, concat=False, backend="padded")
    # Wh == 0 -> out = Σ alpha·0 + b = 1 exactly; checks padding rows too
    assert jnp.allclose(out, jnp.ones_like(out), atol=1e-5)


def test_graphconv_and_gated(karate):
    g = karate
    p1 = L.init_graph_conv(jax.random.PRNGKey(0), g.num_features, 8)
    o1 = L.graph_conv_layer(p1, g, g.features)
    assert o1.shape == (g.num_nodes, 8)
    p2 = L.init_gated_graph_conv(jax.random.PRNGKey(1), 8)
    o2 = L.gated_graph_conv_layer(p2, g, o1)
    assert o2.shape == (g.num_nodes, 8)
    assert np.isfinite(np.asarray(o2)).all()


def test_gated_graph_conv_init_keys_independent():
    """w_h and u_h were both drawn from the same key (GRU candidate's input
    and recurrent projections started identical); params carry no dead
    entries — the step count is the apply-time kwarg."""
    p = L.init_gated_graph_conv(jax.random.PRNGKey(0), 16)
    assert set(p) == {"w_msg", "w_zr", "u_zr", "w_h", "u_h"}
    assert not np.allclose(np.asarray(p["w_h"]), np.asarray(p["u_h"]))
    # every weight pairwise distinct (5 independent subkeys)
    mats = [np.asarray(p[k]) for k in ("w_msg", "w_h", "u_h")]
    for i in range(len(mats)):
        for j in range(i + 1, len(mats)):
            assert not np.allclose(mats[i], mats[j])


def test_pallas_gat_attn_dropout_validates_up_front(karate):
    """Both entry points fail fast with a clear error when asked to train
    attention dropout through the deterministic fused kernel; eval and
    rate-0 paths stay usable."""
    g = karate
    rng = jax.random.PRNGKey(0)
    p = L.init_gat(jax.random.PRNGKey(1), g.num_features, 8, heads=2)
    # layer path: raises before running the kernel
    with pytest.raises(ValueError, match="deterministic"):
        L.gat_layer(p, g, g.features, attn_dropout=0.5, rng=rng, train=True,
                    backend="pallas")
    # net path: no silent zeroing — the same clear error surfaces
    m = build_paper_gat(g.num_features, g.num_classes, backend="pallas")
    params = m.init_params(jax.random.PRNGKey(2))
    with pytest.raises(ValueError, match="deterministic"):
        m.apply(params, g, rng=rng, train=True)
    # eval path (train=False) is deterministic anyway and must work
    logp = m.apply(params, g, train=False)
    assert np.isfinite(np.asarray(logp)).all()
    # rate-0 training works
    m0 = build_paper_gat(g.num_features, g.num_classes, backend="pallas", attn_dropout=0.0)
    p0 = m0.init_params(jax.random.PRNGKey(2))
    logp = m0.apply(p0, g, rng=rng, train=True)
    assert np.isfinite(np.asarray(logp)).all()


def test_paper_model_shapes(karate):
    g = karate
    m = build_paper_gat(g.num_features, g.num_classes)
    params = m.init_params(jax.random.PRNGKey(0))
    logp = m.apply(params, g)
    assert logp.shape == (g.num_nodes, g.num_classes)
    # log-softmax rows normalize
    assert jnp.allclose(jnp.exp(logp).sum(-1), 1.0, atol=1e-4)


@pytest.mark.parametrize("kind", ["gcn", "graphconv", "gatedgraphconv"])
def test_model_zoo_builds(karate, kind):
    g = karate
    m = build_gnn(kind, g.num_features, g.num_classes, hidden=16)
    params = m.init_params(jax.random.PRNGKey(0))
    logp = m.apply(params, g)
    assert logp.shape == (g.num_nodes, g.num_classes)
    assert np.isfinite(np.asarray(logp)).all()


# -------------------------------------------- degree-bucketed layer inputs --


@pytest.fixture(scope="module")
def skewed_mini():
    from repro.graphs import degree_bucketed_layout

    g = load_dataset("skewed-mini")
    return g, degree_bucketed_layout(g)


def test_gcn_pallas_bucketed_matches_padded(skewed_mini):
    """The pallas backend reads the degree-bucketed tiles when handed a
    BucketedGraphBatch and must agree with the padded gather on the same
    graph (same math, different layout)."""
    g, b = skewed_mini
    p = L.init_gcn(jax.random.PRNGKey(0), g.num_features, 16)
    out_p = L.gcn_layer(p, g, g.features, backend="padded")
    out_b = L.gcn_layer(p, b, g.features, backend="pallas")
    assert jnp.allclose(out_p, out_b, atol=1e-4), float(jnp.max(jnp.abs(out_p - out_b)))


def test_gat_pallas_bucketed_matches_padded(skewed_mini):
    g, b = skewed_mini
    p = L.init_gat(jax.random.PRNGKey(0), g.num_features, 8, heads=4)
    out_p = L.gat_layer(p, g, g.features, backend="padded")
    out_b = L.gat_layer(p, b, g.features, backend="pallas")
    assert jnp.allclose(out_p, out_b, atol=1e-4), float(jnp.max(jnp.abs(out_p - out_b)))


def test_padded_backend_ignores_bucket_wrapper(skewed_mini):
    """BucketedGraphBatch delegates to its base: the padded/dense backends
    see the wrapper as the plain padded batch (layout-blind plumbing)."""
    g, b = skewed_mini
    p = L.init_gcn(jax.random.PRNGKey(1), g.num_features, 8)
    out_g = L.gcn_layer(p, g, g.features, backend="padded")
    out_b = L.gcn_layer(p, b, g.features, backend="padded")
    assert jnp.array_equal(out_g, out_b)


def test_bucketed_layer_forced_kernel_matches_oracle(monkeypatch, skewed_mini):
    """REPRO_PALLAS_FORCE_KERNEL=1 (the CI kernels-smoke env) drives the
    layer through the real Pallas kernels in interpret mode."""
    g, b = skewed_mini
    p = L.init_gcn(jax.random.PRNGKey(2), g.num_features, 8)
    want = L.gcn_layer(p, b, g.features, backend="pallas")
    monkeypatch.setenv("REPRO_PALLAS_FORCE_KERNEL", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    got = L.gcn_layer(p, b, g.features, backend="pallas")
    assert jnp.allclose(want, got, atol=1e-4)
