"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.kernels.gat_edge.kernel import gat_aggregate_kernel
from repro.kernels.gat_edge.ref import gat_aggregate_ref
from repro.kernels.gat_edge.ops import gat_aggregate, _ref_call
from repro.kernels.spmm.kernel import padded_spmm_kernel
from repro.kernels.spmm.ref import padded_spmm_ref
from repro.kernels.spmm.ops import padded_spmm
from repro.kernels.ssd.ops import ssd
from repro.models.transformer.ssm import ssd_chunked, ssd_reference


def _tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 1e-4


# ------------------------------------------------------------- GAT edge --


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,h,f", [(64, 4, 2, 8), (300, 9, 8, 8), (130, 16, 4, 16)])
def test_gat_kernel_shapes(n, d, h, f, dtype):
    k = jax.random.PRNGKey(n + d)
    nbr_hw = jax.random.normal(k, (h, n, d, f), dtype)
    s_self = jax.random.normal(jax.random.PRNGKey(1), (h, n), dtype)
    s_nbr = jax.random.normal(jax.random.PRNGKey(2), (h, n, d), dtype)
    mask = jax.random.bernoulli(jax.random.PRNGKey(3), 0.7, (n, d)).at[:, 0].set(True)
    out_k = gat_aggregate_kernel(nbr_hw, s_self, s_nbr, mask)
    out_r = gat_aggregate_ref(nbr_hw, s_self, s_nbr, mask)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), atol=_tol(dtype)
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 200),
    d=st.integers(1, 12),
    h=st.integers(1, 4),
    f=st.integers(1, 16),
    seed=st.integers(0, 99),
)
def test_gat_kernel_hypothesis(n, d, h, f, seed):
    k = jax.random.PRNGKey(seed)
    nbr_hw = jax.random.normal(k, (h, n, d, f))
    s_self = jax.random.normal(jax.random.fold_in(k, 1), (h, n))
    s_nbr = jax.random.normal(jax.random.fold_in(k, 2), (h, n, d))
    mask = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.6, (n, d)).at[:, 0].set(True)
    out_k = gat_aggregate_kernel(nbr_hw, s_self, s_nbr, mask, block_n=64)
    out_r = gat_aggregate_ref(nbr_hw, s_self, s_nbr, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)


def test_gat_op_gradients():
    N, D, H, F = 70, 5, 3, 8
    hw = jax.random.normal(jax.random.PRNGKey(0), (N, H, F))
    s_src = jax.random.normal(jax.random.PRNGKey(1), (N, H))
    s_dst = jax.random.normal(jax.random.PRNGKey(2), (N, H))
    nbr = jax.random.randint(jax.random.PRNGKey(3), (N, D), 0, N)
    mask = jnp.ones((N, D), bool)
    args = (hw, s_src, s_dst)
    g_k = jax.grad(lambda *a: jnp.sum(gat_aggregate(*a, nbr, mask) ** 2), argnums=(0, 1, 2))(*args)
    g_r = jax.grad(lambda *a: jnp.sum(_ref_call(*a, nbr, mask, 0.2) ** 2), argnums=(0, 1, 2))(*args)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ----------------------------------------------------------------- SpMM --


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,f", [(100, 7, 32), (512, 16, 64), (33, 3, 8)])
def test_spmm_kernel_shapes(n, d, f, dtype):
    hw = jax.random.normal(jax.random.PRNGKey(0), (n, f), dtype)
    nbr = jax.random.randint(jax.random.PRNGKey(1), (n, d), 0, n)
    norm = (jax.random.uniform(jax.random.PRNGKey(2), (n, d)) * 0.5).astype(dtype)
    out_k = padded_spmm_kernel(hw, nbr, norm, block_n=128)
    out_r = padded_spmm_ref(hw, nbr, norm)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=_tol(dtype) * d,
    )


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 300), d=st.integers(1, 10), f=st.integers(1, 32), seed=st.integers(0, 99))
def test_spmm_hypothesis(n, d, f, seed):
    k = jax.random.PRNGKey(seed)
    hw = jax.random.normal(k, (n, f))
    nbr = jax.random.randint(jax.random.fold_in(k, 1), (n, d), 0, n)
    norm = jax.random.uniform(jax.random.fold_in(k, 2), (n, d))
    out_k = padded_spmm_kernel(hw, nbr, norm, block_n=64)
    out_r = padded_spmm_ref(hw, nbr, norm)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)


def test_spmm_grad():
    n, d, f = 64, 5, 16
    hw = jax.random.normal(jax.random.PRNGKey(0), (n, f))
    nbr = jax.random.randint(jax.random.PRNGKey(1), (n, d), 0, n)
    norm = jax.random.uniform(jax.random.PRNGKey(2), (n, d))
    g1 = jax.grad(lambda a: jnp.sum(padded_spmm(a, nbr, norm) ** 2))(hw)
    g2 = jax.grad(lambda a: jnp.sum(padded_spmm_ref(a, nbr, norm) ** 2))(hw)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ------------------------------------------------------------------ SSD --


@pytest.mark.parametrize("s,chunk", [(64, 16), (256, 64), (128, 128)])
def test_ssd_kernel_vs_sequential(s, chunk):
    b, h, p, n = 2, 3, 8, 16
    k = jax.random.PRNGKey(s)
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h))) * 0.1
    A = -jnp.exp(jnp.linspace(0.0, 2.0, h))
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, s, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n)) * 0.3
    y_k = ssd(x, dt, A, B, C, chunk)
    y_r, _ = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-4)


def test_ssd_grad_matches_chunked():
    b, s, h, p, n = 1, 64, 2, 4, 8
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h))) * 0.1
    A = -jnp.exp(jnp.linspace(0.0, 1.0, h))
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, s, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n)) * 0.3
    g1 = jax.grad(lambda a: jnp.sum(ssd(a, dt, A, B, C, 16) ** 2))(x)
    g2 = jax.grad(lambda a: jnp.sum(ssd_chunked(a, dt, A, B, C, chunk=16)[0] ** 2))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    s_chunks=st.sampled_from([(32, 8), (64, 32), (96, 32)]),
    p=st.sampled_from([4, 8]),
    n=st.sampled_from([8, 16]),
    seed=st.integers(0, 50),
)
def test_ssd_hypothesis(s_chunks, p, n, seed):
    s, chunk = s_chunks
    b, h = 1, 2
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h))) * 0.1
    A = -jnp.exp(jax.random.uniform(jax.random.fold_in(k, 4), (h,)) * 2)
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, s, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n)) * 0.3
    y_k = ssd(x, dt, A, B, C, chunk)
    y_r, _ = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-4)
