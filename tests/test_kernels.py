"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.kernels.gat_edge.kernel import gat_aggregate_kernel
from repro.kernels.gat_edge.ref import gat_aggregate_ref
from repro.kernels.gat_edge.ops import gat_aggregate, _ref_call
from repro.kernels.spmm.kernel import padded_spmm_kernel
from repro.kernels.spmm.ref import padded_spmm_ref
from repro.kernels.spmm.ops import padded_spmm
from repro.kernels.ssd.ops import ssd
from repro.models.transformer.ssm import ssd_chunked, ssd_reference


def _tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 1e-4


# ------------------------------------------------------------- GAT edge --


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,h,f", [(64, 4, 2, 8), (300, 9, 8, 8), (130, 16, 4, 16)])
def test_gat_kernel_shapes(n, d, h, f, dtype):
    k = jax.random.PRNGKey(n + d)
    nbr_hw = jax.random.normal(k, (h, n, d, f), dtype)
    s_self = jax.random.normal(jax.random.PRNGKey(1), (h, n), dtype)
    s_nbr = jax.random.normal(jax.random.PRNGKey(2), (h, n, d), dtype)
    mask = jax.random.bernoulli(jax.random.PRNGKey(3), 0.7, (n, d)).at[:, 0].set(True)
    out_k = gat_aggregate_kernel(nbr_hw, s_self, s_nbr, mask)
    out_r = gat_aggregate_ref(nbr_hw, s_self, s_nbr, mask)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), atol=_tol(dtype)
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 200),
    d=st.integers(1, 12),
    h=st.integers(1, 4),
    f=st.integers(1, 16),
    seed=st.integers(0, 99),
)
def test_gat_kernel_hypothesis(n, d, h, f, seed):
    k = jax.random.PRNGKey(seed)
    nbr_hw = jax.random.normal(k, (h, n, d, f))
    s_self = jax.random.normal(jax.random.fold_in(k, 1), (h, n))
    s_nbr = jax.random.normal(jax.random.fold_in(k, 2), (h, n, d))
    mask = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.6, (n, d)).at[:, 0].set(True)
    out_k = gat_aggregate_kernel(nbr_hw, s_self, s_nbr, mask, block_n=64)
    out_r = gat_aggregate_ref(nbr_hw, s_self, s_nbr, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)


def test_gat_op_gradients():
    N, D, H, F = 70, 5, 3, 8
    hw = jax.random.normal(jax.random.PRNGKey(0), (N, H, F))
    s_src = jax.random.normal(jax.random.PRNGKey(1), (N, H))
    s_dst = jax.random.normal(jax.random.PRNGKey(2), (N, H))
    nbr = jax.random.randint(jax.random.PRNGKey(3), (N, D), 0, N)
    mask = jnp.ones((N, D), bool)
    args = (hw, s_src, s_dst)
    g_k = jax.grad(lambda *a: jnp.sum(gat_aggregate(*a, nbr, mask) ** 2), argnums=(0, 1, 2))(*args)
    g_r = jax.grad(lambda *a: jnp.sum(_ref_call(*a, nbr, mask, 0.2) ** 2), argnums=(0, 1, 2))(*args)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ----------------------------------------------------------------- SpMM --


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,f", [(100, 7, 32), (512, 16, 64), (33, 3, 8)])
def test_spmm_kernel_shapes(n, d, f, dtype):
    hw = jax.random.normal(jax.random.PRNGKey(0), (n, f), dtype)
    nbr = jax.random.randint(jax.random.PRNGKey(1), (n, d), 0, n)
    norm = (jax.random.uniform(jax.random.PRNGKey(2), (n, d)) * 0.5).astype(dtype)
    out_k = padded_spmm_kernel(hw, nbr, norm, block_n=128)
    out_r = padded_spmm_ref(hw, nbr, norm)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=_tol(dtype) * d,
    )


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 300), d=st.integers(1, 10), f=st.integers(1, 32), seed=st.integers(0, 99))
def test_spmm_hypothesis(n, d, f, seed):
    k = jax.random.PRNGKey(seed)
    hw = jax.random.normal(k, (n, f))
    nbr = jax.random.randint(jax.random.fold_in(k, 1), (n, d), 0, n)
    norm = jax.random.uniform(jax.random.fold_in(k, 2), (n, d))
    out_k = padded_spmm_kernel(hw, nbr, norm, block_n=64)
    out_r = padded_spmm_ref(hw, nbr, norm)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)


def test_spmm_grad():
    n, d, f = 64, 5, 16
    hw = jax.random.normal(jax.random.PRNGKey(0), (n, f))
    nbr = jax.random.randint(jax.random.PRNGKey(1), (n, d), 0, n)
    norm = jax.random.uniform(jax.random.PRNGKey(2), (n, d))
    g1 = jax.grad(lambda a: jnp.sum(padded_spmm(a, nbr, norm) ** 2))(hw)
    g2 = jax.grad(lambda a: jnp.sum(padded_spmm_ref(a, nbr, norm) ** 2))(hw)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ------------------------------------------------------------------ SSD --


@pytest.mark.parametrize("s,chunk", [(64, 16), (256, 64), (128, 128)])
def test_ssd_kernel_vs_sequential(s, chunk):
    b, h, p, n = 2, 3, 8, 16
    k = jax.random.PRNGKey(s)
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h))) * 0.1
    A = -jnp.exp(jnp.linspace(0.0, 2.0, h))
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, s, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n)) * 0.3
    y_k = ssd(x, dt, A, B, C, chunk)
    y_r, _ = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-4)


def test_ssd_grad_matches_chunked():
    b, s, h, p, n = 1, 64, 2, 4, 8
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h))) * 0.1
    A = -jnp.exp(jnp.linspace(0.0, 1.0, h))
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, s, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n)) * 0.3
    g1 = jax.grad(lambda a: jnp.sum(ssd(a, dt, A, B, C, 16) ** 2))(x)
    g2 = jax.grad(lambda a: jnp.sum(ssd_chunked(a, dt, A, B, C, chunk=16)[0] ** 2))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    s_chunks=st.sampled_from([(32, 8), (64, 32), (96, 32)]),
    p=st.sampled_from([4, 8]),
    n=st.sampled_from([8, 16]),
    seed=st.integers(0, 50),
)
def test_ssd_hypothesis(s_chunks, p, n, seed):
    s, chunk = s_chunks
    b, h = 1, 2
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h))) * 0.1
    A = -jnp.exp(jax.random.uniform(jax.random.fold_in(k, 4), (h,)) * 2)
    B = jax.random.normal(jax.random.fold_in(k, 2), (b, s, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n)) * 0.3
    y_k = ssd(x, dt, A, B, C, chunk)
    y_r, _ = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-4)


# ----------------------------------------- bucketed (degree-tiled) layout --


def _bucketed_fixture(seed=0, n=80, m=220, f=16):
    """A real degree-bucketed layout (from graphs.partition) plus random
    features — the kernels' contract is the layout the layers feed them."""
    from repro.graphs.data import build_graph_batch
    from repro.graphs.partition import degree_bucketed_layout

    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(m, 2))
    feats = rng.standard_normal((n, 4)).astype(np.float32)
    labels = rng.integers(0, 3, size=n)
    g = build_graph_batch(feats, edges, labels, 3)
    b = degree_bucketed_layout(g, widths=(4, 8, g.neighbors.shape[1]))
    hw = jax.random.normal(jax.random.PRNGKey(seed), (n, f))
    return g, b, hw


def _bucket_tuples(b):
    return (
        tuple(bk.neighbors for bk in b.buckets),
        tuple(bk.norm for bk in b.buckets),
        tuple(bk.mask for bk in b.buckets),
        tuple(bk.row_node for bk in b.buckets),
    )


def test_bucket_spmm_kernel_matches_ref_tile():
    from repro.kernels.spmm.kernel import bucket_spmm_kernel

    k = jax.random.PRNGKey(5)
    N, R, W, F = 120, 24, 8, 16
    hw = jax.random.normal(k, (N, F))
    nbr = jax.random.randint(jax.random.fold_in(k, 1), (R, W), 0, N)
    nrm = jax.random.uniform(jax.random.fold_in(k, 2), (R, W))
    out_k = bucket_spmm_kernel(hw, nbr, nrm, block_r=16)
    out_r = jnp.einsum("rw,rwf->rf", nrm, hw[nbr])
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)


def test_bucketed_spmm_matches_padded_layout():
    from repro.kernels.spmm.ops import bucketed_spmm

    g, b, hw = _bucketed_fixture()
    nbrs, nrms, _, _ = _bucket_tuples(b)
    out_b = bucketed_spmm(hw, nbrs, nrms, b.gather_rows)
    out_p = padded_spmm_ref(hw, g.neighbors, g.norm)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_p), atol=1e-4)


def test_bucketed_spmm_grad_matches_padded():
    from repro.kernels.spmm.ops import bucketed_spmm

    g, b, hw = _bucketed_fixture(seed=1)
    nbrs, nrms, _, _ = _bucket_tuples(b)
    g_b = jax.grad(lambda a: jnp.sum(bucketed_spmm(a, nbrs, nrms, b.gather_rows) ** 2))(hw)
    g_p = jax.grad(lambda a: jnp.sum(padded_spmm_ref(a, g.neighbors, g.norm) ** 2))(hw)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_p), atol=1e-4)


def test_bucket_gat_kernel_matches_ref_tile():
    from repro.kernels.gat_edge.kernel import bucket_gat_kernel
    from repro.kernels.gat_edge.ref import bucket_gat_ref

    k = jax.random.PRNGKey(9)
    N, R, W, H, F = 90, 16, 8, 3, 8
    hw_heads = jax.random.normal(k, (H, N, F))
    nbr = jax.random.randint(jax.random.fold_in(k, 1), (R, W), 0, N)
    s_self = jax.random.normal(jax.random.fold_in(k, 2), (H, R))
    s_nbr = jax.random.normal(jax.random.fold_in(k, 3), (H, R, W))
    mask = jax.random.bernoulli(jax.random.fold_in(k, 4), 0.7, (R, W)).at[:, 0].set(True)
    out_k = bucket_gat_kernel(hw_heads, nbr, s_self, s_nbr, mask, block_r=8)
    out_r = bucket_gat_ref(hw_heads, nbr, s_self, s_nbr, mask)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)


def test_bucketed_gat_matches_padded_layout():
    from repro.kernels.gat_edge.ops import bucketed_gat_aggregate

    g, b, _ = _bucketed_fixture(seed=2)
    H, F = 3, 8
    k = jax.random.PRNGKey(4)
    hw = jax.random.normal(k, (g.num_nodes, H, F))
    s_src = jax.random.normal(jax.random.fold_in(k, 1), (g.num_nodes, H))
    s_dst = jax.random.normal(jax.random.fold_in(k, 2), (g.num_nodes, H))
    nbrs, _, msks, rows = _bucket_tuples(b)
    out_b = bucketed_gat_aggregate(hw, s_src, s_dst, nbrs, msks, rows, b.gather_rows)
    out_p = _ref_call(hw, s_src, s_dst, g.neighbors, g.mask, 0.2)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_p), atol=1e-4)


def test_bucketed_gat_grad_matches_padded():
    from repro.kernels.gat_edge.ops import bucketed_gat_aggregate

    g, b, _ = _bucketed_fixture(seed=3, n=50, m=140)
    H, F = 2, 4
    k = jax.random.PRNGKey(6)
    hw = jax.random.normal(k, (g.num_nodes, H, F))
    s_src = jax.random.normal(jax.random.fold_in(k, 1), (g.num_nodes, H))
    s_dst = jax.random.normal(jax.random.fold_in(k, 2), (g.num_nodes, H))
    nbrs, _, msks, rows = _bucket_tuples(b)
    g_b = jax.grad(
        lambda *a: jnp.sum(
            bucketed_gat_aggregate(*a, nbrs, msks, rows, b.gather_rows) ** 2
        ),
        argnums=(0, 1, 2),
    )(hw, s_src, s_dst)
    g_p = jax.grad(
        lambda *a: jnp.sum(_ref_call(*a, g.neighbors, g.mask, 0.2) ** 2),
        argnums=(0, 1, 2),
    )(hw, s_src, s_dst)
    for a, b_ in zip(g_b, g_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4)


def test_bucketed_ops_jit_with_forced_kernel(monkeypatch):
    """REPRO_PALLAS_FORCE_KERNEL=1 routes the bucketed forwards through the
    Pallas kernels (interpret mode here) inside jit — the CI smoke path —
    and still matches the oracle."""
    from repro.kernels.spmm.ops import bucketed_spmm
    from repro.kernels.spmm.ref import bucketed_spmm_ref

    g, b, hw = _bucketed_fixture(seed=4, n=40, m=90, f=8)
    nbrs, nrms, _, _ = _bucket_tuples(b)
    want = bucketed_spmm_ref(hw, nbrs, nrms, b.gather_rows)
    monkeypatch.setenv("REPRO_PALLAS_FORCE_KERNEL", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    got = jax.jit(
        lambda a: bucketed_spmm(a, nbrs, nrms, b.gather_rows)
    )(hw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
