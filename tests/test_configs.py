"""Config registry: all assigned archs present with exact hyper-parameters."""

import pytest

from repro.configs import SHAPES, get_arch, get_shape, list_archs
from repro.configs.base import pipeline_padding

ASSIGNED = {
    "codeqwen1.5-7b": dict(num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
                           d_ff=13440, vocab_size=92416),
    "qwen2.5-32b": dict(num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
                        d_ff=27648, vocab_size=152064),
    "qwen2-vl-2b": dict(num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
                        d_ff=8960, vocab_size=151936),
    "gemma2-27b": dict(num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
                       d_ff=36864, vocab_size=256000),
    "glm4-9b": dict(num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
                    d_ff=13696, vocab_size=151552),
    "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
                      d_ff=14336, vocab_size=32000, ssm_state=64),
    "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128,
                             d_ff=2048, vocab_size=129280, num_experts=256,
                             experts_per_token=8),
    "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
                        d_ff=4864, vocab_size=32000, num_experts=128,
                        experts_per_token=2),
    "musicgen-large": dict(num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
                           d_ff=8192, vocab_size=2048),
    "mamba2-130m": dict(num_layers=24, d_model=768, vocab_size=50280, ssm_state=128),
}


def test_all_ten_archs_registered():
    assert set(list_archs()) == set(ASSIGNED)


@pytest.mark.parametrize("name,fields", ASSIGNED.items())
def test_exact_assigned_hyperparameters(name, fields):
    cfg = get_arch(name)
    for k, v in fields.items():
        assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_smoke_variant_constraints(name):
    cfg = get_arch(name, smoke=True)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.arch_type == get_arch(name).arch_type


def test_shapes_table():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert get_shape("train_4k").global_batch == 256
    assert get_shape("prefill_32k").seq_len == 32768
    assert get_shape("decode_32k").kind == "decode"
    assert get_shape("long_500k").seq_len == 524288
    assert get_shape("long_500k").global_batch == 1


def test_arch_type_coverage():
    kinds = {get_arch(a).arch_type for a in list_archs()}
    assert kinds == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_gemma2_alternation_and_softcaps():
    cfg = get_arch("gemma2-27b")
    wins = cfg.layer_windows()
    assert wins[0] == 4096 and wins[1] == 0  # local, global, local, ...
    assert cfg.attn_softcap == 50.0 and cfg.logit_softcap == 30.0


def test_long_context_fallback_windows():
    cfg = get_arch("codeqwen1.5-7b")
    assert all(w == 0 for w in cfg.layer_windows())
    assert all(w == cfg.long_context_window for w in cfg.layer_windows(long_context=True))


def test_zamba2_hybrid_pattern():
    cfg = get_arch("zamba2-7b")
    kinds = cfg.layer_kinds()
    assert kinds[5] == "attn" and kinds[0] == "mamba"
    assert kinds.count("attn") == len([i for i in range(81) if i % 6 == 5])


def test_pipeline_padding_math():
    assert pipeline_padding(61, 16) == (4, 3)
    assert pipeline_padding(32, 16) == (2, 0)
    assert pipeline_padding(81, 16) == (6, 15)
