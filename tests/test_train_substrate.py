"""Optimizer / losses / data pipeline / checkpoint substrate."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.data.tokens import token_batch, frontend_embeds
from repro.train import optimizer as opt_lib
from repro.train.losses import masked_accuracy, masked_nll, softmax_xent


def test_adam_matches_reference_formula():
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    opt = opt_lib.adam(1e-2)
    st_ = opt.init(p)
    upd, st2 = opt.update(g, st_, p)
    # step 1: mhat = g, vhat = g², upd = -lr·g/(|g|+eps)
    expect = -1e-2 * np.sign(np.asarray(g["w"]))
    np.testing.assert_allclose(np.asarray(upd["w"]), expect, atol=1e-4)
    assert int(st2.step) == 1


def test_adam_weight_decay_and_clip():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    opt = opt_lib.adam(1e-2, weight_decay=0.1, grad_clip=1.0)
    upd, _ = opt.update(g, opt.init(p), p)
    assert np.isfinite(np.asarray(upd["w"])).all()


def test_sgd_momentum():
    p = {"w": jnp.zeros((2,))}
    g = {"w": jnp.ones((2,))}
    opt = opt_lib.sgd(0.1, momentum=0.9)
    s = opt.init(p)
    upd1, s = opt.update(g, s, p)
    upd2, s = opt.update(g, s, p)
    # velocity builds up
    assert float(jnp.abs(upd2["w"]).sum()) > float(jnp.abs(upd1["w"]).sum())


def test_cosine_schedule_shape():
    sched = opt_lib.cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.0, abs=0.01)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(2, 9), st.integers(0, 99))
def test_masked_nll_matches_numpy(n, c, seed):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((n, c)).astype(np.float32)
    logp = jax.nn.log_softmax(jnp.asarray(logits))
    labels = jnp.asarray(rng.integers(0, c, n))
    mask = jnp.asarray(rng.integers(0, 2, n).astype(bool))
    got = float(masked_nll(logp, labels, mask))
    lp = np.asarray(logp)
    sel = lp[np.arange(n), np.asarray(labels)]
    m = np.asarray(mask)
    want = -(sel * m).sum() / max(m.sum(), 1)
    assert got == pytest.approx(want, rel=1e-4)


def test_softmax_xent_uniform_is_log_vocab():
    v = 17
    logits = jnp.zeros((3, 5, v))
    labels = jnp.zeros((3, 5), jnp.int32)
    assert float(softmax_xent(logits, labels)) == pytest.approx(np.log(v), rel=1e-5)


def test_masked_accuracy():
    logp = jnp.log(jnp.asarray([[0.9, 0.1], [0.2, 0.8], [0.6, 0.4]]))
    labels = jnp.asarray([0, 1, 1])
    mask = jnp.asarray([True, True, False])
    assert float(masked_accuracy(logp, labels, mask)) == pytest.approx(1.0)


def test_token_batch_deterministic_and_in_range():
    a = token_batch(batch=4, seq=32, vocab=100, seed=7, step=3)
    b = token_batch(batch=4, seq=32, vocab=100, seed=7, step=3)
    c = token_batch(batch=4, seq=32, vocab=100, seed=7, step=4)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 100
    assert a.shape == (4, 33)


def test_frontend_embeds_shape():
    e = frontend_embeds(batch=2, seq=16, d_model=64, seed=0)
    assert e.shape == (2, 16, 64)
    assert np.isfinite(e).all()


def test_checkpoint_roundtrip(tmp_path):
    from repro.train.checkpoint import save_checkpoint, load_checkpoint

    params = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, step=42)
    restored, meta = load_checkpoint(path)
    assert meta["step"] == 42
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(params["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16
