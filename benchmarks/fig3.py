"""Fig 3 analogue — training time growth with micro-batch count.

The paper's slowdown comes from per-chunk sub-graph rebuilds; we report
epoch time AND the isolated rebuild cost so the overhead source is explicit.

Beyond-paper: every chunk count runs the full engine × schedule matrix —
host (fill-drain / 1F1B / interleaved / zb-h1 where legal) and compiled,
where fill-drain runs the fused scan and 1F1B/interleaved/zb-h1 run the
scheduled executor (``spmd_pipeline_scheduled``) inside the same jitted
program (zb-h1 splits every backward into B/W halves and fills the drain
bubble with deferred weight-grad work — its win needs concurrent ticks, so
the CI perf gate measures this table under 4 forced host devices). Each
row carries the schedule's bubble fraction and peak live activations
(measured on the host engine, static stash accounting on the scheduled
compiled path) next to the epoch time; ``compiled_vs_host`` reports the
speedup against the host fill-drain baseline of the same chunk count.

``json_path`` writes the whole table as machine-readable ``BENCH_fig3.json``
— the artifact the CI perf-regression gate (``benchmarks/check_perf.py``)
diffs against the committed baseline.

The table also carries the ``partition/*`` rows: the cost-model-driven stage
partitioner vs the layer-count-uniform split on a deliberately imbalanced
GCN stack (see ``_partition_bench``), with the measured per-layer cost table
written alongside the json as ``partition_costs.json``. ``partition=
"profiled"`` additionally reruns the main engine×schedule matrix with the
profiler choosing the paper model's balance (exercising the ``--partition``
CLI path end to end).

``scale/*`` rows extend the figure along the graph axis: streamed power-law
graphs up to 1e5 nodes, built chunk-by-chunk with nothing global ever
materialized, stepped on the (data, stage) mesh when the host has enough
devices (see ``_scale_bench``). The perf gate checks the run-internal
growth ratio step(n)/step(n_min) and the in-run host-oracle
``updates_match`` bit.

``auto/*`` rows measure the ``--auto`` planner: its pick (resolved
schedule x chunks x balance x placement) stepped interleaved against a set
of hand-picked configs on the imbalanced GCN fixture, with the planner's
predicted step time in the row (see ``_auto_bench``). The perf gate
requires the pick to be within threshold of the best measured hand-picked
config and bounds the predicted/measured ratio against the baseline's.

``overlap/*`` rows measure the double-buffered wire dataflow
(``--overlap double-buffer``) against the serialized ppermute-after-work
baseline on the deepest ring of the matrix, interleaved-stepped with an
in-run host fill-drain oracle check, plus a ``jax.profiler`` overlap
report for both modes (``overlap_report.json`` + uploaded traces — see
``_overlap_bench`` and ``repro.core.overlap_report``). The perf gate's
overlap rule is platform-conditional; the row carries the tick accounting
(``num_ticks``, ``wire_latency``) it needs.
"""

from __future__ import annotations

import json
import os
import statistics
import time

import jax

from benchmarks.common import PipelineCLIConfig, emit
from repro.core.microbatch import make_plan
from repro.graphs import load_dataset
from repro.launch.train import run_gnn

SCHEDULES = ("fill_drain", "1f1b", "interleaved", "zb-h1")
ENGINES = ("host", "compiled")


def run(*, dataset="cora", epochs=30, max_chunks=4, schedules=SCHEDULES,
        json_path=None, partition="uniform"):
    g = load_dataset(dataset)
    rows = []
    stages, pipe_devices = 4, 2
    bench = {
        "dataset": dataset,
        "stages": stages,
        "pipe_devices": pipe_devices,
        "epochs": epochs,
        "rows": {},
    }
    for chunks in range(1, max_chunks + 1):
        plan = make_plan(g, chunks, strategy="sequential")
        layer_costs = None
        if partition == "profiled":
            # profile ONCE per chunk count (costs depend only on the model
            # and the padded chunk shape) — every matrix cell below reuses
            # the measurement through the ``args.layer_costs`` pass-through,
            # and the fingerprint-keyed sidecar means a rerun (or the
            # ``--auto`` planner sweeping the same shapes) reuses it across
            # processes too
            from repro.core.costmodel import cached_profile_layer_costs
            from repro.models.gnn.net import build_paper_gat

            model = build_paper_gat(g.num_features, g.num_classes)
            chunk0 = jax.tree_util.tree_map(lambda a: a[0], plan.stacked().graph)
            layer_costs = cached_profile_layer_costs(
                model, model.init_params(jax.random.PRNGKey(0)), chunk0,
                cache_path=(
                    os.path.join(os.path.dirname(json_path), "layer_costs_cache.json")
                    if json_path else None
                ),
            )
        host_epoch_s = None
        for engine in ENGINES:
            for schedule in schedules:
                args = PipelineCLIConfig(
                    engine=engine, schedule=schedule, chunks=chunks, stages=stages,
                    partition=partition, pipe_devices=pipe_devices,
                ).namespace(
                    mode="gnn", dataset=dataset, strategy="sequential",
                    epochs=epochs, seed=0, log_every=0, layer_costs=layer_costs,
                )
                try:
                    r = run_gnn(args)
                except ValueError:
                    continue  # schedule rejects this (stages, chunks) combo
                finally:
                    # each cell leaves its jitted programs in the global
                    # compilation cache; without clearing, LATE cells measure
                    # under 30+ resident programs' worth of allocator/cache
                    # pressure the early cells never saw — a positional bias
                    # that lands exactly on the zb-h1 rows the perf gate
                    # compares against 1f1b
                    jax.clear_caches()
                # the CSV, the speedup ratio and the gated JSON all use the
                # same MEDIAN estimator — mixing estimators made the human
                # artifact disagree with what the gate enforces whenever a
                # scheduler hiccup inflated one cell's mean
                step_s = r["median_epoch_s"]
                if engine == "host" and schedule == "fill_drain":
                    host_epoch_s = step_s
                name = (
                    f"{schedule}_chunks{chunks}" if engine == "host"
                    else f"compiled_{schedule}_chunks{chunks}"
                )
                derived = (
                    f"rebuild_s={plan.rebuild_seconds:.3f};edge_cut={plan.edge_cut:.3f};"
                    f"bubble={r['bubble_fraction']:.3f};"
                    f"peak_live={r['peak_live_activations']}"
                )
                if engine == "compiled" and host_epoch_s:
                    derived += f";compiled_vs_host={host_epoch_s / step_s:.2f}x"
                emit(f"fig3/{dataset}/{name}", step_s * 1e6, derived)
                bench["rows"][f"{engine}/{schedule}/chunks{chunks}"] = {
                    # median, not mean: the gate's strict/thresholded row
                    # comparisons must not hinge on whether a scheduler
                    # hiccup landed in this cell's epochs (means came out
                    # 2-3x the median on contended CI-class hosts)
                    "step_s": r["median_epoch_s"],
                    "bubble": r["bubble_fraction"],
                    "peak_live": r["peak_live_activations"],
                    "peak_live_accounted": r["peak_live_accounted"],
                    "rebuild_s": plan.rebuild_seconds,
                }
                rows.append((f"{engine}/{schedule}", chunks, step_s, plan.rebuild_seconds))
    rows.extend(
        _partition_bench(
            bench,
            epochs=max(epochs, 12),
            json_dir=os.path.dirname(json_path) if json_path else None,
        )
    )
    rows.extend(
        _sparse_bench(
            bench,
            epochs=max(epochs, 12),
            json_dir=os.path.dirname(json_path) if json_path else None,
        )
    )
    rows.extend(_scale_bench(bench, epochs=max(epochs // 2, 8)))
    rows.extend(
        _overlap_bench(
            bench,
            epochs=max(epochs, 12),
            json_dir=os.path.dirname(json_path) if json_path else None,
        )
    )
    rows.extend(
        _auto_bench(
            bench,
            epochs=max(epochs, 12),
            json_dir=os.path.dirname(json_path) if json_path else None,
        )
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


def _partition_bench(bench, *, epochs, chunks=4, dataset="cora", json_dir=None):
    """Cost-model partitioner vs the layer-count-uniform split on the
    deliberately imbalanced GCN stack (``build_imbalanced_gcn``: the leading
    convs are ~10x the tail, so the uniform split stacks the two heavy
    layers into stage 0 and every pipeline tick waits on it). Both configs
    run the compiled 1F1B executor; rows land in the BENCH json as
    ``partition/{uniform|profiled}/chunksC`` — the perf gate requires
    profiled to beat uniform when ticks run concurrently (the CI gate's
    4 forced host devices). The measured per-layer cost table is written to
    ``json_dir/partition_costs.json`` (the CI artifact)."""
    from repro.core.costmodel import (
        choose_balance,
        predicted_balance_time,
        profile_layer_costs,
        uniform_balance,
    )
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.core.schedule import get_schedule
    from repro.models.gnn.net import build_imbalanced_gcn
    from repro.train import optimizer as opt_lib

    g = load_dataset(dataset)
    model = build_imbalanced_gcn(g.num_features, g.num_classes)
    plan = make_plan(g, chunks, strategy="sequential")
    chunk0 = jax.tree_util.tree_map(lambda a: a[0], plan.stacked().graph)
    params0 = model.init_params(jax.random.PRNGKey(0))
    costs = profile_layer_costs(model, params0, chunk0)
    schedule = get_schedule("1f1b")
    stages = 4
    profiled, _ = choose_balance(costs, stages, schedule, chunks)
    balances = {
        "uniform": uniform_balance(len(model.layers), stages),
        "profiled": profiled,
    }
    if json_dir:
        os.makedirs(json_dir, exist_ok=True)
        with open(os.path.join(json_dir, "partition_costs.json"), "w") as f:
            json.dump(
                {
                    "dataset": dataset,
                    "model": "imbalanced_gcn",
                    "layers": costs.table(),
                    "balances": {
                        name: {
                            "balance": list(bal),
                            "predicted_step_s": predicted_balance_time(
                                costs, bal, schedule, chunks
                            ),
                        }
                        for name, bal in balances.items()
                    },
                },
                f, indent=2,
            )
            f.write("\n")

    # the perf gate compares the two rows STRICTLY, so the measurement must
    # be drift-proof: the configs' steps run interleaved (machine drift —
    # thermal, neighbors, allocator state — hits both equally instead of
    # whichever ran second) and the estimator is the median with the
    # compile step dropped
    opt = opt_lib.adam(1e-2)
    pipes, states, times = {}, {}, {}
    for name, balance in balances.items():
        pipes[name] = make_engine(model, GPipeConfig(engine="compiled",
            balance=balance, chunks=chunks, schedule="1f1b",
        ))
        params = pipes[name].init_params(jax.random.PRNGKey(0))
        states[name] = [params, opt.init(params), jax.random.PRNGKey(0)]
        times[name] = []
    for _ in range(epochs):
        for name, pipe in pipes.items():
            params, state, key = states[name]
            key, rng = jax.random.split(key)
            t0 = time.perf_counter()
            params, state, loss = pipe.train_step(params, state, plan, rng, opt)
            jax.block_until_ready(loss)
            times[name].append(time.perf_counter() - t0)
            states[name] = [params, state, key]

    rows = []
    for name, balance in balances.items():
        step_s = statistics.median(times[name][1:])
        predicted = predicted_balance_time(costs, balance, schedule, chunks)
        emit(
            f"fig3/{dataset}/partition_{name}_chunks{chunks}",
            step_s * 1e6,
            f"balance={'-'.join(map(str, balance))};predicted_s={predicted:.4f}",
        )
        bench["rows"][f"partition/{name}/chunks{chunks}"] = {
            "step_s": step_s,
            "balance": list(balance),
            "predicted_step_s": predicted,
        }
        rows.append((f"partition/{name}", chunks, step_s, plan.rebuild_seconds))
    return rows


def _scale_bench(bench, *, epochs, sizes=(25_000, 50_000, 100_000),
                 nodes_per_chunk=12_500, dataset="powerlaw-64k"):
    """Step time vs graph size on the streamed power-law generator — the
    paper's figure extended along the graph axis instead of the chunk axis.

    Each size ``n`` materializes nothing globally: ``open_streamed`` builds
    ``n / nodes_per_chunk`` chunks block-by-block on the host (so per-chunk
    work stays roughly constant and chunk count carries the growth), and the
    compiled engine shards them over the (data, stage) mesh when the host
    has >= data_parallel * ring devices (the CI gate's 4 forced devices),
    else the single-replica fallback — recorded per row as
    ``data_parallel_active``. Rows land in the BENCH json as
    ``scale/n{N}/chunks{C}`` with the one-step host fill-drain oracle check
    (``updates_match``) computed in the SAME run the gate times; the gate
    compares the run-internal growth ratio step(n)/step(n_min) against the
    baseline's ratio, which cancels machine speed entirely.

    The sizes stay ~1e5 so the oracle+timing loop fits a CI lane; the 1e6
    registry entries (``powerlaw-1m``) run through the identical code path
    (see ``examples/scaling_larger_graphs.py``)."""
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.graphs import open_streamed, streamed_plan
    from repro.models.gnn.net import build_gnn
    from repro.train import optimizer as opt_lib

    balance = (2, 2)
    dp = 2 if jax.device_count() >= 2 * len(balance) else 1
    opt = opt_lib.adam(1e-2)

    pipes, plans, states, times, meta = {}, {}, {}, {}, {}
    for n in sizes:
        chunks = max(n // nodes_per_chunk, dp)
        ds = open_streamed(dataset, num_nodes=n)
        plan = streamed_plan(ds, chunks, max_degree=32)
        g0 = plan.batches[0].graph
        model = build_gnn("gcn", g0.num_features, g0.num_classes,
                          hidden=32, depth=2)
        pipe = make_engine(model, GPipeConfig(
            engine="compiled", balance=balance, chunks=chunks,
            schedule="1f1b", data_parallel=dp,
        ))
        params0 = pipe.init_params(jax.random.PRNGKey(0))

        # oracle check in the measured run: one step from identical params
        # through the host fill-drain reference and the compiled mesh config
        host = make_engine(model, GPipeConfig(
            engine="host", balance=balance, chunks=chunks))
        rng0 = jax.random.PRNGKey(1)
        p_ref, _, _ = host.train_step(params0, opt.init(params0), plan, rng0, opt)
        p_cmp, _, _ = pipe.train_step(params0, opt.init(params0), plan, rng0, opt)
        diff = max(
            float(abs(a - b).max()) for a, b in zip(
                jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_cmp)
            )
        )
        pipes[n], plans[n] = pipe, plan
        states[n] = [params0, opt.init(params0), jax.random.PRNGKey(0)]
        times[n] = []
        meta[n] = {"chunks": chunks, "diff": diff, "edge_cut": plan.edge_cut,
                   "dp_active": pipe._data_parallel_active}

    # interleaved measurement across sizes: drift (thermal, neighbors,
    # allocator) hits every size equally, so the gate's step(n)/step(n_min)
    # ratio is drift-free; median with the warm-up step dropped
    for _ in range(epochs):
        for n, pipe in pipes.items():
            params, state, key = states[n]
            key, rng = jax.random.split(key)
            t0 = time.perf_counter()
            params, state, loss = pipe.train_step(params, state, plans[n], rng, opt)
            jax.block_until_ready(loss)
            times[n].append(time.perf_counter() - t0)
            states[n] = [params, state, key]

    tol = 5e-4  # engine-oracle tolerance (compiled program fuses differently)
    rows = []
    for n in sizes:
        step_s = statistics.median(times[n][1:])
        chunks = meta[n]["chunks"]
        emit(
            f"fig3/{dataset}/scale_n{n}_chunks{chunks}",
            step_s * 1e6,
            f"max_update_diff={meta[n]['diff']:.2e};"
            f"edge_cut={meta[n]['edge_cut']:.3f};"
            f"data_parallel={dp if meta[n]['dp_active'] else 1}",
        )
        bench["rows"][f"scale/n{n}/chunks{chunks}"] = {
            "step_s": step_s,
            "nodes": n,
            "chunks": chunks,
            "max_update_diff": meta[n]["diff"],
            "updates_match": meta[n]["diff"] <= tol,
            "edge_cut": meta[n]["edge_cut"],
            "data_parallel_active": meta[n]["dp_active"],
        }
        rows.append((f"scale/n{n}", chunks, step_s, 0.0))
    return rows


def _sparse_bench(bench, *, epochs, chunks=2, dataset="skewed-powerlaw", json_dir=None):
    """Degree-bucketed pallas aggregation vs the padded layout on the
    power-law fixture (median degree ~14, max capped at 128 — the padded
    layout spends ~90% of its slots on padding). Rows land in the BENCH
    json as ``sparse/{padded|bucketed}/chunksC``; the perf gate requires
    the bucketed compiled step to beat padded STRICTLY in the same run and
    the two updates to agree at oracle tolerance with a host fill-drain
    reference step. The per-stage roofline table (measured vs roof
    bytes/FLOPs for both layouts — the fig's sparse row) is written to
    ``json_dir/roofline_stages.json``."""
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.graphs import bucketize_stacked
    from repro.models.gnn.net import build_gnn
    from repro.roofline import sparse_stage_report
    from repro.train import optimizer as opt_lib

    # max_degree=128 keeps the padded einsum's (n, max_deg, hidden) gather
    # bounded while preserving the skew (median 14 vs cap 128)
    g = load_dataset(dataset, max_degree=128)
    balance = (2, 2)
    models = {
        "padded": build_gnn("gcn", g.num_features, g.num_classes,
                            hidden=32, depth=2, backend="padded"),
        "bucketed": build_gnn("gcn", g.num_features, g.num_classes,
                              hidden=32, depth=2, backend="pallas"),
    }
    plan = make_plan(g, chunks, strategy="sequential")
    opt = opt_lib.adam(1e-2)

    # oracle-tolerance update identity, asserted in the SAME run the gate
    # times: one step from identical params through the host fill-drain
    # padded reference and through each measured compiled config
    ref = make_engine(models["padded"], GPipeConfig(
        balance=balance, chunks=chunks, engine="host", backend="padded"))
    params0 = ref.init_params(jax.random.PRNGKey(0))
    rng0 = jax.random.PRNGKey(1)
    p_ref, _, _ = ref.train_step(params0, opt.init(params0), plan, rng0, opt)

    pipes, states, times, diffs = {}, {}, {}, {}
    for name, model in models.items():
        pipes[name] = make_engine(model, GPipeConfig(
            balance=balance, chunks=chunks, engine="compiled",
            backend="pallas" if name == "bucketed" else "padded",
        ))
        p1, _, _ = pipes[name].train_step(params0, opt.init(params0), plan, rng0, opt)
        diffs[name] = max(
            float(abs(a - b).max()) for a, b in zip(
                jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p1)
            )
        )
        states[name] = [params0, opt.init(params0), jax.random.PRNGKey(0)]
        times[name] = []

    # interleaved measurement (drift hits both layouts equally), median
    # with the warm-up step dropped — same discipline as _partition_bench
    for _ in range(epochs):
        for name, pipe in pipes.items():
            params, state, key = states[name]
            key, rng = jax.random.split(key)
            t0 = time.perf_counter()
            params, state, loss = pipe.train_step(params, state, plan, rng, opt)
            jax.block_until_ready(loss)
            times[name].append(time.perf_counter() - t0)
            states[name] = [params, state, key]

    stacked = plan.stacked().graph
    report = sparse_stage_report(
        models["bucketed"], params0, stacked, bucketize_stacked(stacked), balance
    )
    if json_dir:
        os.makedirs(json_dir, exist_ok=True)
        with open(os.path.join(json_dir, "roofline_stages.json"), "w") as f:
            json.dump({"dataset": dataset, "balance": list(balance), **report}, f, indent=2)
            f.write("\n")

    tol = 2e-4  # oracle tolerance: bucket concat reorders f32 edge sums
    rows = []
    for name in models:
        step_s = statistics.median(times[name][1:])
        slots = report["slots"]
        emit(
            f"fig3/{dataset}/sparse_{name}_chunks{chunks}",
            step_s * 1e6,
            f"max_update_diff={diffs[name]:.2e};"
            f"slots={slots[name] if name in slots else slots['padded']:.0f};"
            f"live_slots={slots['live']:.0f}",
        )
        bench["rows"][f"sparse/{name}/chunks{chunks}"] = {
            "step_s": step_s,
            "max_update_diff": diffs[name],
            "updates_match": diffs[name] <= tol,
            "layout_slots": slots.get(name, slots["padded"]),
            "live_slots": slots["live"],
        }
        rows.append((f"sparse/{name}", chunks, step_s, plan.rebuild_seconds))
    return rows


def _overlap_bench(bench, *, epochs, chunks=8, dataset="cora", json_dir=None):
    """Double-buffered wire ticks vs the serialized baseline on the deepest
    ring of the matrix (paper GAT, balance (2,1,1,2), 1f1b).

    Both engines run the SAME lowered schedule family; ``double-buffer``
    retimes it to wire latency 2 so each tick's ppermute pair is posted one
    tick before its arrivals are consumed (no data dependency pins it to
    the critical path). The update stays bit-identical dataflow, checked
    here at oracle tolerance against one host fill-drain step from
    identical params — in the SAME run the gate times.

    Each row carries the tick accounting the perf gate's
    platform-conditional rule needs: on runtimes whose traced
    ``overlap_fraction`` shows real hiding, the gate requires the
    double-buffered STEP to win outright; on lockstep single-threaded
    executors (CI's forced-host CPU — fraction ~0, no scheduling can win
    wall-clock there) it bounds the retimed program's per-TICK cost
    instead. ``capture_overlap_report`` traces one warm step per mode and
    the pair of reports lands in ``json_dir/overlap_report.json`` with the
    raw profiler traces beside it."""
    from repro.core.overlap_report import capture_overlap_report
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.models.gnn.net import build_paper_gat
    from repro.train import optimizer as opt_lib

    g = load_dataset(dataset)
    model = build_paper_gat(g.num_features, g.num_classes)
    plan = make_plan(g, chunks, strategy="sequential")
    balance = (2, 1, 1, 2)
    opt = opt_lib.adam(1e-2)
    modes = {"serialized": "off", "double-buffer": "double-buffer"}

    # oracle update check, same discipline as _sparse_bench: one step from
    # identical params through a host fill-drain reference and through each
    # measured compiled config, in the run the gate times
    ref = make_engine(model, GPipeConfig(balance=balance, chunks=chunks, engine="host"))
    params0 = ref.init_params(jax.random.PRNGKey(0))
    rng0 = jax.random.PRNGKey(1)
    p_ref, _, _ = ref.train_step(params0, opt.init(params0), plan, rng0, opt)

    pipes, states, times, diffs, stats = {}, {}, {}, {}, {}
    for name, overlap in modes.items():
        pipes[name] = make_engine(model, GPipeConfig(
            balance=balance, chunks=chunks, schedule="1f1b",
            engine="compiled", overlap=overlap,
        ))
        st: dict = {}
        p1, _, _ = pipes[name].train_step(
            params0, opt.init(params0), plan, rng0, opt, stats=st
        )
        stats[name] = st
        diffs[name] = max(
            float(abs(a - b).max()) for a, b in zip(
                jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p1)
            )
        )
        states[name] = [params0, opt.init(params0), jax.random.PRNGKey(0)]
        times[name] = []

    # interleaved measurement, median with the warm-up step dropped
    for _ in range(epochs):
        for name, pipe in pipes.items():
            params, state, key = states[name]
            key, rng = jax.random.split(key)
            t0 = time.perf_counter()
            params, state, loss = pipe.train_step(params, state, plan, rng, opt)
            jax.block_until_ready(loss)
            times[name].append(time.perf_counter() - t0)
            states[name] = [params, state, key]

    # trace one warm step per mode; the report pair (and the raw traces) is
    # the figure's overlap evidence — a fraction of ~0 on forced-host CPU is
    # itself the documented finding (see repro.core.overlap_report)
    reports = {}
    trace_root = os.path.join(json_dir, "overlap_traces") if json_dir else None
    for name, pipe in pipes.items():
        params, state, key = states[name]
        _, rng = jax.random.split(key)

        def one_step(pipe=pipe, params=params, state=state, rng=rng):
            _, _, loss = pipe.train_step(params, state, plan, rng, opt)
            jax.block_until_ready(loss)

        tdir = os.path.join(trace_root, name) if trace_root else None
        if tdir:
            os.makedirs(tdir, exist_ok=True)
        reports[name] = capture_overlap_report(one_step, trace_dir=tdir)

    if json_dir:
        os.makedirs(json_dir, exist_ok=True)
        with open(os.path.join(json_dir, "overlap_report.json"), "w") as f:
            json.dump(
                {"dataset": dataset, "chunks": chunks, "schedule": "1f1b",
                 "balance": list(balance), "modes": reports},
                f, indent=2, sort_keys=True,
            )
            f.write("\n")

    tol = 5e-4  # engine-cross tolerance (fused host vs scheduled float order)
    rows = []
    for name in modes:
        step_s = statistics.median(times[name][1:])
        ticks = int(stats[name].get("num_ticks", 0))
        emit(
            f"fig3/{dataset}/overlap_{name}_chunks{chunks}",
            step_s * 1e6,
            f"max_update_diff={diffs[name]:.2e};num_ticks={ticks};"
            f"overlap_fraction={reports[name]['overlap_fraction']:.3f}",
        )
        bench["rows"][f"overlap/{name}/chunks{chunks}"] = {
            "step_s": step_s,
            "num_ticks": ticks,
            "wire_latency": int(stats[name].get("wire_latency", 0)),
            "max_update_diff": diffs[name],
            "updates_match": diffs[name] <= tol,
            "overlap_fraction": reports[name]["overlap_fraction"],
        }
        rows.append((f"overlap/{name}", chunks, step_s, plan.rebuild_seconds))
    return rows


def _auto_bench(bench, *, epochs, chunks=4, dataset="cora", json_dir=None):
    """The ``--auto`` planner's pick vs hand-picked configs on the
    deliberately imbalanced GCN stack (the partitioner's fixture — the
    stack where config choice actually matters).

    A small set of representative hand-picked configs (uniform and profiled
    balances under fill-drain / 1F1B / zb-h1, all at the paper's 4-chunk
    operating point) is measured INTERLEAVED with the planner's pick —
    machine drift hits every config equally, medians with the warm-up step
    dropped, same discipline as ``_partition_bench``. Rows land in the
    BENCH json as ``auto/hand/{name}/chunksC`` plus the stable-keyed
    ``auto/pick``; the perf gate (``check_perf``) requires the pick's
    measured step to be within threshold of the BEST measured hand-picked
    config (the planner must not pick badly) and bounds the pick's
    predicted/measured ratio against the baseline's same ratio (the
    prediction layer must not drift — the ratio is machine-relative, since
    on forced-host CPU the unmodeled per-tick dispatch dominates the
    absolute step time). The planner's profile lands in the shared
    ``layer_costs_cache.json`` sidecar, so the sweep costs one profile per
    (model, chunk shape)."""
    from repro.core.autotune import PlanConstraints, plan_pipeline
    from repro.core.costmodel import (
        cached_profile_layer_costs,
        choose_balance,
        predicted_balance_time,
        uniform_balance,
    )
    from repro.core.pipeline import GPipeConfig, make_engine
    from repro.core.schedule import get_schedule
    from repro.models.gnn.net import build_imbalanced_gcn
    from repro.train import optimizer as opt_lib

    g = load_dataset(dataset)
    model = build_imbalanced_gcn(g.num_features, g.num_classes)
    stages = 4
    cache_path = os.path.join(json_dir, "layer_costs_cache.json") if json_dir else None
    plan = make_plan(g, chunks, strategy="sequential")
    chunk0 = jax.tree_util.tree_map(lambda a: a[0], plan.stacked().graph)
    params0 = model.init_params(jax.random.PRNGKey(0))
    costs = cached_profile_layer_costs(model, params0, chunk0, cache_path=cache_path)

    uniform = uniform_balance(len(model.layers), stages)
    hand = {
        "fill_drain_uniform": ("fill_drain", uniform),
        "1f1b_uniform": ("1f1b", uniform),
        "1f1b_profiled": (
            "1f1b", choose_balance(costs, stages, get_schedule("1f1b"), chunks)[0],
        ),
        "zb-h1_profiled": (
            "zb-h1", choose_balance(costs, stages, get_schedule("zb-h1"), chunks)[0],
        ),
    }

    # the planner resolves schedule x chunks x balance over the full search
    # space (rotations off: predicted time is placement-invariant, so the
    # axis only pads the table here); each candidate chunk count's profile
    # comes from the same sidecar cache
    auto_plan = plan_pipeline(
        model, g,
        PlanConstraints(num_stages=stages, chunk_counts=(2, chunks),
                        rotations=False),
        params=params0, cache_path=cache_path,
    )
    plans = {name: plan for name in hand}
    plans["pick"] = (
        plan if auto_plan.chunks == chunks
        else make_plan(g, auto_plan.chunks, strategy="sequential")
    )

    opt = opt_lib.adam(1e-2)
    pipes, states, times = {}, {}, {}
    for name, (schedule, balance) in hand.items():
        pipes[name] = make_engine(model, GPipeConfig(engine="compiled",
            balance=balance, chunks=chunks, schedule=schedule,
        ))
    pipes["pick"] = make_engine(model, auto_plan)
    for name, pipe in pipes.items():
        params = pipe.init_params(jax.random.PRNGKey(0))
        states[name] = [params, opt.init(params), jax.random.PRNGKey(0)]
        times[name] = []
    for _ in range(epochs):
        for name, pipe in pipes.items():
            params, state, key = states[name]
            key, rng = jax.random.split(key)
            t0 = time.perf_counter()
            params, state, loss = pipe.train_step(params, state, plans[name], rng, opt)
            jax.block_until_ready(loss)
            times[name].append(time.perf_counter() - t0)
            states[name] = [params, state, key]

    rows = []
    for name, (schedule, balance) in hand.items():
        step_s = statistics.median(times[name][1:])
        predicted = predicted_balance_time(
            costs, balance, get_schedule(schedule), chunks
        )
        emit(
            f"fig3/{dataset}/auto_hand_{name}_chunks{chunks}",
            step_s * 1e6,
            f"schedule={schedule};balance={'-'.join(map(str, balance))};"
            f"predicted_s={predicted:.4f}",
        )
        bench["rows"][f"auto/hand/{name}/chunks{chunks}"] = {
            "step_s": step_s,
            "schedule": schedule,
            "balance": list(balance),
            "predicted_step_s": predicted,
        }
        rows.append((f"auto/hand/{name}", chunks, step_s, plan.rebuild_seconds))
    pick_s = statistics.median(times["pick"][1:])
    emit(
        f"fig3/{dataset}/auto_pick",
        pick_s * 1e6,
        f"schedule={auto_plan.schedule};chunks={auto_plan.chunks};"
        f"balance={'-'.join(map(str, auto_plan.balance))};"
        f"predicted_s={auto_plan.predicted_step_s:.4f};"
        f"evaluated={auto_plan.evaluated}",
    )
    # stable key on purpose (no chunk suffix): the pick's chunk count is the
    # planner's to choose, and a changed pick must not read as a coverage
    # regression — the payload carries the resolved config
    bench["rows"]["auto/pick"] = {
        "step_s": pick_s,
        "schedule": auto_plan.schedule,
        "chunks": auto_plan.chunks,
        "balance": list(auto_plan.balance),
        "predicted_step_s": auto_plan.predicted_step_s,
        "evaluated": auto_plan.evaluated,
    }
    rows.append(("auto/pick", auto_plan.chunks, pick_s, plan.rebuild_seconds))
    return rows


def main_auto() -> None:
    """Standalone auto-cell entry for CI's bench-smoke: run only the
    ``auto/*`` rows (planner pick vs hand-picked configs) and write them as
    ``BENCH_fig3_auto.json`` plus the profile sidecar — uploaded artifacts,
    not the gate baseline (the perf-gate job regenerates the full table)."""
    import argparse

    ap = argparse.ArgumentParser(description="fig3 planner (auto) cells only")
    ap.add_argument("--auto-cell", action="store_true",
                    help="marker flag selecting this entry from __main__")
    ap.add_argument("--chunks", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--json-out", default=None)
    a = ap.parse_args()
    bench = {"dataset": a.dataset, "epochs": a.epochs, "rows": {}}
    _auto_bench(bench, epochs=a.epochs, chunks=a.chunks,
                dataset=a.dataset, json_dir=a.json_out)
    if a.json_out:
        os.makedirs(a.json_out, exist_ok=True)
        path = os.path.join(a.json_out, "BENCH_fig3_auto.json")
        with open(path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


def main_overlap() -> None:
    """Standalone overlap-cell entry for CI's bench-smoke: run only the
    ``overlap/*`` pair and write ``BENCH_fig3_overlap.json`` plus
    ``overlap_report.json`` and the raw profiler traces — uploaded
    artifacts, not the gate baseline (the perf-gate job regenerates the
    full table)."""
    import argparse

    ap = argparse.ArgumentParser(description="fig3 overlap cells only")
    ap.add_argument("--overlap-cell", action="store_true",
                    help="marker flag selecting this entry from __main__")
    ap.add_argument("--chunks", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--json-out", default=None)
    a = ap.parse_args()
    bench = {"dataset": a.dataset, "epochs": a.epochs, "rows": {}}
    _overlap_bench(bench, epochs=a.epochs, chunks=a.chunks,
                   dataset=a.dataset, json_dir=a.json_out)
    if a.json_out:
        os.makedirs(a.json_out, exist_ok=True)
        path = os.path.join(a.json_out, "BENCH_fig3_overlap.json")
        with open(path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


def main_scale() -> None:
    """Standalone streamed-cell entry for CI's bench-smoke: run only the
    ``scale/*`` rows (one or a few mid-size streamed-generator cells) and
    write them as ``BENCH_fig3_scale.json`` — an uploaded artifact, not the
    gate baseline (the perf-gate job regenerates the full table)."""
    import argparse

    ap = argparse.ArgumentParser(description="fig3 streamed graph-scaling cells only")
    ap.add_argument("--scale-sizes", default="100000",
                    help="comma list of streamed node counts (default: one 1e5 cell)")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--dataset", default="powerlaw-64k")
    ap.add_argument("--json-out", default=None)
    a = ap.parse_args()
    sizes = tuple(int(s) for s in a.scale_sizes.split(","))
    bench = {"dataset": a.dataset, "epochs": a.epochs, "rows": {}}
    _scale_bench(bench, epochs=a.epochs, sizes=sizes, dataset=a.dataset)
    if a.json_out:
        os.makedirs(a.json_out, exist_ok=True)
        path = os.path.join(a.json_out, "BENCH_fig3_scale.json")
        with open(path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    import sys

    if "--overlap-cell" in sys.argv:
        main_overlap()
    elif "--auto-cell" in sys.argv:
        main_auto()
    else:
        main_scale()
