"""Fig 3 analogue — training time growth with micro-batch count.

The paper's slowdown comes from per-chunk sub-graph rebuilds; we report
epoch time AND the isolated rebuild cost so the overhead source is explicit.

Beyond-paper: every chunk count runs the full engine × schedule matrix —
host (fill-drain / 1F1B / interleaved / zb-h1 where legal) and compiled,
where fill-drain runs the fused scan and 1F1B/interleaved/zb-h1 run the
scheduled executor (``spmd_pipeline_scheduled``) inside the same jitted
program (zb-h1 splits every backward into B/W halves and fills the drain
bubble with deferred weight-grad work — its win needs concurrent ticks, so
the CI perf gate measures this table under 4 forced host devices). Each
row carries the schedule's bubble fraction and peak live activations
(measured on the host engine, static stash accounting on the scheduled
compiled path) next to the epoch time; ``compiled_vs_host`` reports the
speedup against the host fill-drain baseline of the same chunk count.

``json_path`` writes the whole table as machine-readable ``BENCH_fig3.json``
— the artifact the CI perf-regression gate (``benchmarks/check_perf.py``)
diffs against the committed baseline.
"""

from __future__ import annotations

import json
import types

from benchmarks.common import emit
from repro.core.microbatch import make_plan
from repro.graphs import load_dataset
from repro.launch.train import run_gnn

SCHEDULES = ("fill_drain", "1f1b", "interleaved", "zb-h1")
ENGINES = ("host", "compiled")


def run(*, dataset="cora", epochs=30, max_chunks=4, schedules=SCHEDULES, json_path=None):
    g = load_dataset(dataset)
    rows = []
    stages, pipe_devices = 4, 2
    bench = {
        "dataset": dataset,
        "stages": stages,
        "pipe_devices": pipe_devices,
        "epochs": epochs,
        "rows": {},
    }
    for chunks in range(1, max_chunks + 1):
        plan = make_plan(g, chunks, strategy="sequential")
        host_epoch_s = None
        for engine in ENGINES:
            for schedule in schedules:
                args = types.SimpleNamespace(
                    mode="gnn", dataset=dataset, backend="padded", strategy="sequential",
                    stages=stages, chunks=chunks, epochs=epochs, seed=0, log_every=0,
                    schedule=schedule, pipe_devices=pipe_devices, engine=engine,
                )
                try:
                    r = run_gnn(args)
                except ValueError:
                    continue  # schedule rejects this (stages, chunks) combo
                if engine == "host" and schedule == "fill_drain":
                    host_epoch_s = r["avg_epoch_s"]
                name = (
                    f"{schedule}_chunks{chunks}" if engine == "host"
                    else f"compiled_{schedule}_chunks{chunks}"
                )
                derived = (
                    f"rebuild_s={plan.rebuild_seconds:.3f};edge_cut={plan.edge_cut:.3f};"
                    f"bubble={r['bubble_fraction']:.3f};"
                    f"peak_live={r['peak_live_activations']}"
                )
                if engine == "compiled" and host_epoch_s:
                    derived += f";compiled_vs_host={host_epoch_s / r['avg_epoch_s']:.2f}x"
                emit(f"fig3/{dataset}/{name}", r["avg_epoch_s"] * 1e6, derived)
                bench["rows"][f"{engine}/{schedule}/chunks{chunks}"] = {
                    "step_s": r["avg_epoch_s"],
                    "bubble": r["bubble_fraction"],
                    "peak_live": r["peak_live_activations"],
                    "peak_live_accounted": r["peak_live_accounted"],
                    "rebuild_s": plan.rebuild_seconds,
                }
                rows.append((f"{engine}/{schedule}", chunks, r["avg_epoch_s"], plan.rebuild_seconds))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows
