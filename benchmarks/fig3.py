"""Fig 3 analogue — training time growth with micro-batch count.

The paper's slowdown comes from per-chunk sub-graph rebuilds; we report
epoch time AND the isolated rebuild cost so the overhead source is explicit.

Beyond-paper: every chunk count also runs under each pipeline schedule
(fill-drain / 1F1B / interleaved where legal), emitting the schedule's
bubble fraction and measured peak live activations next to the epoch time —
the schedule-comparison columns for the ROADMAP's speed axis. The
``compiled`` rows rerun fill-drain on the compiled SPMD engine (one jitted
program instead of the host queue loop) so engine regressions show up in
the same perf table; ``compiled_vs_host`` reports the speedup directly.
"""

from __future__ import annotations

import types

from benchmarks.common import emit
from repro.core.microbatch import make_plan
from repro.graphs import load_dataset
from repro.launch.train import run_gnn

SCHEDULES = ("fill_drain", "1f1b", "interleaved")


def run(*, dataset="cora", epochs=30, max_chunks=4, schedules=SCHEDULES):
    g = load_dataset(dataset)
    rows = []
    stages, pipe_devices = 4, 2
    for chunks in range(1, max_chunks + 1):
        plan = make_plan(g, chunks, strategy="sequential")
        host_epoch_s = None
        for schedule in schedules:
            args = types.SimpleNamespace(
                mode="gnn", dataset=dataset, backend="padded", strategy="sequential",
                stages=stages, chunks=chunks, epochs=epochs, seed=0, log_every=0,
                schedule=schedule, pipe_devices=pipe_devices, engine="host",
            )
            try:
                r = run_gnn(args)
            except ValueError:
                continue  # schedule rejects this (stages, chunks) combo
            if schedule == "fill_drain":
                host_epoch_s = r["avg_epoch_s"]
            emit(
                f"fig3/{dataset}/{schedule}_chunks{chunks}",
                r["avg_epoch_s"] * 1e6,
                f"rebuild_s={plan.rebuild_seconds:.3f};edge_cut={plan.edge_cut:.3f};"
                f"bubble={r['bubble_fraction']:.3f};"
                f"peak_live={r['peak_live_activations']}",
            )
            rows.append((schedule, chunks, r["avg_epoch_s"], plan.rebuild_seconds))
        # compiled-engine smoke: same plan/seed, fill-drain, one fused program
        args = types.SimpleNamespace(
            mode="gnn", dataset=dataset, backend="padded", strategy="sequential",
            stages=stages, chunks=chunks, epochs=epochs, seed=0, log_every=0,
            schedule="fill_drain", pipe_devices=None, engine="compiled",
        )
        r = run_gnn(args)
        speedup = host_epoch_s / r["avg_epoch_s"] if host_epoch_s else float("nan")
        emit(
            f"fig3/{dataset}/compiled_chunks{chunks}",
            r["avg_epoch_s"] * 1e6,
            f"rebuild_s={plan.rebuild_seconds:.3f};edge_cut={plan.edge_cut:.3f};"
            f"compiled_vs_host={speedup:.2f}x",
        )
        rows.append(("compiled", chunks, r["avg_epoch_s"], plan.rebuild_seconds))
    return rows
