"""Fig 3 analogue — training time growth with micro-batch count.

The paper's slowdown comes from per-chunk sub-graph rebuilds; we report
epoch time AND the isolated rebuild cost so the overhead source is explicit.
"""

from __future__ import annotations

import types

from benchmarks.common import emit
from repro.core.microbatch import make_plan
from repro.graphs import load_dataset
from repro.launch.train import run_gnn


def run(*, dataset="cora", epochs=30, max_chunks=4):
    g = load_dataset(dataset)
    rows = []
    for chunks in range(1, max_chunks + 1):
        plan = make_plan(g, chunks, strategy="sequential")
        args = types.SimpleNamespace(
            mode="gnn", dataset=dataset, backend="padded", strategy="sequential",
            stages=4, chunks=chunks, epochs=epochs, seed=0, log_every=0,
        )
        r = run_gnn(args)
        emit(
            f"fig3/{dataset}/chunks{chunks}",
            r["avg_epoch_s"] * 1e6,
            f"rebuild_s={plan.rebuild_seconds:.3f};edge_cut={plan.edge_cut:.3f}",
        )
        rows.append((chunks, r["avg_epoch_s"], plan.rebuild_seconds))
    return rows
