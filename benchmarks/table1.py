"""Table 1 analogue — single-device benchmarks across aggregation backends.

The paper compares two graph frameworks (DGL vs PyG) on the same GAT model;
our analogue compares this framework's aggregation backends on identical
math: ``padded`` (TPU-native gather layout), ``dense`` (masked adjacency
matmul), and ``pallas`` (fused kernel, interpret mode on CPU). Reports
average epoch time and test accuracy per (backend × dataset).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.graphs import load_dataset
from repro.models.gnn.net import build_paper_gat
from repro.train.loop import train


def run(*, datasets=("cora", "citeseer"), backends=("padded", "dense", "pallas"), epochs=60):
    rows = []
    for ds in datasets:
        g = load_dataset(ds)
        for backend in backends:
            if backend == "dense" and g.num_nodes > 5000:
                continue  # dense adjacency would not fit; paper hit the same wall
            # the fused pallas attention kernel has no in-kernel dropout
            # path and refuses a nonzero rate up-front, so its column runs
            # the attn_dropout=0 variant (flagged in the derived field)
            attn_dropout = 0.0 if backend == "pallas" else 0.6
            m = build_paper_gat(
                g.num_features, g.num_classes,
                backend=backend, attn_dropout=attn_dropout,
            )
            res = train(m, g, epochs=epochs)
            emit(
                f"table1/{ds}/{backend}",
                res.avg_epoch_s * 1e6,
                f"test_acc={res.test_acc:.3f};first_epoch_s={res.first_epoch_s:.2f}"
                f";attn_dropout={attn_dropout:g}",
            )
            rows.append((ds, backend, res.avg_epoch_s, res.test_acc))
    return rows
