"""Shared benchmark utilities.

Also the benchmarks' import point for the unified pipeline CLI surface:
``add_pipeline_args`` / ``PipelineCLIConfig`` live in ``repro.core.cli``
(importable by launch drivers and examples, which can't see the
``benchmarks`` package) and are re-exported here so the benchmark scripts
keep a single local import for their flag handling.
"""

from __future__ import annotations

import time

import jax

from repro.core.cli import PipelineCLIConfig, add_pipeline_args  # noqa: F401


def timed(fn, *args, iters: int = 5, warmup: int = 1):
    """Wall-time per call in microseconds (median-ish: mean of post-warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
