"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax


def timed(fn, *args, iters: int = 5, warmup: int = 1):
    """Wall-time per call in microseconds (median-ish: mean of post-warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
