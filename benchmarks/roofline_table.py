"""§Roofline table — prints the per-(arch × shape) roofline terms recorded
by the dry-run sweep (reports/dryrun/*.json)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def run(*, reports_dir: str = "reports/dryrun", mesh: str = "16x16"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(reports_dir, f"*__{mesh}.json"))):
        r = json.load(open(fn))
        rf = r["roofline"]
        emit(
            f"roofline/{r['arch']}/{r['shape']}",
            rf["bound_s"] * 1e6,
            f"dominant={rf['dominant']};compute_s={rf['compute_s']:.4f};"
            f"memory_s={rf['memory_s']:.4f};collective_s={rf['collective_s']:.4f};"
            f"useful={rf['useful_flops_ratio']:.3f};"
            f"peak_gib={r['memory']['peak_estimate_gib']}",
        )
        rows.append(r)
    if not rows:
        emit("roofline/none", 0.0, f"no reports under {reports_dir} — run dryrun first")
    return rows
