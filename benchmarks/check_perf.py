"""CI perf-regression gate over the fig3 engine × schedule table.

Compares a freshly produced ``BENCH_fig3.json`` (``python -m benchmarks.run
--only fig3 --json-out DIR``) against the committed baseline
(``benchmarks/BENCH_fig3.json``) and exits non-zero if the compiled engine
regressed:

  * **speed** — by default each compiled row's step time is NORMALIZED by the
    same run's host fill-drain step time at the same chunk count, so the
    gate compares machine-independent ratios: a compiled/host ratio more
    than ``--threshold`` (default 1.20, i.e. >20%) above the baseline's
    ratio fails. ``--absolute`` compares raw seconds instead (only
    meaningful when baseline and current ran on identical hardware). Row
    step times are per-epoch MEDIANS (fig3 writes them that way): on
    shared CI-class hosts a few scheduler hiccups inflate a mean 2-3x,
    which is noise, not regression;
  * **coverage** — every compiled row present in the baseline must exist in
    the current table (a silently vanished row is a regression too);
  * **memory** — the scheduled executor's 1F1B peak live activations must
    stay strictly below the fill-drain compiled accounting at every chunk
    count >= 4 (the schedule-aware engine's headline memory invariant; this
    check is deterministic, not timing-based);
  * **partition** — the profiled (cost-model) partitioner's compiled step
    time on the deliberately imbalanced GCN stack must beat the
    layer-count-uniform split's in the same run (``partition/*`` rows; the
    comparison is run-internal, like the zero-bubble gate, so machine speed
    cancels). Missing or zero host fill-drain normalizer rows fail with a
    named-row error instead of silently shrinking the comparison set;
  * **sparse** — the degree-bucketed pallas backend's compiled step on the
    power-law fixture must beat the padded layout's STRICTLY in the same
    run (``sparse/{padded|bucketed}/chunksC`` rows — run-internal, so
    machine speed cancels), and BOTH rows must report ``updates_match``:
    fig3 asserts each measured config's one-step update against a host
    fill-drain padded reference at oracle tolerance, so a layout that got
    fast by computing something else fails here, not in prod;
  * **scale** — the streamed-graph growth rows (``scale/nN/chunksC``, from
    fig3's ``_scale_bench``): every row's one-step update must have matched
    the host fill-drain oracle in the same run that was timed
    (``updates_match``), and the run-internal growth ratio
    step(n)/step(n_min) must stay within ``--threshold`` of the baseline's
    ratio — the sizes are stepped interleaved, so machine speed cancels and
    the ratio isolates how step time grows with the graph;
  * **overlap** — the double-buffered wire rows (``overlap/*`` from fig3's
    ``_overlap_bench``): both modes' one-step updates must have matched the
    host fill-drain oracle in the same run (``updates_match`` — the
    retiming is bit-identical dataflow), and the speed rule is
    PLATFORM-CONDITIONAL on the row's traced ``overlap_fraction``. When the
    profiler shows the runtime actually hid collectives under same-device
    compute (fraction > 0.05), the double-buffered step must beat/match the
    serialized step within ``--threshold``. When the fraction is ~0 — CI's
    forced-host CPU rings are lockstep single-threaded executors where no
    schedule can hide a collective, and wire latency 2 adds ticks by
    construction — the gate bounds the retimed program's per-TICK cost
    instead (``step_s/num_ticks`` double-buffer <= serialized), i.e. the
    step-time cost must stay below the statically-accounted tick inflation;
  * **auto** — the ``--auto`` planner rows (``auto/pick`` + ``auto/hand/*``
    from fig3's ``_auto_bench``): the pick's measured step must be within
    ``--threshold`` of the BEST measured hand-picked config in the same
    interleaved run (``auto-pick``, run-internal so machine speed cancels),
    and its predicted step time must stay within ``--auto-pred-ratio`` of
    the measurement in either direction (``auto-prediction`` — loose, since
    forced-host per-tick dispatch is unmodeled, but it catches a broken
    cost model). Both rules fail by name;
  * **zero-bubble** — at every chunk count >= 4 the compiled zb-h1 row must
    beat or match the same run's compiled 1F1B step time (within the same
    ``--threshold`` slack the speed gate uses), its bubble fraction must sit
    strictly below 1F1B's, and its peak-live accounting must not exceed
    1F1B's (the last two are deterministic). zb-h1's step-time win comes
    from filling the drain bubble with deferred weight-grad (W) work, which
    needs ticks to actually run concurrently — so produce the table under
    forced host devices (the CI gate uses 4; see below), not on the serial
    lane substrate where a drained bubble saves nothing.

The gate also covers the **serving** table (``BENCH_serve.json``, produced
by ``repro.launch.serve_gnn --json-out``): pass ``--serving-current`` to
check it against the committed ``benchmarks/BENCH_serve.json``. Every
baseline serving row must be present (fail-by-name, like the fig3 coverage
rule), report a positive achieved throughput, and keep its p99 latency —
normalized by the same run's warm single-batch eval call time, so machine
speed cancels exactly like the host-normalized fig3 ratios — within
``--serving-threshold`` of the baseline's normalized p99.

And the **kernel microbench** table (``BENCH_kernels.json``, produced by
``benchmarks.run --only kernels --json-out``): pass ``--kernels-current``
to check the padded-vs-degree-bucketed aggregation op rows — coverage,
output agreement, a strict run-internal bucketed win, and the
bucketed/padded time ratio vs the committed baseline (see
``check_kernels``).

Intentional regressions (e.g. trading speed for a feature) are overridden by
applying the ``perf-regression-ok`` label to the PR — the CI job skips the
gate when the label is present — and committing a refreshed baseline.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m benchmarks.run --fast --only fig3 --json-out /tmp/bench
    python -m benchmarks.check_perf --current /tmp/bench/BENCH_fig3.json

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m repro.launch.serve_gnn --qps 50 --duration 5 --json-out /tmp/serve
    python -m benchmarks.check_perf --serving-current /tmp/serve/BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).resolve().parent / "BENCH_fig3.json"
DEFAULT_SERVING_BASELINE = Path(__file__).resolve().parent / "BENCH_serve.json"
DEFAULT_KERNELS_BASELINE = Path(__file__).resolve().parent / "BENCH_kernels.json"


def _chunks_of(key: str) -> int:
    return int(key.rsplit("chunks", 1)[1])


def normalized_ratios(rows: dict) -> tuple[dict[str, float], list[str]]:
    """compiled-row step time / same-run host fill-drain step time.

    Returns (ratios, problems): a compiled row whose host fill-drain
    normalizer is missing or has a non-positive step time is reported in
    ``problems`` by NAME — it must become a gate failure, not a silent drop
    (a table with a broken normalizer used to shrink the comparison set
    quietly; a key missing from the BASELINE side was never reported at
    all, and a zero step time would otherwise be a division crash or an
    infinite ratio depending on which side it landed)."""
    out: dict[str, float] = {}
    problems: list[str] = []
    for key, row in sorted(rows.items()):
        if not key.startswith("compiled/"):
            continue
        host_key = f"host/fill_drain/chunks{_chunks_of(key)}"
        host = rows.get(host_key)
        if host is None:
            problems.append(f"{key}: normalizer row {host_key} is missing")
        elif not host["step_s"] > 0:
            problems.append(
                f"{key}: normalizer row {host_key} has non-positive "
                f"step_s {host['step_s']!r}"
            )
        else:
            out[key] = row["step_s"] / host["step_s"]
    return out, problems


def check(baseline: dict, current: dict, *, threshold: float, absolute: bool,
          auto_pred_ratio: float = 25.0) -> list[str]:
    failures: list[str] = []
    b_rows, c_rows = baseline["rows"], current["rows"]

    for key in sorted(b_rows):
        if key.startswith(
            ("compiled/", "partition/", "sparse/", "scale/", "overlap/", "auto/")
        ) and key not in c_rows:
            failures.append(f"coverage: baseline row {key} missing from current run")

    if absolute:
        pairs = {
            k: (b_rows[k]["step_s"], c_rows[k]["step_s"])
            for k in b_rows
            if k.startswith("compiled/") and k in c_rows
        }
    else:
        nb, b_problems = normalized_ratios(b_rows)
        nc, c_problems = normalized_ratios(c_rows)
        failures.extend(f"normalizer(baseline): {p}" for p in b_problems)
        failures.extend(f"normalizer(current): {p}" for p in c_problems)
        pairs = {k: (nb[k], nc[k]) for k in nb if k in nc}
        # every baseline comparison must still be computable: a current run
        # missing the host fill-drain normalizer (or the compiled row) for a
        # baseline key would otherwise shrink the comparison set silently —
        # in the limit to zero pairs, turning the gate into a no-op pass
        for k in sorted(set(nb) - set(nc)):
            failures.append(
                f"coverage: cannot compare {k} — its row or its host "
                f"fill_drain normalizer is missing from the current run"
            )
    if not pairs:
        failures.append("coverage: no comparable compiled rows between baseline and current")

    unit = "s" if absolute else "x host"
    for key in sorted(pairs):
        base, cur = pairs[key]
        status = "ok"
        if cur > base * threshold:
            status = f"REGRESSED >{(threshold - 1):.0%}"
            failures.append(
                f"perf: {key} {cur:.4f}{unit} vs baseline {base:.4f}{unit} "
                f"(allowed {base * threshold:.4f})"
            )
        print(f"  {key:40s} baseline {base:8.4f}{unit}  current {cur:8.4f}{unit}  {status}")

    # memory invariant: scheduled 1F1B strictly below fill-drain accounting
    for key, row in sorted(c_rows.items()):
        if not key.startswith("compiled/1f1b/"):
            continue
        chunks = _chunks_of(key)
        if chunks < 4:
            continue
        fd = c_rows.get(f"compiled/fill_drain/chunks{chunks}")
        peak = row.get("peak_live")
        fd_peak = fd and fd.get("peak_live_accounted")
        if peak is None or fd_peak is None:
            failures.append(f"memory: {key} peak-live accounting missing")
        elif not peak < fd_peak:
            failures.append(
                f"memory: {key} peak_live {peak} not strictly below "
                f"fill-drain accounting {fd_peak}"
            )

    # zero-bubble invariants: at chunks >= 4 compiled zb-h1 must beat or
    # match compiled 1F1B's step time (same run, same threshold slack as the
    # speed gate), undercut its bubble strictly, and not exceed its
    # peak-live accounting
    for key, row in sorted(c_rows.items()):
        if not key.startswith("compiled/zb-h1/"):
            continue
        chunks = _chunks_of(key)
        if chunks < 4:
            continue
        ob = c_rows.get(f"compiled/1f1b/chunks{chunks}")
        if ob is None:
            failures.append(f"zero-bubble: {key} has no compiled 1f1b row to compare")
            continue
        if row["step_s"] > ob["step_s"] * threshold:
            failures.append(
                f"zero-bubble: {key} step {row['step_s']:.4f}s does not beat/"
                f"match 1f1b {ob['step_s']:.4f}s (allowed "
                f"{ob['step_s'] * threshold:.4f})"
            )
        if not row["bubble"] < ob["bubble"]:
            failures.append(
                f"zero-bubble: {key} bubble {row['bubble']:.3f} not strictly "
                f"below 1f1b's {ob['bubble']:.3f}"
            )
        peak, ob_peak = row.get("peak_live"), ob.get("peak_live")
        if peak is None or ob_peak is None:
            failures.append(f"zero-bubble: {key} peak-live accounting missing")
        elif peak > ob_peak:
            failures.append(
                f"zero-bubble: {key} peak_live {peak} exceeds 1f1b's {ob_peak}"
            )

    # partition gate: on the deliberately imbalanced stack the profiled
    # partitioner's measured compiled step must beat the layer-count-uniform
    # split (same run, deterministic comparison — the partitioner's whole
    # claim is that cost-aware boundaries shorten the slowest stage's tick)
    for key, row in sorted(c_rows.items()):
        if not key.startswith("partition/profiled/"):
            continue
        uni = c_rows.get(f"partition/uniform/chunks{_chunks_of(key)}")
        if uni is None:
            failures.append(f"partition: {key} has no uniform row to compare")
            continue
        if not row["step_s"] < uni["step_s"]:
            failures.append(
                f"partition: {key} step {row['step_s']:.4f}s does not beat "
                f"the uniform split's {uni['step_s']:.4f}s "
                f"(balance {row.get('balance')} vs {uni.get('balance')})"
            )

    # sparse gate: the degree-bucketed pallas backend must beat the padded
    # layout strictly in the same run, and both measured configs' one-step
    # updates must have matched the host fill-drain padded reference at
    # oracle tolerance (fig3 computes updates_match in the SAME run it
    # times, so speed can never be bought with wrong math unnoticed)
    for key, row in sorted(c_rows.items()):
        if not key.startswith("sparse/bucketed/"):
            continue
        pad = c_rows.get(f"sparse/padded/chunks{_chunks_of(key)}")
        if pad is None:
            failures.append(f"sparse: {key} has no padded row to compare")
            continue
        if not row["step_s"] < pad["step_s"]:
            failures.append(
                f"sparse: {key} step {row['step_s']:.4f}s not strictly below "
                f"the padded layout's {pad['step_s']:.4f}s"
            )
        for name, r in (("bucketed", row), ("padded", pad)):
            if not r.get("updates_match"):
                failures.append(
                    f"sparse: {key.rsplit('/', 2)[0]}/{name} update diverged from "
                    f"the host fill-drain reference "
                    f"(max_update_diff={r.get('max_update_diff')!r})"
                )

    # scale gate: the streamed-graph growth rows (``scale/nN/chunksC``).
    # Every current row's one-step update must have matched the host
    # fill-drain oracle in the SAME run fig3 timed (updates_match), and the
    # run-internal growth ratio step(n)/step(n_min) must stay within
    # ``threshold`` of the baseline's same ratio — fig3 steps all sizes
    # interleaved, so machine speed cancels out of the ratio entirely and
    # what remains is genuinely how step time grows with the graph
    c_scale = {row["nodes"]: (key, row)
               for key, row in c_rows.items() if key.startswith("scale/")}
    b_scale = {row["nodes"]: (key, row)
               for key, row in b_rows.items() if key.startswith("scale/")}
    for n, (key, row) in sorted(c_scale.items()):
        if not row.get("updates_match"):
            failures.append(
                f"scale: {key} update diverged from the host fill-drain "
                f"oracle (max_update_diff={row.get('max_update_diff')!r})"
            )
    if b_scale and c_scale:
        bmin, cmin = min(b_scale), min(c_scale)
        b0, c0 = b_scale[bmin][1]["step_s"], c_scale[cmin][1]["step_s"]
        if not (b0 > 0 and c0 > 0):
            failures.append(
                f"scale: non-positive anchor step_s (baseline n{bmin}: {b0!r}, "
                f"current n{cmin}: {c0!r})"
            )
        else:
            for n in sorted((set(b_scale) & set(c_scale)) - {bmin, cmin}):
                base = b_scale[n][1]["step_s"] / b0
                cur = c_scale[n][1]["step_s"] / c0
                status = "ok"
                if cur > base * threshold:
                    status = f"REGRESSED >{(threshold - 1):.0%}"
                    failures.append(
                        f"scale: {c_scale[n][0]} growth ratio {cur:.3f}x vs "
                        f"baseline {base:.3f}x (allowed {base * threshold:.3f})"
                    )
                print(f"  {c_scale[n][0]:40s} baseline {base:8.3f}x-min "
                      f"current {cur:8.3f}x-min  {status}")

    # auto gate: the ``--auto`` planner rows from fig3's ``_auto_bench``.
    # Two named rules:
    #   * auto-pick — the planner's pick, measured interleaved with the
    #     hand-picked configs in the same run (machine speed cancels), must
    #     be within ``threshold`` of the BEST measured hand-picked config: a
    #     planner that picks badly is a regression even when every engine
    #     got faster;
    #   * auto-prediction — the pick's predicted step time must stay within
    #     ``auto_pred_ratio`` of its measurement (either direction). The
    #     bound is deliberately loose: on forced-host CPU the unmodeled
    #     per-tick dispatch dominates absolute step time (the partition
    #     rows show the same gap), but a prediction off by the full ratio
    #     cap means the cost model broke, not that the machine drifted.
    pick = c_rows.get("auto/pick")
    hands = {k: v for k, v in c_rows.items() if k.startswith("auto/hand/")}
    if pick is None:
        if "auto/pick" in b_rows:
            failures.append("auto-pick: baseline has auto/pick but the "
                            "current run produced none")
    else:
        if not hands:
            failures.append(
                "auto-pick: auto/pick present but no auto/hand/* rows to "
                "compare the pick against"
            )
        else:
            best_key = min(hands, key=lambda k: hands[k]["step_s"])
            best = hands[best_key]["step_s"]
            status = "ok"
            if not best > 0:
                failures.append(
                    f"auto-pick: best hand row {best_key} has non-positive "
                    f"step_s {best!r}"
                )
            elif pick["step_s"] > best * threshold:
                status = "REGRESSED"
                failures.append(
                    f"auto-pick: planner pick ({pick.get('schedule')}/"
                    f"chunks{pick.get('chunks')}, {pick['step_s']:.4f}s) not "
                    f"within {threshold:.2f}x of best hand-picked {best_key} "
                    f"({best:.4f}s)"
                )
            if best > 0:
                print(f"  {'auto/pick':40s} vs best hand ({best_key}) "
                      f"{pick['step_s'] / best:8.3f}x  {status}")
        pred, meas = pick.get("predicted_step_s"), pick["step_s"]
        if not (pred and pred > 0 and meas > 0):
            failures.append(
                f"auto-prediction: auto/pick predicted_step_s {pred!r} / "
                f"step_s {meas!r} unusable"
            )
        else:
            off = max(pred / meas, meas / pred)
            status = "ok"
            if off > auto_pred_ratio:
                status = "REGRESSED"
                failures.append(
                    f"auto-prediction: predicted {pred:.4f}s vs measured "
                    f"{meas:.4f}s — off by {off:.1f}x (allowed "
                    f"{auto_pred_ratio:.1f}x)"
                )
            print(f"  {'auto/pick':40s} predicted/measured "
                  f"{pred / meas:8.3f}x  {status}")

    # overlap gate: the double-buffered wire rows (``overlap/*`` from
    # fig3's ``_overlap_bench``). Both rows must have matched the host
    # fill-drain oracle in the SAME run that was timed (updates_match).
    # The speed rule is platform-conditional, keyed on the traced
    # ``overlap_fraction`` the row carries:
    #   * fraction > 0.05 — the runtime demonstrably hid collectives under
    #     compute, so the double-buffered STEP must beat/match the
    #     serialized step within ``threshold`` (run-internal, interleaved
    #     stepping, machine speed cancels);
    #   * fraction ~0 — a lockstep single-threaded executor (CI's
    #     forced-host CPU rings) runs every collective inline on the device
    #     thread, so NO scheduling can win wall-clock and retiming to wire
    #     latency 2 adds ticks by construction. There the gate bounds the
    #     retimed program's per-TICK cost instead:
    #     step_s/num_ticks (double-buffer) <= step_s/num_ticks (serialized)
    #     — equivalently, the retimed step's slowdown must stay below its
    #     statically-accounted tick inflation. The early-posted transfers
    #     must make ticks cheaper (slack absorbs the rendezvous wait), not
    #     dearer (e.g. the extra wire buffers thrashing cache).
    for key, row in sorted(c_rows.items()):
        if not key.startswith("overlap/double-buffer/"):
            continue
        ser_key = f"overlap/serialized/chunks{_chunks_of(key)}"
        ser = c_rows.get(ser_key)
        if ser is None:
            failures.append(f"overlap: {key} has no serialized row {ser_key} to compare")
            continue
        for name, r in (("double-buffer", row), ("serialized", ser)):
            if not r.get("updates_match"):
                failures.append(
                    f"overlap: overlap/{name} update diverged from the host "
                    f"fill-drain reference "
                    f"(max_update_diff={r.get('max_update_diff')!r})"
                )
        frac = row.get("overlap_fraction")
        if frac is None:
            failures.append(f"overlap: {key} missing overlap_fraction (overlap_report)")
            continue
        if frac > 0.05:
            status = "ok"
            if row["step_s"] > ser["step_s"] * threshold:
                status = "REGRESSED"
                failures.append(
                    f"overlap: {key} step {row['step_s'] * 1e3:.2f}ms not <= "
                    f"serialized {ser['step_s'] * 1e3:.2f}ms x{threshold} "
                    f"despite traced overlap_fraction {frac:.3f}"
                )
            print(f"  {key:40s} step vs serialized "
                  f"{row['step_s'] / ser['step_s']:8.3f}x "
                  f"(overlap {frac:.3f})  {status}")
        else:
            ticks, s_ticks = row.get("num_ticks"), ser.get("num_ticks")
            if not ticks or not s_ticks:
                failures.append(
                    f"overlap: {key} tick accounting missing "
                    f"(num_ticks={ticks!r}, serialized={s_ticks!r})"
                )
                continue
            cur, base = row["step_s"] / ticks, ser["step_s"] / s_ticks
            status = "ok"
            if cur > base:
                status = "REGRESSED"
                failures.append(
                    f"overlap: {key} per-tick step {cur * 1e3:.2f}ms "
                    f"(T={ticks}) not <= serialized {base * 1e3:.2f}ms "
                    f"(T={s_ticks}) — the double-buffered tick must absorb "
                    f"its early-posted transfers"
                )
            print(f"  {key:40s} per-tick {cur * 1e3:8.3f}ms vs serialized "
                  f"{base * 1e3:8.3f}ms (overlap {frac:.3f})  {status}")
    return failures


def check_serving(baseline: dict, current: dict, *, threshold: float) -> list[str]:
    """The serving gate over ``BENCH_serve.json`` tables.

    Rules, all fail-by-name like the fig3 gates:

      * every ``serving/`` row in the baseline must exist in the current run
        (coverage), and the current run must contain at least one;
      * each current row must report a positive ``achieved_qps`` over a
        positive query count (a zero-throughput run is a broken server, not
        a latency data point);
      * p99 latency is compared as a RATIO over the same run's warm
        single-batch ``eval_call_s`` — the machine-cancelling normalizer the
        serving driver measures at warmup — and must stay within
        ``threshold`` of the baseline's ratio. Queueing makes p99 noisier
        than a step-time median, hence the separate (looser) serving
        threshold. A missing or non-positive normalizer on either side is a
        named failure, never a silent drop."""
    failures: list[str] = []
    b_rows = {k: v for k, v in baseline.get("rows", {}).items() if k.startswith("serving/")}
    c_rows = {k: v for k, v in current.get("rows", {}).items() if k.startswith("serving/")}

    for key in sorted(b_rows):
        if key not in c_rows:
            failures.append(f"serving-coverage: baseline row {key} missing from current run")
    if not c_rows:
        failures.append("serving-coverage: current run has no serving/ rows")

    def ratio(side, key, row):
        call = row.get("eval_call_s")
        if call is None or not call > 0:
            failures.append(
                f"serving-normalizer({side}): {key} eval_call_s {call!r} "
                f"missing or non-positive"
            )
            return None
        p99 = row.get("p99_s")
        if p99 is None or not p99 > 0:
            failures.append(f"serving-normalizer({side}): {key} p99_s {p99!r} unusable")
            return None
        return p99 / call

    for key in sorted(c_rows):
        row = c_rows[key]
        if not row.get("queries", 0) > 0:
            failures.append(f"serving: {key} served no queries")
        if not row.get("achieved_qps", 0) > 0:
            failures.append(f"serving: {key} achieved_qps {row.get('achieved_qps')!r} not positive")
        cur = ratio("current", key, row)
        base_row = b_rows.get(key)
        if base_row is None:
            continue  # a NEW row has no baseline ratio yet — coverage runs above
        base = ratio("baseline", key, base_row)
        if cur is None or base is None:
            continue
        status = "ok"
        if cur > base * threshold:
            status = f"REGRESSED >{(threshold - 1):.0%}"
            failures.append(
                f"serving: {key} p99/eval_call {cur:.2f}x vs baseline "
                f"{base:.2f}x (allowed {base * threshold:.2f}x)"
            )
        print(f"  {key:40s} baseline {base:8.2f}x  current {cur:8.2f}x  {status}")
    return failures


def check_kernels(baseline: dict, current: dict, *, threshold: float) -> list[str]:
    """The kernel-microbench gate over ``BENCH_kernels.json`` tables.

    Covers the padded-vs-degree-bucketed aggregation op rows
    (``kernels/{spmm|gat}/{padded|bucketed}``, produced by
    ``benchmarks.kernels_bench`` at the skewed-fixture shapes). Rules:

      * coverage — every ``kernels/`` row in the baseline must exist in the
        current run, which must contain at least one (fail-by-name);
      * correctness — each bucketed row must report ``outputs_match``: the
        bench compares the bucketed op's output against the padded op's on
        the same graph at float tolerance in the same run it times;
      * sparse win — per op family the bucketed op's time must be STRICTLY
        below the padded op's in the same run (run-internal, so machine
        speed and interpret-vs-compiled mode cancel);
      * ratio — the bucketed/padded time ratio must stay within
        ``threshold`` of the baseline's ratio (the machine-cancelling
        regression check: a bucketed path that silently lost half its win
        still "beats padded" but fails here)."""
    failures: list[str] = []
    b_rows = {k: v for k, v in baseline.get("rows", {}).items() if k.startswith("kernels/")}
    c_rows = {k: v for k, v in current.get("rows", {}).items() if k.startswith("kernels/")}

    for key in sorted(b_rows):
        if key not in c_rows:
            failures.append(f"kernels-coverage: baseline row {key} missing from current run")
    if not c_rows:
        failures.append("kernels-coverage: current run has no kernels/ rows")

    def ratio(rows, which):
        for key, row in sorted(rows.items()):
            if not key.endswith("/bucketed"):
                continue
            pad = rows.get(key.rsplit("/", 1)[0] + "/padded")
            if pad is None:
                failures.append(f"kernels({which}): {key} has no padded row to compare")
                continue
            if not pad["t_us"] > 0:
                failures.append(
                    f"kernels({which}): {key} padded normalizer t_us "
                    f"{pad['t_us']!r} not positive"
                )
                continue
            yield key, row, row["t_us"] / pad["t_us"]

    c_ratios = {}
    for key, row, r in ratio(c_rows, "current"):
        c_ratios[key] = r
        if not row.get("outputs_match"):
            failures.append(
                f"kernels: {key} output diverged from the padded op's "
                f"(max_abs_diff={row.get('max_abs_diff')!r})"
            )
        if not r < 1.0:
            failures.append(
                f"kernels: {key} at {r:.2f}x the padded op's time — the "
                f"bucketed layout must win strictly at the skewed shapes"
            )
    for key, _, base in ratio(b_rows, "baseline"):
        cur = c_ratios.get(key)
        if cur is None:
            continue  # coverage failure already recorded above
        status = "ok"
        if cur > base * threshold:
            status = f"REGRESSED >{(threshold - 1):.0%}"
            failures.append(
                f"kernels: {key} bucketed/padded ratio {cur:.3f} vs baseline "
                f"{base:.3f} (allowed {base * threshold:.3f})"
            )
        print(f"  {key:40s} baseline {base:8.3f}x  current {cur:8.3f}x  {status}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--current", default=None,
                    help="fresh BENCH_fig3.json (required unless --serving-current is given)")
    ap.add_argument("--threshold", type=float, default=1.20,
                    help="max allowed current/baseline slowdown factor (1.20 = +20%%)")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw seconds instead of host-normalized ratios")
    ap.add_argument("--auto-pred-ratio", type=float, default=25.0,
                    help="max allowed predicted/measured step-time ratio (either "
                         "direction) for the auto/pick row — loose on purpose: "
                         "forced-host CPU dispatch overhead is unmodeled, but a "
                         "prediction this far off means the cost model broke")
    ap.add_argument("--serving-baseline", default=str(DEFAULT_SERVING_BASELINE))
    ap.add_argument("--serving-current", default=None,
                    help="fresh BENCH_serve.json from repro.launch.serve_gnn --json-out")
    ap.add_argument("--serving-threshold", type=float, default=2.0,
                    help="max allowed normalized-p99 slowdown factor for serving rows "
                         "(looser than --threshold: open-loop queueing tails are noisy)")
    ap.add_argument("--kernels-baseline", default=str(DEFAULT_KERNELS_BASELINE))
    ap.add_argument("--kernels-current", default=None,
                    help="fresh BENCH_kernels.json from benchmarks.run --only kernels --json-out")
    ap.add_argument("--kernels-threshold", type=float, default=1.30,
                    help="max allowed bucketed/padded ratio growth for kernel rows "
                         "(microbench medians are noisier than pipeline steps)")
    args = ap.parse_args()
    if args.current is None and args.serving_current is None and args.kernels_current is None:
        ap.error("provide --current, --serving-current and/or --kernels-current")

    failures = []
    if args.current is not None:
        with open(args.baseline) as f:
            baseline = json.load(f)
        with open(args.current) as f:
            current = json.load(f)
        print(f"perf gate: baseline={args.baseline} threshold={args.threshold:.2f} "
              f"mode={'absolute' if args.absolute else 'host-normalized'}")
        failures += check(baseline, current, threshold=args.threshold,
                          absolute=args.absolute,
                          auto_pred_ratio=args.auto_pred_ratio)
    if args.serving_current is not None:
        with open(args.serving_baseline) as f:
            serving_baseline = json.load(f)
        with open(args.serving_current) as f:
            serving_current = json.load(f)
        print(f"serving gate: baseline={args.serving_baseline} "
              f"threshold={args.serving_threshold:.2f} (p99 / warm eval call)")
        failures += check_serving(
            serving_baseline, serving_current, threshold=args.serving_threshold
        )
    if args.kernels_current is not None:
        with open(args.kernels_baseline) as f:
            kernels_baseline = json.load(f)
        with open(args.kernels_current) as f:
            kernels_current = json.load(f)
        print(f"kernels gate: baseline={args.kernels_baseline} "
              f"threshold={args.kernels_threshold:.2f} (bucketed / padded op time)")
        failures += check_kernels(
            kernels_baseline, kernels_current, threshold=args.kernels_threshold
        )
    if failures:
        print("\nPERF GATE FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        print("(intentional? apply the 'perf-regression-ok' PR label and "
              "commit a refreshed benchmarks/BENCH_fig3.json / BENCH_serve.json "
              "/ BENCH_kernels.json)")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
