"""Table 2 analogue — pipeline configurations on one dataset.

Paper rows: single CPU/GPU vs DGX+GPipe chunks 1–4 (epoch-1 time, epochs
2–300 time, train loss/acc, val acc). Ours: single-device vs GPipe 4-stage
with chunks 1–4 (sequential strategy, the faithful one).
"""

from __future__ import annotations

import types

from benchmarks.common import emit
from repro.launch.train import run_gnn


def _args(**kw):
    base = dict(mode="gnn", dataset="cora", backend="padded", strategy="sequential",
                stages=1, chunks=1, epochs=60, seed=0, log_every=0)
    base.update(kw)
    return types.SimpleNamespace(**base)


def run(*, dataset="cora", epochs=60):
    rows = []
    single = run_gnn(_args(dataset=dataset, epochs=epochs))
    emit(f"table2/{dataset}/single", single["avg_epoch_s"] * 1e6,
         f"val_acc={single['val_acc']:.3f};first_epoch_s={single['first_epoch_s']:.2f}")
    rows.append(("single", single))
    for chunks in (1, 2, 3, 4):
        r = run_gnn(_args(dataset=dataset, stages=4, chunks=chunks, epochs=epochs))
        emit(
            f"table2/{dataset}/gpipe_chunks{chunks}",
            r["avg_epoch_s"] * 1e6,
            f"val_acc={r['val_acc']:.3f};train_acc={r['train_acc']:.3f};"
            f"edge_cut={r['edge_cut']:.3f};first_epoch_s={r['first_epoch_s']:.2f}",
        )
        rows.append((f"chunks{chunks}", r))
    return rows
