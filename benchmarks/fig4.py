"""Fig 4 analogue — accuracy vs chunks: the paper's collapse + our fixes.

sequential = paper-faithful (edges dropped at chunk boundaries);
greedy     = structure-aware partitions (beyond-paper);
halo       = exact k-hop ghost nodes (beyond-paper; should match full batch).

The schedule-comparison columns rerun the halo config under 1F1B,
interleaved 1F1B and zero-bubble zb-h1 (split B/W backward): accuracy must
NOT move (per-chunk gradients are reduced in a canonical order, so every
schedule's update is bit-identical) while the bubble/peak-activation
accounting does — schedules buy speed and memory, never model quality. The
``engine=compiled`` columns rerun the same halo config through the compiled
SPMD engine under every schedule (fill-drain on the fused scan,
1F1B/interleaved/zb-h1 on the scheduled executor): same plan, same seed, so
their accuracy sitting next to the host rows is the
schedule×engine-equivalence smoke — and those rows' metrics now come from
``CompiledGNNPipeline.evaluate``, the forward-only jitted scheduled
program, so the compiled eval path is exercised (and must agree) too.
"""

from __future__ import annotations

from benchmarks.common import PipelineCLIConfig, emit
from repro.launch.train import run_gnn


def _args(dataset, epochs, *, strategy="sequential", **pipeline):
    """One fig4 cell's run_gnn namespace off the shared pipeline CLI bundle."""
    return PipelineCLIConfig(**pipeline).namespace(
        mode="gnn", dataset=dataset, strategy=strategy,
        epochs=epochs, seed=0, log_every=0,
    )


def run(*, dataset="cora", epochs=60, strategies=("sequential", "greedy", "halo")):
    rows = []
    full = run_gnn(_args(dataset, epochs))
    emit(f"fig4/{dataset}/full_batch", full["avg_epoch_s"] * 1e6,
         f"val_acc={full['val_acc']:.3f}")
    rows.append(("full", 1, full["val_acc"]))
    halo4 = None
    for strategy in strategies:
        for chunks in (2, 4):
            r = run_gnn(_args(dataset, epochs, strategy=strategy, stages=4, chunks=chunks))
            if strategy == "halo" and chunks == 4:
                halo4 = r  # fill-drain baseline, reused for the schedule rows
            emit(
                f"fig4/{dataset}/{strategy}_chunks{chunks}",
                r["avg_epoch_s"] * 1e6,
                f"val_acc={r['val_acc']:.3f};edge_cut={r['edge_cut']:.3f}",
            )
            rows.append((strategy, chunks, r["val_acc"]))
    # schedule-equivalence columns: same halo config, every schedule
    for schedule in ("fill_drain", "1f1b", "interleaved", "zb-h1", "zb-v"):
        if schedule == "fill_drain" and halo4 is not None:
            r = halo4  # identical config already trained above
        else:
            r = run_gnn(_args(
                dataset, epochs, strategy="halo",
                stages=4, chunks=4, schedule=schedule, pipe_devices=2,
            ))
        emit(
            f"fig4/{dataset}/halo_chunks4_{schedule}",
            r["avg_epoch_s"] * 1e6,
            f"val_acc={r['val_acc']:.3f};bubble={r['bubble_fraction']:.3f};"
            f"peak_live={r['peak_live_activations']}",
        )
        rows.append((f"halo/{schedule}", 4, r["val_acc"]))
    # engine-equivalence columns: same halo plan/seed on the compiled engine
    # under every schedule — fill-drain runs the fused scan, 1F1B and
    # interleaved the scheduled executor. Accuracy must sit on top of the
    # host fill-drain row for all of them (schedule- AND engine-invariance).
    for schedule, pipe_devices in (
        ("fill_drain", None), ("1f1b", None), ("interleaved", 2),
        ("zb-h1", None), ("zb-v", 2),
    ):
        r = run_gnn(_args(
            dataset, epochs, strategy="halo", engine="compiled",
            stages=4, chunks=4, schedule=schedule, pipe_devices=pipe_devices,
        ))
        emit(
            f"fig4/{dataset}/halo_chunks4_compiled_{schedule}",
            r["avg_epoch_s"] * 1e6,
            f"val_acc={r['val_acc']:.3f};engine=compiled;"
            f"peak_live={r['peak_live_activations']}",
        )
        rows.append((f"halo/compiled/{schedule}", 4, r["val_acc"]))
    # partition-invariance column: the SAME halo config under the profiled
    # (cost-model) balance — moving layer boundaries must not move accuracy,
    # only the per-stage cost profile (partitioning reorders work, never math)
    r = run_gnn(_args(
        dataset, epochs, strategy="halo", engine="compiled",
        stages=4, chunks=4, schedule="1f1b", partition="profiled",
    ))
    emit(
        f"fig4/{dataset}/halo_chunks4_compiled_1f1b_profiled",
        r["avg_epoch_s"] * 1e6,
        f"val_acc={r['val_acc']:.3f};engine=compiled;"
        f"balance={'-'.join(map(str, r['balance']))}",
    )
    rows.append(("halo/compiled/1f1b/profiled", 4, r["val_acc"]))
    return rows
