# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure + roofline table.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--dataset cora]

``--fast`` trims epochs for CI-speed runs; the full-protocol numbers
(300 epochs, pubmed) are produced with ``--full`` as in the paper.
"""

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true", help="paper protocol: 300 epochs + pubmed")
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--only", default=None, help="comma list: table1,table2,fig3,fig4,kernels,roofline")
    ap.add_argument("--json-out", default=None,
                    help="directory for machine-readable outputs (BENCH_fig3.json, "
                         "consumed by benchmarks.check_perf)")
    ap.add_argument("--partition", default="uniform", choices=["uniform", "profiled"],
                    help="fig3: stage balance for the engine×schedule matrix "
                         "(the imbalanced-stack partitioner comparison runs either way)")
    ap.add_argument("--table1-backends", default="padded,dense,pallas",
                    help="comma list of aggregation backends for the table1 "
                         "columns (pallas runs the fused kernel in interpret "
                         "mode on CPU)")
    args = ap.parse_args()

    epochs = 300 if args.full else (15 if args.fast else 60)
    dataset = "pubmed" if args.full else args.dataset
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    if want("table1"):
        from benchmarks import table1

        datasets = ("cora", "citeseer", "pubmed") if args.full else ("cora",)
        table1.run(
            datasets=datasets,
            backends=tuple(args.table1_backends.split(",")),
            epochs=epochs,
        )
    if want("table2"):
        from benchmarks import table2

        table2.run(dataset=dataset, epochs=epochs)
    if want("fig3"):
        from benchmarks import fig3

        json_path = None
        if args.json_out:
            os.makedirs(args.json_out, exist_ok=True)
            json_path = os.path.join(args.json_out, "BENCH_fig3.json")
        fig3.run(dataset=dataset, epochs=max(epochs // 2, 10), json_path=json_path,
                 partition=args.partition)
    if want("fig4"):
        from benchmarks import fig4

        fig4.run(dataset=dataset, epochs=epochs)
    if want("kernels"):
        from benchmarks import kernels_bench

        json_path = None
        if args.json_out:
            os.makedirs(args.json_out, exist_ok=True)
            json_path = os.path.join(args.json_out, "BENCH_kernels.json")
        kernels_bench.run(json_path=json_path)
    if want("roofline"):
        from benchmarks import roofline_table

        roofline_table.run()
    sys.stdout.flush()


if __name__ == "__main__":
    main()
