"""Kernel microbenches: Pallas (interpret on CPU) vs jnp oracle per shape.

On this container the numbers measure the *reference* math (interpret mode
executes kernel bodies in Python/XLA); they validate plumbing and give the
oracle's CPU cost. TPU wall-clock comes from deploying with interpret=False.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels.gat_edge.kernel import gat_aggregate_kernel
from repro.kernels.gat_edge.ref import gat_aggregate_ref
from repro.kernels.spmm.kernel import padded_spmm_kernel
from repro.kernels.spmm.ref import padded_spmm_ref
from repro.kernels.ssd.ops import ssd
from repro.models.transformer.ssm import ssd_chunked


def run():
    k = jax.random.PRNGKey(0)
    # GAT edge (cora-scale)
    h_, n, d, f = 8, 2708, 14, 8
    nbr_hw = jax.random.normal(k, (h_, n, d, f))
    s_self = jax.random.normal(jax.random.fold_in(k, 1), (h_, n))
    s_nbr = jax.random.normal(jax.random.fold_in(k, 2), (h_, n, d))
    mask = jnp.ones((n, d), bool)
    t_ref = timed(jax.jit(gat_aggregate_ref), nbr_hw, s_self, s_nbr, mask)
    t_ker = timed(lambda *a: gat_aggregate_kernel(*a), nbr_hw, s_self, s_nbr, mask)
    emit("kernels/gat_edge/ref", t_ref, f"n={n};d={d};h={h_}")
    emit("kernels/gat_edge/pallas_interpret", t_ker, "same shape")

    # SpMM (pubmed-scale features)
    n2, d2, f2 = 8192, 16, 64
    hw = jax.random.normal(k, (n2, f2))
    nbr = jax.random.randint(jax.random.fold_in(k, 3), (n2, d2), 0, n2)
    norm = jax.random.uniform(jax.random.fold_in(k, 4), (n2, d2))
    t_ref = timed(jax.jit(padded_spmm_ref), hw, nbr, norm)
    t_ker = timed(lambda *a: padded_spmm_kernel(*a), hw, nbr, norm)
    emit("kernels/spmm/ref", t_ref, f"n={n2};d={d2};f={f2}")
    emit("kernels/spmm/pallas_interpret", t_ker, "same shape")

    # SSD (mamba2-130m-ish slice)
    b, s, hh, p, nn = 1, 512, 8, 64, 64
    x = jax.random.normal(k, (b, s, hh, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 5), (b, s, hh))) * 0.1
    A = -jnp.exp(jnp.linspace(0.0, 2.0, hh))
    B = jax.random.normal(jax.random.fold_in(k, 6), (b, s, nn)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 7), (b, s, nn)) * 0.3
    t_ref = timed(jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0]), x, dt, A, B, C)
    t_ker = timed(lambda *a: ssd(*a, 128), x, dt, A, B, C)
    emit("kernels/ssd/ref_chunked", t_ref, f"s={s};h={hh};p={p};n={nn}")
    emit("kernels/ssd/pallas_interpret", t_ker, "same shape")
