"""Kernel microbenches: Pallas (interpret on CPU) vs jnp oracle per shape.

On this container the numbers measure the *reference* math (interpret mode
executes kernel bodies in Python/XLA); they validate plumbing and give the
oracle's CPU cost. TPU wall-clock comes from deploying with interpret=False.

The padded-vs-degree-bucketed rows (``kernels/{spmm|gat}/{padded|bucketed}``)
time the PUBLIC aggregation ops — the exact entry points the GNN layers
call, routed by ``kernels.use_kernel_forward()`` — on the skewed power-law
fixtures' real layouts, and compare the two ops' outputs at float tolerance
in the same run. ``json_path`` writes them as ``BENCH_kernels.json``, the
artifact ``benchmarks.check_perf --kernels-current`` gates (coverage +
output agreement + strict bucketed win + ratio vs the committed baseline).
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.graphs import degree_bucketed_layout, load_dataset
from repro.kernels.gat_edge.kernel import gat_aggregate_kernel
from repro.kernels.gat_edge.ops import bucketed_gat_aggregate, gat_aggregate
from repro.kernels.gat_edge.ref import gat_aggregate_ref
from repro.kernels.spmm.kernel import padded_spmm_kernel
from repro.kernels.spmm.ops import bucketed_spmm, padded_spmm
from repro.kernels.spmm.ref import padded_spmm_ref
from repro.kernels.ssd.ops import ssd
from repro.models.transformer.ssm import ssd_chunked


def _sparse_rows(rows: dict) -> None:
    """padded vs degree-bucketed aggregation ops at the skewed shapes.

    SpMM runs at full ``skewed-powerlaw`` scale (8k nodes, degree cap 128 —
    the serving-relevant shape); GAT at the ``skewed-mini`` twin because the
    padded GAT op materializes the gathered ``(H, N, D, F)`` tensor, which
    at 8k x 128 is the exact blow-up the bucketed layout exists to avoid —
    timing it would mostly measure the allocator.
    """
    k = jax.random.PRNGKey(7)
    tol = 2e-4  # bucket concat reorders the f32 edge sums

    def bench(family, g, fns, args, derived):
        b = degree_bucketed_layout(g)
        slots = {
            "padded": int(g.neighbors.shape[0] * g.neighbors.shape[1]),
            "bucketed": int(sum(bk.rows * bk.width for bk in b.buckets)),
        }
        jitted = {name: jax.jit(fn(b) if name == "bucketed" else fn(g))
                  for name, fn in fns.items()}
        outs = {name: jax.block_until_ready(fn(*args)) for name, fn in jitted.items()}
        diff = float(jnp.max(jnp.abs(outs["padded"] - outs["bucketed"])))
        for name, fn in jitted.items():
            t = timed(fn, *args, iters=10)
            extra = "" if name == "padded" else f";max_abs_diff={diff:.2e}"
            emit(f"kernels/{family}/{name}", t, f"{derived};slots={slots[name]}{extra}")
            rows[f"kernels/{family}/{name}"] = {
                "t_us": t,
                "layout_slots": slots[name],
                "max_abs_diff": diff,
                "outputs_match": diff <= tol,
            }

    def bucket_fields(b):
        return (
            tuple(bk.neighbors for bk in b.buckets),
            tuple(bk.mask for bk in b.buckets),
            tuple(bk.norm for bk in b.buckets),
            tuple(bk.row_node for bk in b.buckets),
            b.gather_rows,
        )

    # SpMM — GCN-style weighted neighbor sum at hidden width 32
    g = load_dataset("skewed-powerlaw", max_degree=128)
    hw = jax.random.normal(k, (g.features.shape[0], 32))
    bench(
        "spmm", g,
        {
            "padded": lambda g: lambda h: padded_spmm(h, g.neighbors, g.norm),
            "bucketed": lambda b: (
                lambda h, fields=bucket_fields(b):
                bucketed_spmm(h, fields[0], fields[2], fields[4])
            ),
        },
        (hw,),
        f"dataset=skewed-powerlaw;n={g.features.shape[0]};"
        f"max_deg={g.neighbors.shape[1]};f=32",
    )

    # GAT — fused attention aggregate, 8 heads x 8 features. The mini twin,
    # not the 8k fixture: the padded op materializes the gathered
    # (H, N, D, F) tensor, which at 8k x 128 mostly measures the allocator.
    g = load_dataset("skewed-mini")
    heads, f = 8, 8
    hw = jax.random.normal(k, (g.features.shape[0], heads, f))
    s_src = jax.random.normal(jax.random.fold_in(k, 1), (g.features.shape[0], heads))
    s_dst = jax.random.normal(jax.random.fold_in(k, 2), (g.features.shape[0], heads))
    bench(
        "gat", g,
        {
            "padded": lambda g: (
                lambda h, a, c: gat_aggregate(h, a, c, g.neighbors, g.mask)
            ),
            "bucketed": lambda b: (
                lambda h, a, c, fields=bucket_fields(b):
                bucketed_gat_aggregate(h, a, c, fields[0], fields[1], fields[3], fields[4])
            ),
        },
        (hw, s_src, s_dst),
        f"dataset=skewed-mini;n={g.features.shape[0]};"
        f"max_deg={g.neighbors.shape[1]};h={heads};f={f}",
    )


def run(*, json_path=None):
    k = jax.random.PRNGKey(0)
    # GAT edge (cora-scale)
    h_, n, d, f = 8, 2708, 14, 8
    nbr_hw = jax.random.normal(k, (h_, n, d, f))
    s_self = jax.random.normal(jax.random.fold_in(k, 1), (h_, n))
    s_nbr = jax.random.normal(jax.random.fold_in(k, 2), (h_, n, d))
    mask = jnp.ones((n, d), bool)
    t_ref = timed(jax.jit(gat_aggregate_ref), nbr_hw, s_self, s_nbr, mask)
    t_ker = timed(lambda *a: gat_aggregate_kernel(*a), nbr_hw, s_self, s_nbr, mask)
    emit("kernels/gat_edge/ref", t_ref, f"n={n};d={d};h={h_}")
    emit("kernels/gat_edge/pallas_interpret", t_ker, "same shape")

    # SpMM (pubmed-scale features)
    n2, d2, f2 = 8192, 16, 64
    hw = jax.random.normal(k, (n2, f2))
    nbr = jax.random.randint(jax.random.fold_in(k, 3), (n2, d2), 0, n2)
    norm = jax.random.uniform(jax.random.fold_in(k, 4), (n2, d2))
    t_ref = timed(jax.jit(padded_spmm_ref), hw, nbr, norm)
    t_ker = timed(lambda *a: padded_spmm_kernel(*a), hw, nbr, norm)
    emit("kernels/spmm/ref", t_ref, f"n={n2};d={d2};f={f2}")
    emit("kernels/spmm/pallas_interpret", t_ker, "same shape")

    # SSD (mamba2-130m-ish slice)
    b, s, hh, p, nn = 1, 512, 8, 64, 64
    x = jax.random.normal(k, (b, s, hh, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 5), (b, s, hh))) * 0.1
    A = -jnp.exp(jnp.linspace(0.0, 2.0, hh))
    B = jax.random.normal(jax.random.fold_in(k, 6), (b, s, nn)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 7), (b, s, nn)) * 0.3
    t_ref = timed(jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0]), x, dt, A, B, C)
    t_ker = timed(lambda *a: ssd(*a, 128), x, dt, A, B, C)
    emit("kernels/ssd/ref_chunked", t_ref, f"s={s};h={hh};p={p};n={nn}")
    emit("kernels/ssd/pallas_interpret", t_ker, "same shape")

    rows: dict = {}
    _sparse_rows(rows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"rows": rows}, f, indent=2, sort_keys=True)
            f.write("\n")
