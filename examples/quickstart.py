"""Quickstart: train the paper's GAT on (synthetic) Cora, single device.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.graphs import load_dataset
from repro.models.gnn.net import build_paper_gat
from repro.train.loop import train


def main():
    g = load_dataset("cora")
    print(f"cora: {g.num_nodes} nodes, {int(g.num_edges)//2} edges, "
          f"{g.num_features} features, {g.num_classes} classes")
    model = build_paper_gat(g.num_features, g.num_classes)
    res = train(model, g, epochs=100, log_every=20)
    print(f"test accuracy: {res.test_acc:.3f}  "
          f"(avg epoch {res.avg_epoch_s*1e3:.1f} ms, first {res.first_epoch_s:.2f} s)")


if __name__ == "__main__":
    main()
