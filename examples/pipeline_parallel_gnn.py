"""The paper's experiment in miniature: GPipe the GAT across 4 stages and
compare micro-batching strategies — the faithful lossy ``sequential`` split
(accuracy collapses, Fig 4) vs the beyond-paper ``halo`` batching (exact) —
then the same model under each pipeline schedule (fill-drain / 1F1B /
interleaved): validation accuracy is identical by construction while the
bubble fraction and live-activation footprint shrink. Finally the same halo
config reruns on the compiled SPMD engine (one jitted program instead of
the host queue loop): same accuracy, faster epochs.

    PYTHONPATH=src python examples/pipeline_parallel_gnn.py [--dataset cora]
"""

import argparse

from repro.core.cli import PipelineCLIConfig
from repro.core.schedule import get_schedule
from repro.launch.train import run_gnn


def print_schedule_matrix(stages=4, pipe_devices=2, chunk_counts=(2, 4, 8)):
    """Bubble fraction / peak live activations per (schedule, chunks)."""
    print(f"\nschedule matrix (S={stages} stages, interleaved on "
          f"D={pipe_devices} devices => V={stages // pipe_devices} virtual/device):")
    print(f"  {'schedule':<12} {'chunks':>6} {'ticks':>6} {'bubble':>8} {'peak_live':>10}")
    for name, kw in (("fill_drain", {}), ("1f1b", {}),
                     ("interleaved", {"num_devices": pipe_devices}),
                     ("zb-h1", {}),
                     ("zb-v", {"num_devices": pipe_devices})):
        sched = get_schedule(name, **kw)
        for chunks in chunk_counts:
            try:
                d = sched.describe(stages, chunks)
            except ValueError:
                continue  # interleaved needs chunks % devices == 0
            print(f"  {name:<12} {chunks:>6} {d['ticks']:>6} "
                  f"{d['bubble_fraction']:>8.3f} {d['peak_live_activations']:>10}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--epochs", type=int, default=60)
    args = ap.parse_args()

    def cfg(*, strategy="sequential", **pipeline):
        # one shared flag bundle (repro.core.cli) instead of a hand-rolled
        # namespace — the same surface the CLI drivers and benchmarks use
        pipeline.setdefault("pipe_devices", 2)
        return PipelineCLIConfig(**pipeline).namespace(
            mode="gnn", dataset=args.dataset,
            strategy=strategy, epochs=args.epochs, seed=0, log_every=0,
        )

    print("== full batch (single device) ==")
    full = run_gnn(cfg())
    print("== GPipe 4 stages, 4 chunks, SEQUENTIAL split (paper-faithful) ==")
    seq = run_gnn(cfg(stages=4, chunks=4, strategy="sequential"))
    print("== GPipe 4 stages, 4 chunks, HALO batching (beyond-paper fix) ==")
    halo = run_gnn(cfg(stages=4, chunks=4, strategy="halo"))
    print("== same halo config under 1F1B (identical update, less memory) ==")
    halo_1f1b = run_gnn(cfg(stages=4, chunks=4, strategy="halo", schedule="1f1b"))
    print("== ... and interleaved 1F1B (2 devices x 2 virtual stages) ==")
    halo_il = run_gnn(cfg(stages=4, chunks=4, strategy="halo", schedule="interleaved"))
    print("== same halo config on the COMPILED engine (one jitted program) ==")
    halo_c = run_gnn(cfg(stages=4, chunks=4, strategy="halo", engine="compiled"))
    print("== ... and 1F1B INSIDE the compiled program (scheduled executor) ==")
    halo_c1 = run_gnn(cfg(stages=4, chunks=4, strategy="halo", engine="compiled",
                          schedule="1f1b"))
    print("== ... and zero-bubble ZB-H1 (split B/W backward, deferred weight grads) ==")
    halo_zb = run_gnn(cfg(stages=4, chunks=4, strategy="halo", engine="compiled",
                          schedule="zb-h1"))
    print("== ... and ZB-V (split backward + 2 virtual stages/device) ==")
    halo_zbv = run_gnn(cfg(stages=4, chunks=4, strategy="halo", engine="compiled",
                           schedule="zb-v"))
    print("== --auto: the planner picks schedule/chunks/balance/placement ==")
    auto = run_gnn(cfg(stages=4, strategy="halo", engine="compiled", auto=True))

    print("\nsummary (val accuracy):")
    print(f"  full batch               {full['val_acc']:.3f}")
    print(f"  gpipe sequential         {seq['val_acc']:.3f}   edges lost: {seq['edge_cut']:.0%}")
    print(f"  gpipe halo               {halo['val_acc']:.3f}   edges lost: 0%")
    print(f"  gpipe halo / 1f1b        {halo_1f1b['val_acc']:.3f}   "
          f"peak_live {halo_1f1b['peak_live_activations']} vs {halo['peak_live_activations']}")
    print(f"  gpipe halo / interleaved {halo_il['val_acc']:.3f}   "
          f"bubble {halo_il['bubble_fraction']:.3f} vs {halo['bubble_fraction']:.3f}")
    print(f"  compiled engine (halo)   {halo_c['val_acc']:.3f}   "
          f"epoch {halo_c['avg_epoch_s']*1e3:.0f}ms vs host {halo['avg_epoch_s']*1e3:.0f}ms")
    print(f"  compiled halo / 1f1b     {halo_c1['val_acc']:.3f}   "
          f"peak_live {halo_c1['peak_live_activations']} "
          f"(stash accounting) vs fill-drain {4 * 4}")
    print(f"  compiled halo / zb-h1    {halo_zb['val_acc']:.3f}   "
          f"bubble {halo_zb['bubble_fraction']:.3f} vs 1f1b "
          f"{halo_c1['bubble_fraction']:.3f}, peak_live "
          f"{halo_zb['peak_live_activations']}")
    print(f"  compiled halo / zb-v     {halo_zbv['val_acc']:.3f}   "
          f"bubble {halo_zbv['bubble_fraction']:.3f} "
          f"(2 virtual stages/device + split B/W)")
    print(f"  compiled halo / --auto   {auto['val_acc']:.3f}   "
          f"picked {auto['schedule']}/chunks{auto['chunks']} "
          f"predicted {auto['predicted_step_s'] * 1e3:.1f}ms "
          f"measured {auto['median_epoch_s'] * 1e3:.1f}ms")
    print_schedule_matrix()


if __name__ == "__main__":
    main()
