"""The paper's experiment in miniature: GPipe the GAT across 4 stages and
compare micro-batching strategies — the faithful lossy ``sequential`` split
(accuracy collapses, Fig 4) vs the beyond-paper ``halo`` batching (exact).

    PYTHONPATH=src python examples/pipeline_parallel_gnn.py [--dataset cora]
"""

import argparse
import types

from repro.launch.train import run_gnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cora")
    ap.add_argument("--epochs", type=int, default=60)
    args = ap.parse_args()

    def cfg(**kw):
        base = dict(mode="gnn", dataset=args.dataset, backend="padded",
                    strategy="sequential", stages=1, chunks=1,
                    epochs=args.epochs, seed=0, log_every=0)
        base.update(kw)
        return types.SimpleNamespace(**base)

    print("== full batch (single device) ==")
    full = run_gnn(cfg())
    print("== GPipe 4 stages, 4 chunks, SEQUENTIAL split (paper-faithful) ==")
    seq = run_gnn(cfg(stages=4, chunks=4, strategy="sequential"))
    print("== GPipe 4 stages, 4 chunks, HALO batching (beyond-paper fix) ==")
    halo = run_gnn(cfg(stages=4, chunks=4, strategy="halo"))

    print("\nsummary (val accuracy):")
    print(f"  full batch        {full['val_acc']:.3f}")
    print(f"  gpipe sequential  {seq['val_acc']:.3f}   edges lost: {seq['edge_cut']:.0%}")
    print(f"  gpipe halo        {halo['val_acc']:.3f}   edges lost: 0%")


if __name__ == "__main__":
    main()
