"""Batched serving: prefill a prompt batch, decode greedily through the
pipelined serve_step (KV/SSM caches, ring buffers, the lot).

    PYTHONPATH=src python examples/serve_batched.py --arch codeqwen1.5-7b
"""

import argparse
import types

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--decode-steps", type=int, default=12)
    a = ap.parse_args()
    run(types.SimpleNamespace(
        arch=a.arch, full_arch=False, prompt_len=a.prompt_len,
        decode_steps=a.decode_steps, batch=a.batch, stages=1, chunks=1, seed=0,
    ))


if __name__ == "__main__":
    main()
