"""End-to-end LM pretraining through the SPMD pipeline (same engine the
512-chip dry-run lowers), smoke-sized to run on CPU.

    PYTHONPATH=src python examples/lm_pretrain.py --arch mamba2-130m --steps 50

To train a ~100M-param model for a few hundred steps (the deliverable-scale
run; give it time on CPU):

    PYTHONPATH=src python examples/lm_pretrain.py --arch mamba2-130m \
        --full-arch --steps 300 --seq 256 --batch 8
"""

import argparse
import types

from repro.launch.train import run_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--full-arch", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    a = ap.parse_args()
    out = run_lm(types.SimpleNamespace(
        arch=a.arch, full_arch=a.full_arch, steps=a.steps, seq=a.seq,
        batch=a.batch, stages=1, chunks=2, lr=3e-4, seed=0, log_every=10,
    ))
    print("loss moved:", out["first_loss"], "->", out["last_loss"])


if __name__ == "__main__":
    main()
