"""Paper §8: "pipeline parallelism is intended to benefit ... much greater
[graphs] than the PubMed set used here". This example runs the reddit-mini
stand-in (8192 nodes / 131k edges / 50 classes) and shows where chunking
starts paying: per-chunk peak activation size drops ~linearly with chunks
while halo batching keeps accuracy at full-batch level.

    PYTHONPATH=src python examples/scaling_larger_graphs.py
"""

import time

import jax

from repro.core.microbatch import make_plan
from repro.core.pipeline import GPipe, GPipeConfig
from repro.graphs import load_dataset
from repro.models.gnn.net import build_paper_gat
from repro.train import optimizer as opt_lib
from repro.train.loop import make_eval


def main():
    t0 = time.time()
    g = load_dataset("reddit-mini")
    print(f"reddit-mini built in {time.time()-t0:.1f}s: {g.num_nodes} nodes, "
          f"{int(g.num_edges)//2} edges, max_deg {g.max_degree}")

    model = build_paper_gat(g.num_features, g.num_classes)
    opt = opt_lib.adam(5e-3, weight_decay=5e-4)
    evaluate = make_eval(model)

    for chunks, strategy in [(1, "sequential"), (4, "halo"), (8, "halo")]:
        pipe = GPipe(model, GPipeConfig(balance=(2, 1, 1, 2), chunks=chunks))
        plan = make_plan(g, chunks, strategy=strategy, halo_hops=2)
        sizes = [b.num_nodes for b in plan.batches]
        key = jax.random.PRNGKey(0)
        params = pipe.init_params(key)
        state = opt.init(params)
        t0 = time.time()
        for epoch in range(3):
            key, rng = jax.random.split(key)
            params, state, loss = pipe.train_step(params, state, plan, rng, opt)
        jax.block_until_ready(loss)
        m = evaluate(params, g)
        print(f"chunks={chunks:2d} ({strategy:10s}) max_chunk_nodes={max(sizes):6d} "
              f"(full={g.num_nodes}) epoch_s={(time.time()-t0)/3:6.2f} "
              f"val_acc@3ep={float(m['val_acc']):.3f} edge_cut={plan.edge_cut:.2f}")
    print()
    print("observed: on this small-world graph (avg degree 32) a 2-hop halo of")
    print("1/4 of the nodes already spans the WHOLE graph — exact halos cannot")
    print("shrink chunks here. This is precisely why GraphSAGE-style sampling")
    print("and SIGN precompute (graphs/sign.py) exist: SIGN makes chunks exact")
    print("AND small regardless of graph density (see tests/test_sign.py).")


if __name__ == "__main__":
    main()
