"""Paper §8: "pipeline parallelism is intended to benefit ... much greater
[graphs] than the PubMed set used here". Two parts:

Part 1 runs the reddit-mini stand-in (8192 nodes / 131k edges / 50
classes) and shows where chunking starts paying: per-chunk peak activation
size drops ~linearly with chunks while halo batching keeps accuracy at
full-batch level.

Part 2 goes past what fits in one replica: a STREAMED power-law graph
(``repro.graphs.open_streamed`` — edges from a per-block counter-based
RNG, features materialized per chunk on the host, never the whole matrix)
trained over the 2-D ``("data", "stage")`` mesh when the host has enough
devices (``data_parallel=2``), with the update checked against the host
fill-drain oracle. The same code path runs the 10⁶-node registry entry
(``open_streamed("powerlaw-1m")``) — only chunk count and wall-clock grow.

    PYTHONPATH=src python examples/scaling_larger_graphs.py
    # the mesh path activates with >= data_parallel * stages devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/scaling_larger_graphs.py
"""

import time

import jax

from repro.core.microbatch import make_plan
from repro.core.pipeline import GPipe, GPipeConfig, make_engine
from repro.graphs import DoubleBufferedLoader, load_dataset, open_streamed, streamed_plan
from repro.models.gnn.net import build_gnn, build_paper_gat
from repro.train import optimizer as opt_lib
from repro.train.loop import make_eval


def streamed_mesh_demo(num_nodes=32_768, chunks=8, epochs=3):
    """Streamed graph over the (data, stage) mesh, oracle-checked."""
    t0 = time.time()
    ds = open_streamed("powerlaw-64k", num_nodes=num_nodes)
    plan = streamed_plan(ds, chunks, max_degree=32)
    g0 = plan.batches[0].graph
    print(f"\nstreamed powerlaw-64k@{num_nodes} built in {time.time()-t0:.1f}s: "
          f"{chunks} chunks x {g0.num_nodes} nodes, edge_cut={plan.edge_cut:.2f}")

    balance = (2, 2)
    dp = 2 if jax.device_count() >= 2 * len(balance) else 1
    model = build_gnn("gcn", g0.num_features, g0.num_classes, hidden=32, depth=2)
    opt = opt_lib.adam(1e-2)
    pipe = make_engine(model, GPipeConfig(
        engine="compiled", balance=balance, chunks=chunks,
        schedule="1f1b", data_parallel=dp,
    ))
    host = make_engine(model, GPipeConfig(engine="host", balance=balance, chunks=chunks))

    params = pipe.init_params(jax.random.PRNGKey(0))
    state = opt.init(params)
    rng0 = jax.random.PRNGKey(1)
    p_ref, _, _ = host.train_step(params, opt.init(params), plan, rng0, opt)
    p_cmp, _, _ = pipe.train_step(params, opt.init(params), plan, rng0, opt)
    diff = max(float(abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_cmp)))
    print(f"data_parallel={dp} (mesh active: {pipe._data_parallel_active}) "
          f"vs host fill-drain oracle: max update diff {diff:.2e}")

    # the loader overlaps chunk t+1's device_put with chunk t's compute; on
    # the training path the stacked plan ships whole, so just demonstrate
    # the streaming order contract here
    batches = list(DoubleBufferedLoader(plan.batches[i].graph for i in range(chunks)))
    assert len(batches) == chunks
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    for _ in range(epochs):
        key, rng = jax.random.split(key)
        params, state, loss = pipe.train_step(params, state, plan, rng, opt)
    jax.block_until_ready(loss)
    print(f"epoch_s={(time.time()-t0)/epochs:6.2f} loss@{epochs}ep={float(loss):.3f}")
    print("scale further: open_streamed('powerlaw-256k') / ('powerlaw-1m') —")
    print("same code path, chunk count carries the growth (fig3's scale/* rows).")


def main():
    t0 = time.time()
    g = load_dataset("reddit-mini")
    print(f"reddit-mini built in {time.time()-t0:.1f}s: {g.num_nodes} nodes, "
          f"{int(g.num_edges)//2} edges, max_deg {g.max_degree}")

    model = build_paper_gat(g.num_features, g.num_classes)
    opt = opt_lib.adam(5e-3, weight_decay=5e-4)
    evaluate = make_eval(model)

    for chunks, strategy in [(1, "sequential"), (4, "halo"), (8, "halo")]:
        pipe = GPipe(model, GPipeConfig(balance=(2, 1, 1, 2), chunks=chunks))
        plan = make_plan(g, chunks, strategy=strategy, halo_hops=2)
        sizes = [b.num_nodes for b in plan.batches]
        key = jax.random.PRNGKey(0)
        params = pipe.init_params(key)
        state = opt.init(params)
        t0 = time.time()
        for epoch in range(3):
            key, rng = jax.random.split(key)
            params, state, loss = pipe.train_step(params, state, plan, rng, opt)
        jax.block_until_ready(loss)
        m = evaluate(params, g)
        print(f"chunks={chunks:2d} ({strategy:10s}) max_chunk_nodes={max(sizes):6d} "
              f"(full={g.num_nodes}) epoch_s={(time.time()-t0)/3:6.2f} "
              f"val_acc@3ep={float(m['val_acc']):.3f} edge_cut={plan.edge_cut:.2f}")
    print()
    print("observed: on this small-world graph (avg degree 32) a 2-hop halo of")
    print("1/4 of the nodes already spans the WHOLE graph — exact halos cannot")
    print("shrink chunks here. This is precisely why GraphSAGE-style sampling")
    print("and SIGN precompute (graphs/sign.py) exist: SIGN makes chunks exact")
    print("AND small regardless of graph density (see tests/test_sign.py).")

    streamed_mesh_demo()


if __name__ == "__main__":
    main()
